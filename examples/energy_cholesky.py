"""End-to-end paper scenario: distributed Cholesky with an algorithmic
energy plan, from DAG to power trace.

    PYTHONPATH=src python examples/energy_cholesky.py [--csv trace.csv]

* builds the 2-D block-cyclic Cholesky DAG on the paper's 16x16 grid,
* derives the static (algorithmic) DVFS schedule from per-task slack,
* simulates all four strategies on the ARC cluster power model,
* ACTUALLY runs the same factorization numerically (shard_map kernel on
  however many devices this host has) and checks ||L L^T - A||,
* writes the Fig-2-style 3-node power trace to CSV.
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dag import build_dag
from repro.core.energy_model import make_processor
from repro.core.scheduler import CostModel, simulate
from repro.core.strategies import (PlanContext, evaluate_strategies,
                                   get_strategy, registered_strategies)
from repro.linalg import distributed as D

ap = argparse.ArgumentParser()
ap.add_argument("--csv", default=None)
ap.add_argument("--tiles", type=int, default=24)
ap.add_argument("--tile-size", type=int, default=16)
args = ap.parse_args()

# ---------------------------------------------------- energy plan (16x16)
print("=== strategies on the paper's 16x16 grid ===")
graph = build_dag("cholesky", args.tiles, 2560, (16, 16))
proc = make_processor("arc_opteron_6128")
cost = CostModel()
for name, r in evaluate_strategies(graph, proc, cost,
                                   names=registered_strategies()).items():
    print(f"  {name:14s} time {r.makespan_s:7.3f} s   "
          f"energy {r.energy_j / 1e3:8.2f} kJ   "
          f"saved {r.energy_saved_pct:6.2f} %   "
          f"slowdown {r.slowdown_pct:5.2f} %   "
          f"switches {r.switch_count}")

ctx = PlanContext(graph, proc, cost)
tds = ctx.tds
print("  TDS wait classes (idle s): ",
      {k: round(v, 3) for k, v in tds.wait_seconds_by_class().items()
       if k != "none"})
print("  TDS slack classes (recl s):",
      {k: round(v, 3) for k, v in tds.slack_seconds_by_class().items()
       if k != "none"})

# how much of TX survives an imperfect cost model (the tx_online rows
# above used the default 10% relative error; sweep it here) -- and how
# much the closed loop wins back by re-planning from observed finishes
# every panel iteration (tx_replan, same noise draw; core/replan.py)
print("\n=== tx_online vs tx_replan: savings vs cost-model error ===")
from repro.core.strategies import StrategyConfig  # noqa: E402
tx_saved = None
for err in (0.0, 0.1, 0.2, 0.4):
    cfg = StrategyConfig(tx_online_rel_err=err)
    res = evaluate_strategies(graph, proc, cost,
                              names=("original", "tx_online", "tx_replan"),
                              cfg=cfg)
    r, rp = res["tx_online"], res["tx_replan"]
    if tx_saved is None:
        tx_saved = r.energy_saved_pct          # err=0 == offline tx
    keep = (r.energy_saved_pct / tx_saved) if tx_saved else 0.0
    print(f"  rel_err {err:4.2f}: one-shot saved {r.energy_saved_pct:6.2f} %"
          f"  (keeps {100.0 * keep:5.1f} % of TX)   "
          f"closed-loop saved {rp.energy_saved_pct:6.2f} %  "
          f"({rp.energy_saved_pct - r.energy_saved_pct:+5.2f} pts, "
          f"single seed)")

# ----------------------------------- asymmetric (big.LITTLE) cluster demo
# The same DAG on a heterogeneous machine: half the ranks are derated
# LITTLE cores (Costero-style). Strategies plan per-rank -- every task
# splits within its owner's own gear ladder -- and savings are vs the
# mixed machine's own peak-gear baseline.
print("\n=== big.LITTLE (1:1) on a 4x4 grid ===")
from repro.core.energy_model import make_big_little  # noqa: E402
bl_graph = build_dag("cholesky", args.tiles, 2560, (4, 4))
bl = make_big_little(n_big=1, n_little=1)       # interleaved big/LITTLE
for name, r in evaluate_strategies(bl_graph, bl, cost,
                                   names=("original", "race_to_halt",
                                          "algorithmic", "tx")).items():
    print(f"  {name:14s} time {r.makespan_s:7.3f} s   "
          f"energy {r.energy_j / 1e3:8.2f} kJ   "
          f"saved {r.energy_saved_pct:6.2f} %   "
          f"slowdown {r.slowdown_pct:5.2f} %")

# --------------------------------------------- the actual numerical kernel
print("\n=== the same algorithm, numerically, on this host's devices ===")
n_dev = jax.device_count()
q = 2 if n_dev >= 2 else 1
p = n_dev // q
mesh = jax.make_mesh((p, q), ("data", "model"))
n = args.tiles * args.tile_size
rng = np.random.default_rng(0)
a = rng.standard_normal((n, n))
a = (a @ a.T + n * np.eye(n)).astype(np.float32)
l = np.asarray(D.factorize("cholesky", jnp.asarray(a), args.tile_size, mesh))
err = np.abs(l @ l.T - a).max() / np.abs(a).max()
print(f"  mesh {p}x{q}, N={n}: max |L L^T - A| / |A| = {err:.2e}")
assert err < 1e-3

# ----------------------------------------------------------- power trace
if args.csv:
    sched = simulate(graph, proc, cost,
                     get_strategy("algorithmic").plan(ctx))
    times = np.linspace(0, sched.makespan, 500)
    watts = sched.power_trace(times, nodes=(0, 1, 2))
    with open(args.csv, "w") as f:
        f.write("time_s,watts_3nodes\n")
        for t, w in zip(times, watts):
            f.write(f"{t:.4f},{w:.1f}\n")
    print(f"  wrote power trace -> {args.csv}")
print("done.")
