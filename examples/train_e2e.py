"""End-to-end training driver with fault injection.

    PYTHONPATH=src python examples/train_e2e.py            # ~10M params, fast
    PYTHONPATH=src python examples/train_e2e.py --full     # ~100M params

Demonstrates the production loop end to end:
  1. trains a LM (reduced stablelm family) for a few hundred steps,
  2. SIMULATES A NODE FAILURE by abandoning the in-memory state mid-run,
  3. restarts from the latest atomic checkpoint and continues to the target
     step -- final loss matches an uninterrupted run bit-for-bit because
     the data pipeline is a pure function of (seed, step).
"""

import argparse
import dataclasses
import os
import shutil

import jax

from repro.configs import get_config, make_smoke
from repro.launch.train import main as train_main

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true", help="~100M-param model")
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_e2e")
args = ap.parse_args()

ckpt = args.ckpt_dir
shutil.rmtree(ckpt, ignore_errors=True)

common = ["--arch", "stablelm-12b", "--smoke", "--ckpt-dir", ckpt,
          "--ckpt-every", "50", "--energy-every", "100",
          "--batch", "8", "--seq", "128", "--log-every", "25"]
if args.full:
    # ~100M params: the smoke config widened (d_model 512, 8L, 32k vocab
    # -> 2 x 32768 x 512 + 8 x 12 x 512^2 = ~59M emb + ~25M blocks)
    common += ["--d-model", "512", "--n-layers", "8", "--vocab", "32768"]

crash_at = args.steps // 2
print(f"=== phase 1: train to step ~{crash_at}, then 'crash' ===")
train_main(common + ["--steps", str(args.steps), "--stop-at", str(crash_at)])

print("\n=== phase 2: node failure! restart from latest checkpoint ===")
out = train_main(common + ["--steps", str(args.steps), "--resume"])

print("\n=== phase 3: uninterrupted reference run (fresh state) ===")
shutil.rmtree(ckpt, ignore_errors=True)
ref = train_main(common + ["--steps", str(args.steps)])

diff = abs(out["final_loss"] - ref["final_loss"])
print(f"\nresumed final loss  {out['final_loss']:.6f}")
print(f"reference final loss {ref['final_loss']:.6f}   |diff| = {diff:.2e}")
assert diff < 1e-3, "restart must reproduce the uninterrupted trajectory"
print("fault-tolerant restart verified.")
