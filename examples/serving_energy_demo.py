"""Energy-aware LM serving demo: J/token + p99 across the registry.

Replays one seeded traffic trace (diurnal by default) through the
continuous-batching wave compiler (`repro.core.serving`), plans every
registered strategy on a serving-class cluster, scores them in ONE
batched `simulate_fleet` pass, and writes the serving-trace JSON
(arrivals + per-strategy J/token, p99, SLO violations) that nightly CI
uploads as an artifact.

    PYTHONPATH=src python examples/serving_energy_demo.py \
        [--shape diurnal] [--servers 4] [--rate 10] [--duration 24] \
        [--slo 2.5] [--seed 0] [--out results/serving_trace.json]
"""

from __future__ import annotations

import argparse
import json
import os

from repro.core import (MODEL_PROFILES, PlanContext, StrategyConfig,
                        TRAFFIC_SHAPES, build_serving_graph, get_strategy,
                        make_server_proc, make_trace, p99_latency_s,
                        registered_strategies, request_latencies,
                        serving_cost_model, serving_machine, simulate_fleet,
                        slo_violation_rate)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--shape", choices=TRAFFIC_SHAPES, default="diurnal")
    ap.add_argument("--family", choices=sorted(MODEL_PROFILES),
                    default="dense")
    ap.add_argument("--servers", type=int, default=4)
    ap.add_argument("--rate", type=float, default=10.0,
                    help="mean offered request rate (requests/s)")
    ap.add_argument("--duration", type=float, default=24.0,
                    help="trace horizon in seconds")
    ap.add_argument("--period", type=float, default=0.25,
                    help="continuous-batching wave period in seconds")
    ap.add_argument("--slo", type=float, default=2.5,
                    help="per-request latency SLO in seconds (p99 target)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="results/serving_trace.json",
                    help="serving-trace JSON output path")
    args = ap.parse_args()

    profile = MODEL_PROFILES[args.family]
    cost = serving_cost_model(profile)
    trace = make_trace(args.shape, rate_rps=args.rate,
                       duration_s=args.duration, seed=args.seed)
    sg = build_serving_graph(trace, n_servers=args.servers,
                             step_period_s=args.period, cost=cost,
                             profile=profile)
    machine = serving_machine(make_server_proc(), args.servers)
    cfg = StrategyConfig(plan_search_rounds=2, plan_search_lanes=64,
                         replan_every=8,
                         slo_latency_s=sg.horizon_s + args.slo)
    ctx = PlanContext(sg.graph, machine, cost, cfg)
    names = registered_strategies()
    plans = [get_strategy(n).plan(ctx) for n in names]
    fleet = simulate_fleet(sg.graph, machine, cost, plans, cores_per_node=1)
    energy = fleet.total_energy_j()
    lat = request_latencies(sg, fleet.finish)
    p99 = p99_latency_s(lat)
    viol = slo_violation_rate(lat, args.slo)

    print(f"shape={args.shape} family={args.family} "
          f"requests={trace.n_requests} tokens={trace.total_decode_tokens} "
          f"waves={sg.n_waves} servers={args.servers} slo={args.slo}s")
    print(f"{'strategy':16s} {'J/token':>8s} {'saved%':>7s} "
          f"{'p99 ms':>8s} {'viol%':>6s} {'SLO':>4s}")
    base = energy[names.index("original")]
    strategies_out = {}
    for i, name in enumerate(names):
        jpt = energy[i] / trace.total_decode_tokens
        ok = bool(p99[i] <= args.slo)
        print(f"{name:16s} {jpt:8.4f} {100 * (1 - energy[i] / base):7.2f} "
              f"{p99[i] * 1e3:8.1f} {100 * viol[i]:6.2f} "
              f"{'ok' if ok else 'MISS':>4s}")
        strategies_out[name] = {
            "j_per_token": round(float(jpt), 6),
            "energy_j": round(float(energy[i]), 3),
            "p99_latency_ms": round(float(p99[i]) * 1e3, 2),
            "slo_viol_pct": round(float(viol[i]) * 100.0, 3),
            "slo_ok": ok,
            "makespan_s": round(float(fleet.makespan[i]), 4),
        }

    payload = {
        "suite": "examples.serving_energy_demo",
        "shape": args.shape, "family": args.family, "seed": args.seed,
        "rate_rps": args.rate, "duration_s": args.duration,
        "period_s": args.period, "slo_s": args.slo,
        "n_servers": args.servers, "n_waves": sg.n_waves,
        "n_requests": trace.n_requests,
        "total_decode_tokens": trace.total_decode_tokens,
        "trace": {
            "arrival_s": [round(float(t), 6) for t in trace.arrival_s],
            "prompt_tokens": trace.prompt_tokens.tolist(),
            "decode_tokens": trace.decode_tokens.tolist(),
        },
        "strategies": strategies_out,
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
