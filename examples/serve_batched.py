"""Batched serving across cache families: linear KV (qwen), ring KV +
logit softcap (gemma2), recurrent states (recurrentgemma), SSD states
(mamba2).

    PYTHONPATH=src python examples/serve_batched.py [--arch gemma2-2b]

Serves a batch of 4 prompts with a prefill + autoregressive decode loop on
reduced configs (CPU-runnable), asserting finite logits and exercising
exactly the cache layouts the decode_32k / long_500k dry-run cells shard.
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, make_smoke
from repro.models import get_model
from repro.serve.engine import generate, temperature_sample

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default=None,
                help="single arch; default: one per cache family")
ap.add_argument("--prompt-len", type=int, default=32)
ap.add_argument("--new-tokens", type=int, default=16)
args = ap.parse_args()

archs = [args.arch] if args.arch else [
    "qwen2.5-3b",           # linear KV cache
    "gemma2-2b",            # ring (sliding-window) KV + softcap
    "recurrentgemma-2b",    # RG-LRU recurrent state + local attn
    "mamba2-370m",          # SSD state
]

for arch in archs:
    cfg = make_smoke(get_config(arch))
    api = get_model(cfg)
    params = api.param_tree("init", jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (4, args.prompt_len),
                                0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.frontend == "audio":
        batch["audio_embeds"] = jax.random.normal(
            jax.random.key(2), (4, cfg.frontend_len, cfg.d_model))
    t0 = time.time()
    out = generate(api, params, batch, n_new=args.new_tokens,
                   sampler=temperature_sample)
    dt = time.time() - t0
    toks = np.asarray(out.tokens)
    assert np.isfinite(np.asarray(out.prefill_logits)).all(), arch
    assert toks.shape == (4, args.new_tokens)
    print(f"{arch:22s} family={cfg.family:7s} "
          f"prefill+{args.new_tokens}tok x4 reqs in {dt:5.1f}s  "
          f"sample row0: {toks[0, :8].tolist()}")
print("done.")
