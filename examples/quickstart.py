"""Quickstart: the paper's energy machinery + the LM substrate in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. Builds the tiled-Cholesky task DAG the paper schedules, computes the
   per-task slack, and compares the four energy strategies.
2. Trains a reduced qwen2.5-family model for 20 steps on CPU and generates
   a few tokens -- the substrate the 10 production configs instantiate.
"""

import jax
import numpy as np

from repro.configs import get_config, make_smoke
from repro.core.dag import build_dag
from repro.core.energy_model import make_processor
from repro.core.scheduler import CostModel
from repro.core.strategies import (PlanContext, evaluate_strategies,
                                   registered_strategies)
from repro.models import get_model
from repro.serve.engine import generate
from repro.train.data import SyntheticDataset
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step

# ---------------------------------------------------------------- 1. paper
print("=== energy strategies on a 12x12-tile Cholesky, 4x4 grid ===")
graph = build_dag("cholesky", 12, 512, (4, 4))
proc = make_processor("amd_opteron_2218")     # the paper's worked example CPU
cost = CostModel()
res = evaluate_strategies(graph, proc, cost, names=registered_strategies())
for name, r in res.items():
    print(f"  {name:14s} time {r.makespan_s * 1e3:8.2f} ms   "
          f"energy {r.energy_j:8.2f} J   saved {r.energy_saved_pct:6.2f} %"
          f"   slowdown {r.slowdown_pct:5.2f} %")

# the TDS wait taxonomy behind the tx strategy's per-class gear policy
ctx = PlanContext(graph, proc, cost)
tds = ctx.tds
print("  TDS wait classes (idle ms):",
      {k: round(v * 1e3, 1) for k, v in tds.wait_seconds_by_class().items()
       if k != "none"})

# the task-type mix behind task_type_gears' asymmetric tables (panel /
# solve / update tasks, each confined to its own slice of the gear ladder)
from repro.core.tds import GEAR_CLASS_NAMES  # noqa: E402
classes = ctx.gear_classes
print("  task-type gear classes    :",
      {name: int((classes == code).sum())
       for code, name in enumerate(GEAR_CLASS_NAMES)})

# ------------------------------------------------------------ 2. substrate
print("\n=== 20 training steps of a reduced qwen2.5 config (CPU) ===")
cfg = make_smoke(get_config("qwen2.5-3b"))
api = get_model(cfg)
opt_cfg = AdamWConfig(peak_lr=3e-3, warmup_steps=5, total_steps=20)
state = init_train_state(api, opt_cfg, jax.random.key(0))
step_fn = jax.jit(make_train_step(api, opt_cfg), donate_argnums=(0, 1))
data = SyntheticDataset(cfg, batch=8, seq=64)

params, opt = state.params, state.opt
for step in range(20):
    params, opt, metrics = step_fn(params, opt, data.batch_at(step))
    if step % 5 == 0 or step == 19:
        print(f"  step {step:3d}  loss {float(metrics['loss']):.4f}")

print("\n=== greedy generation from the (briefly) trained model ===")
prompt = data.batch_at(999)["tokens"][:2, :16]
out = generate(api, params, {"tokens": prompt}, n_new=12)
print("  prompt tails :", np.asarray(prompt[:, -4:]).tolist())
print("  generated    :", np.asarray(out.tokens).tolist())
print("done.")
