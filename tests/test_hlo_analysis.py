"""HLO analyzer validation: trip-count-aware flop/byte/collective counting
against analytically known workloads."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze, parse_module

M, K, N = 64, 128, 96


def _hlo_of(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    a = jax.ShapeDtypeStruct((M, K), jnp.float32)
    b = jax.ShapeDtypeStruct((K, N), jnp.float32)
    hlo = _hlo_of(lambda a, b: a @ b, a, b)
    cost = analyze(hlo)
    assert cost.dot_flops == pytest.approx(2 * M * K * N, rel=1e-6)
    assert cost.n_while == 0


def test_scanned_matmul_multiplies_by_trip_count():
    """A scan over 7 matmuls must count 7x the flops (cost_analysis counts
    the body once -- the bug this analyzer exists to fix)."""
    trips = 7
    a = jax.ShapeDtypeStruct((M, M), jnp.float32)

    def fn(x):
        def body(c, _):
            return c @ x, ()
        y, _ = jax.lax.scan(body, jnp.eye(M), None, length=trips)
        return y

    hlo = _hlo_of(fn, a)
    cost = analyze(hlo)
    assert cost.n_while >= 1
    assert cost.dot_flops == pytest.approx(trips * 2 * M**3, rel=1e-6)


def test_nested_scan_multiplies():
    t_out, t_in = 3, 5
    a = jax.ShapeDtypeStruct((M, M), jnp.float32)

    def fn(x):
        def inner(c, _):
            return c @ x, ()

        def outer(c, _):
            y, _ = jax.lax.scan(inner, c, None, length=t_in)
            return y, ()

        y, _ = jax.lax.scan(outer, jnp.eye(M), None, length=t_out)
        return y

    cost = analyze(_hlo_of(fn, a))
    assert cost.dot_flops == pytest.approx(t_out * t_in * 2 * M**3, rel=1e-6)


def test_fori_loop_trip_count():
    trips = 11
    a = jax.ShapeDtypeStruct((M, M), jnp.float32)

    def fn(x):
        return jax.lax.fori_loop(0, trips, lambda i, c: c @ x, jnp.eye(M))

    cost = analyze(_hlo_of(fn, a))
    assert cost.dot_flops == pytest.approx(trips * 2 * M**3, rel=1e-6)


def test_hbm_bytes_reasonable_for_copy():
    """y = x + 1 on a [1024,1024] f32: HBM traffic ~ 2 x 4 MiB."""
    a = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    cost = analyze(_hlo_of(lambda x: x + 1.0, a))
    assert 0.5 * 8 * 2**20 <= cost.hbm_bytes <= 3 * 8 * 2**20


def test_parse_module_roundtrip_names():
    a = jax.ShapeDtypeStruct((M, K), jnp.float32)
    b = jax.ShapeDtypeStruct((K, N), jnp.float32)
    hlo = _hlo_of(lambda a, b: jnp.tanh(a @ b), a, b)
    comps = parse_module(hlo)
    assert any(c.is_entry for c in comps.values())
    entry = next(c for c in comps.values() if c.is_entry)
    assert len(entry.instrs) >= 2


def test_grad_of_scanned_mlp_flops():
    """Forward+backward of a scanned 4-layer MLP: 6x per-layer matmul flops
    (1 fwd + 2 bwd) within 25% (transpose/update overheads allowed)."""
    layers, d, bsz = 4, 64, 32
    w = jax.ShapeDtypeStruct((layers, d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((bsz, d), jnp.float32)

    def loss(w, x):
        def body(h, wi):
            return jnp.tanh(h @ wi), ()
        h, _ = jax.lax.scan(body, x, w)
        return (h ** 2).sum()

    cost = analyze(_hlo_of(lambda w, x: jax.grad(loss)(w, x), w, x))
    expect = 3 * layers * 2 * bsz * d * d
    assert expect * 0.75 <= cost.dot_flops <= expect * 1.5
