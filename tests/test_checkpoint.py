"""Fault-tolerant checkpointing: atomicity, GC, elastic restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import (latest_step, latest_steps,
                                    restore_checkpoint, save_checkpoint)


def _tree(seed=0, dtype=jnp.float32):
    k = jax.random.key(seed)
    return {
        "params": {"w": jax.random.normal(k, (4, 8), dtype),
                   "b": jnp.zeros((8,), jnp.bfloat16)},
        "opt": {"step": jnp.asarray(7, jnp.int32),
                "m": {"w": jnp.ones((4, 8), dtype)}},
    }


def test_roundtrip_with_bf16(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 10, tree)
    back = restore_checkpoint(str(tmp_path), 10, jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_gc_keeps_last_k(tmp_path):
    tree = _tree()
    for s in (10, 20, 30, 40, 50):
        save_checkpoint(str(tmp_path), s, tree, keep=3)
    assert latest_steps(str(tmp_path)) == [30, 40, 50]
    assert latest_step(str(tmp_path)) == 50


def test_no_tmp_residue_and_atomic_publish(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    names = os.listdir(tmp_path)
    assert not [n for n in names if n.endswith(".tmp")]
    # a truncated orphan .npz without manifest must be ignored
    with open(tmp_path / "step_00000002.npz", "wb") as f:
        f.write(b"garbage")
    assert latest_step(str(tmp_path)) == 1


def test_elastic_restore_dtype_conversion(tmp_path):
    """bf16 checkpoint restored into an f32 template (smoke-model reload)."""
    tree = {"w": jnp.ones((3, 3), jnp.bfloat16) * 1.5}
    save_checkpoint(str(tmp_path), 5, tree)
    back = restore_checkpoint(
        str(tmp_path), 5, {"w": jax.ShapeDtypeStruct((3, 3), jnp.float32)})
    assert back["w"].dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(back["w"]), 1.5)


def test_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 5, {"w": jnp.ones((3, 3))})
    with pytest.raises(ValueError, match="checkpoint shape"):
        restore_checkpoint(str(tmp_path), 5,
                           {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)})
