"""Plan-feasibility property test (PR 7): over randomized factorization
DAGs on homogeneous and big.LITTLE machines, EVERY registered strategy
must emit plans whose gears -- task segments and per-rank idle gears
alike -- come from the owning rank's own gear ladder (an asymmetric
machine makes a foreign gear a real hazard: the engines would silently
index another processor's power table), and the capped strategies
(`plan_search`, `single_freq_opt`) must honor their slowdown caps on
every draw, not just on the tuned benchmark cells.
"""

import numpy as np
import pytest

from repro.core import (CostModel, PlanContext, StrategyConfig, build_dag,
                        make_big_little, make_processor,
                        registered_strategies, get_strategy, simulate)

COST = CostModel()
MACHINES = {
    "homog": make_processor("arc_opteron_6128"),
    "big_little": make_big_little("arc_opteron_6128"),
}
# overhead-free, noise-free config: feasibility must hold structurally,
# not thanks to a particular overhead/noise draw
CFG = dict(cp_detect_overhead=0.0, monitor_overhead=0.0,
           tx_online_rel_err=0.0, plan_search_rounds=2,
           plan_search_lanes=64)
CAPPED = {"plan_search": "plan_search_slowdown_cap",
          "single_freq_opt": "single_freq_slowdown_cap"}


def _random_ctx(seed, machine):
    rng = np.random.default_rng(seed)
    fact = rng.choice(["cholesky", "lu", "qr"])
    n_tiles = int(rng.integers(3, 9))
    tile = int(rng.choice([128, 256, 512]))
    grid = (int(rng.integers(1, 3)), int(rng.integers(1, 3)))
    return PlanContext(build_dag(fact, n_tiles, tile, grid),
                       MACHINES[machine], COST, StrategyConfig(**CFG))


def _rank_ladders(ctx):
    """Per-rank set of (index, freq) pairs identifying that rank's gears."""
    return [{(g.index, g.freq_ghz) for g in p.gears}
            for p in ctx.rank_procs]


@pytest.mark.parametrize("machine", sorted(MACHINES))
@pytest.mark.parametrize("seed", range(8))
def test_all_strategies_feasible_on_random_dags(seed, machine):
    ctx = _random_ctx(seed, machine)
    ladders = _rank_ladders(ctx)
    n_ranks = ctx.graph.n_ranks
    for name in registered_strategies():
        plan = get_strategy(name).plan(ctx)
        # every emitted segment gear belongs to the owner rank's ladder
        for tid, segs in enumerate(plan.task_segments):
            ok = ladders[ctx.graph.tasks[tid].owner]
            for g, dt in segs:
                assert (g.index, g.freq_ghz) in ok, (name, tid)
                assert dt >= 0.0
        # so does every rank's idle gear
        for r in range(n_ranks):
            g = plan.idle_gear_for(r)
            assert (g.index, g.freq_ghz) in ladders[r], (name, r)
        # capped strategies honor their caps on every draw
        knob = CAPPED.get(name)
        if knob is not None:
            cap = getattr(ctx.cfg, knob)
            sched = simulate(ctx.graph, ctx.proc, COST, plan)
            assert (sched.makespan
                    <= ctx.baseline.makespan * (1.0 + cap) + 1e-9), name
