"""Plan-feasibility property test (PR 7, extended by PR 10): over
randomized factorization DAGs on homogeneous and big.LITTLE machines,
EVERY registered strategy must emit plans whose gears -- task segments and
per-rank idle gears alike -- come from the owning rank's own gear ladder
(an asymmetric machine makes a foreign gear a real hazard: the engines
would silently index another processor's power table), and the capped
strategies (`plan_search`, `single_freq_opt`, `tx_migrate`) must honor
their slowdown caps on every draw, not just on the tuned benchmark cells.

PR 10 migration properties: every `tx_migrate` / migrating `tx_replan`
mapping stays within the machine's ranks and preserves dependency
feasibility on the simulated timeline; a zero-cost `LinkModel` (uniform
default bandwidth, zero transfer energy) reproduces today's plans
bit-identically (the LinkModel no-op proof, mirroring
`MachineModel.homogeneous`); and the tx_migrate outcome on a fixed
big.LITTLE cell is pinned by tests/data/migrate_golden.json alongside
strategy_golden.json.
"""

import json
import os

import numpy as np
import pytest

from repro.core import (CostModel, LinkModel, PlanContext, StrategyConfig,
                        build_dag, make_big_little, make_processor,
                        registered_strategies, get_strategy, simulate,
                        simulate_reference)
from repro.core.replan import replan_tx

COST = CostModel()
MACHINES = {
    "homog": make_processor("arc_opteron_6128"),
    "big_little": make_big_little("arc_opteron_6128"),
}
# overhead-free, noise-free config: feasibility must hold structurally,
# not thanks to a particular overhead/noise draw
CFG = dict(cp_detect_overhead=0.0, monitor_overhead=0.0,
           tx_online_rel_err=0.0, plan_search_rounds=2,
           plan_search_lanes=64)
CAPPED = {"plan_search": "plan_search_slowdown_cap",
          "single_freq_opt": "single_freq_slowdown_cap",
          "tx_migrate": "tx_migrate_slowdown_cap"}

MIGRATE_GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                              "migrate_golden.json")


def _random_ctx(seed, machine, cost=COST, **over):
    rng = np.random.default_rng(seed)
    fact = rng.choice(["cholesky", "lu", "qr"])
    n_tiles = int(rng.integers(3, 9))
    tile = int(rng.choice([128, 256, 512]))
    grid = (int(rng.integers(1, 3)), int(rng.integers(1, 3)))
    return PlanContext(build_dag(fact, n_tiles, tile, grid),
                       MACHINES[machine], cost,
                       StrategyConfig(**{**CFG, **over}))


def _rank_ladders(ctx):
    """Per-rank set of (index, freq) pairs identifying that rank's gears."""
    return [{(g.index, g.freq_ghz) for g in p.gears}
            for p in ctx.rank_procs]


def _effective_owner(ctx, plan, tid):
    if plan.task_owners is None:
        return ctx.graph.tasks[tid].owner
    return plan.task_owners[tid]


def _assert_dependency_feasible(ctx, plan, sched):
    """Every dependency edge is honored on the simulated timeline: a task
    starts no earlier than each producer's finish plus the cross-rank
    transfer delay under the plan's EFFECTIVE mapping."""
    comm = ctx.cost.comm_cost(ctx.graph)
    cm = None if np.ndim(comm) == 0 else np.asarray(comm)
    for t in ctx.graph.tasks:
        own_t = _effective_owner(ctx, plan, t.tid)
        for d in t.deps:
            own_d = _effective_owner(ctx, plan, d)
            if cm is None:
                delay = comm if own_d != own_t else 0.0
            else:
                delay = float(cm[own_d, own_t])
            assert sched.start[t.tid] >= sched.finish[d] + delay - 1e-12, \
                (plan.name, t.tid, d)


@pytest.mark.parametrize("machine", sorted(MACHINES))
@pytest.mark.parametrize("seed", range(8))
def test_all_strategies_feasible_on_random_dags(seed, machine):
    ctx = _random_ctx(seed, machine)
    ladders = _rank_ladders(ctx)
    n_ranks = ctx.graph.n_ranks
    for name in registered_strategies():
        plan = get_strategy(name).plan(ctx)
        # a migration override (if any) stays within the machine's ranks
        # and covers every task exactly once
        if plan.task_owners is not None:
            assert len(plan.task_owners) == ctx.n_tasks, name
            assert all(0 <= o < n_ranks for o in plan.task_owners), name
        # every emitted segment gear belongs to the EFFECTIVE owner rank's
        # ladder (the graph owner's unless the plan migrates the task)
        for tid, segs in enumerate(plan.task_segments):
            ok = ladders[_effective_owner(ctx, plan, tid)]
            for g, dt in segs:
                assert (g.index, g.freq_ghz) in ok, (name, tid)
                assert dt >= 0.0
        # so does every rank's idle gear
        for r in range(n_ranks):
            g = plan.idle_gear_for(r)
            assert (g.index, g.freq_ghz) in ladders[r], (name, r)
        # capped strategies honor their caps on every draw
        knob = CAPPED.get(name)
        if knob is not None:
            cap = getattr(ctx.cfg, knob)
            sched = simulate(ctx.graph, ctx.proc, COST, plan)
            assert (sched.makespan
                    <= ctx.baseline.makespan * (1.0 + cap) + 1e-9), name


# -------------------------------------------------- migration properties
@pytest.mark.parametrize("seed", range(6))
def test_migrating_replan_mappings_feasible(seed):
    """The migrating wave driver's composite plan keeps a valid mapping,
    honors every dependency edge on the simulated timeline, and never
    exceeds the tx_migrate makespan cap by more than its non-migrating
    twin does (migration candidates are only ever ACCEPTED under the
    cap; the fallback is the frozen mapping)."""
    ctx = _random_ctx(seed, "big_little")
    cfg_m = StrategyConfig(**{**CFG, "replan_migrate": True})
    ctx_m = PlanContext(ctx.graph, ctx.proc, ctx.cost, cfg_m)
    out = replan_tx(ctx_m)
    plan = out.plan
    n_ranks = ctx.graph.n_ranks
    if plan.task_owners is not None:
        assert len(plan.task_owners) == ctx.n_tasks
        assert all(0 <= o < n_ranks for o in plan.task_owners)
    else:
        assert all(w.n_migrated == 0 for w in out.waves)
    sched = simulate(ctx.graph, ctx.proc, ctx.cost, plan)
    _assert_dependency_feasible(ctx_m, plan, sched)
    # exact three-engine agreement on the migrated composite
    ref = simulate_reference(ctx.graph, ctx.proc, ctx.cost, plan)
    assert np.array_equal(sched.start, ref.start)
    assert np.array_equal(sched.finish, ref.finish)
    # accepted migrations were gated on the cap; the fallback is the
    # frozen-mapping driver, so the composite can never be slower than
    # the worse of (cap, non-migrating tx_replan)
    base = replan_tx(ctx).plan
    s_base = simulate(ctx.graph, ctx.proc, ctx.cost, base)
    cap = ctx.makespan_cap(cfg_m.tx_migrate_slowdown_cap)
    assert sched.makespan <= max(cap, s_base.makespan) + 1e-9


@pytest.mark.parametrize("seed", range(6))
def test_tx_migrate_dependency_feasible(seed):
    """tx_migrate's winning mapping honors every dependency edge."""
    ctx = _random_ctx(seed, "big_little")
    plan = get_strategy("tx_migrate").plan(ctx)
    sched = simulate(ctx.graph, ctx.proc, ctx.cost, plan)
    _assert_dependency_feasible(ctx, plan, sched)


def test_tx_migrate_never_worse_than_tx():
    """Ties break toward the frozen mapping, so tx_migrate's energy is
    never above tx's on the same context."""
    for seed in range(6):
        ctx = _random_ctx(seed, "big_little")
        e_tx = simulate(ctx.graph, ctx.proc, ctx.cost,
                        get_strategy("tx").plan(ctx)).total_energy_j()
        e_mig = simulate(ctx.graph, ctx.proc, ctx.cost,
                         get_strategy("tx_migrate").plan(ctx)
                         ).total_energy_j()
        assert e_mig <= e_tx + 1e-9, seed


# -------------------------------------------------- LinkModel no-op proof
def _zero_cost_link():
    """A non-trivial LinkModel that is numerically the legacy scalar: the
    uniform default bandwidth on every pair, zero transfer energy."""
    return LinkModel(name="zero_cost",
                     pair_bandwidth_gbs=((COST.comm_bandwidth_gbs,),),
                     pair_energy_per_byte_j=((0.0,),))


@pytest.mark.parametrize("machine", sorted(MACHINES))
def test_zero_cost_link_is_bit_identical(machine):
    """A LinkModel whose matrix equals the uniform scalar and whose
    transfer energy is zero reproduces every strategy's schedule
    bit-identically: same starts/finishes/switches, same total energy
    (comm energy exactly 0.0)."""
    cost_link = CostModel(link=_zero_cost_link())
    assert not cost_link.link.is_trivial
    for seed in (0, 3):
        ctx = _random_ctx(seed, machine)
        ctx_link = _random_ctx(seed, machine, cost=cost_link)
        for name in registered_strategies():
            a = simulate(ctx.graph, ctx.proc, COST,
                         get_strategy(name).plan(ctx))
            b = simulate(ctx_link.graph, ctx_link.proc, cost_link,
                         get_strategy(name).plan(ctx_link))
            assert np.array_equal(a.start, b.start), name
            assert np.array_equal(a.finish, b.finish), name
            assert a.switch_count == b.switch_count, name
            assert b.comm_energy_j == 0.0, name
            assert a.total_energy_j() == b.total_energy_j(), name


# -------------------------------------------------- golden pin
def _migrate_golden_ctx():
    return PlanContext(build_dag("cholesky", 8, 256, (2, 2)),
                       MACHINES["big_little"], COST,
                       StrategyConfig(**CFG))


def test_tx_migrate_matches_golden():
    """tx_migrate on the fixed big.LITTLE cell is pinned: the winning
    mapping, the number of migrated tasks, and the simulated outcome must
    reproduce tests/data/migrate_golden.json (regenerate with
    `python -m tests.test_plan_feasibility` after an intentional change)."""
    with open(MIGRATE_GOLDEN) as f:
        exp = json.load(f)
    ctx = _migrate_golden_ctx()
    plan = get_strategy("tx_migrate").plan(ctx)
    sched = simulate(ctx.graph, ctx.proc, ctx.cost, plan)
    owners = [t.owner for t in ctx.graph.tasks] \
        if plan.task_owners is None else list(plan.task_owners)
    moved = sum(1 for t, o in zip(ctx.graph.tasks, owners) if t.owner != o)
    assert owners == exp["task_owners"]
    assert moved == exp["n_moved"]
    assert sched.switch_count == exp["switches"]
    assert sched.makespan == pytest.approx(exp["makespan"], rel=1e-9)
    assert sched.total_energy_j() == pytest.approx(exp["energy"], rel=1e-9)


def _record_golden():
    ctx = _migrate_golden_ctx()
    plan = get_strategy("tx_migrate").plan(ctx)
    sched = simulate(ctx.graph, ctx.proc, ctx.cost, plan)
    owners = [t.owner for t in ctx.graph.tasks] \
        if plan.task_owners is None else list(plan.task_owners)
    moved = sum(1 for t, o in zip(ctx.graph.tasks, owners) if t.owner != o)
    with open(MIGRATE_GOLDEN, "w") as f:
        json.dump({"task_owners": owners, "n_moved": moved,
                   "switches": sched.switch_count,
                   "makespan": sched.makespan,
                   "energy": sched.total_energy_j()}, f, indent=1)
    print(f"recorded {MIGRATE_GOLDEN}: {moved} moved, "
          f"makespan {sched.makespan}, energy {sched.total_energy_j()}")


if __name__ == "__main__":
    _record_golden()
