"""Property tests for the Ishihara-Yasuura two-gear split (core/dvfs.py).

Invariants checked over dense seeded sweeps of (duration, slack, beta) x
every gear table (plus hypothesis-driven cases when it is installed):

  * work conservation -- the segments perform exactly the task's work;
  * total time <= d + slack, with equality whenever the slack is
    reclaimable within the gear table's range (f_m >= f_min);
  * the gears of a two-segment split are adjacent in the table;
  * `two_gear_split_batch` reproduces the scalar function exactly
    (identical gears and identical floats), per task;
  * asymmetric (per-kind) subtables: batch==scalar parity on every gear
    subtable, segments confined to the subtable, overrun semantics for
    tables whose fastest gear is below f_max, and
    `two_gear_split_batch_by_table` matching the per-task scalar calls.
"""

import numpy as np
import pytest

from repro.core.dvfs import (duration_at, two_gear_split,
                             two_gear_split_batch,
                             two_gear_split_batch_by_table)
from repro.core.energy_model import GEAR_TABLES, make_processor, make_tpu_like

PROCS = [make_processor(name) for name in sorted(GEAR_TABLES)]
ALL_PROCS = PROCS + [make_tpu_like()]


def _sweep(seed=0, n=400):
    rng = np.random.default_rng(seed)
    d = np.concatenate([rng.uniform(1e-6, 10.0, n),
                        [0.0, 1e-12, 1.0, 1.0, 5.0]])
    s = np.concatenate([rng.uniform(0.0, 5.0, n),
                        [1.0, 1.0, 0.0, 1e-16, 100.0]])
    return d, s


def _check_invariants(proc, d, s, beta, segs):
    total_t = sum(t for _, t in segs)
    assert total_t <= d + s + 1e-12
    if d > 0.0:
        # work conservation: per-segment work fractions sum to 1
        work = sum(t / duration_at(d, proc.f_max, g.freq_ghz, beta)
                   for g, t in segs)
        assert work == pytest.approx(1.0, rel=1e-9)
        # equality when the slack is reclaimable within the gear range
        t_floor = duration_at(d, proc.f_max, proc.f_min, beta)
        if s > 1e-15 and t_floor >= d + s:
            assert total_t == pytest.approx(d + s, rel=1e-9)
    if len(segs) == 2:
        (g1, t1), (g2, t2) = segs
        assert abs(g1.index - g2.index) == 1     # adjacent gears
        assert g1.freq_ghz > g2.freq_ghz
        assert t1 > 0.0 and t2 > 0.0
    assert len(segs) <= 2


@pytest.mark.parametrize("proc", ALL_PROCS, ids=lambda p: p.name)
@pytest.mark.parametrize("beta", [1.0, 0.6])
def test_two_gear_split_invariants(proc, beta):
    d, s = _sweep()
    for di, si in zip(d, s):
        segs = two_gear_split(proc, float(di), float(si), beta)
        _check_invariants(proc, float(di), float(si), beta, segs)


@pytest.mark.parametrize("proc", ALL_PROCS, ids=lambda p: p.name)
def test_batch_matches_scalar_exactly(proc):
    d, s = _sweep(seed=7)
    rng = np.random.default_rng(8)
    for beta in (1.0, 0.5, rng.uniform(0.1, 1.0, len(d))):
        batch = two_gear_split_batch(proc, d, s, beta)
        assert len(batch) == len(d)
        for i in range(len(d)):
            bi = beta if np.isscalar(beta) else float(beta[i])
            scalar = two_gear_split(proc, float(d[i]), float(s[i]), bi)
            assert len(scalar) == len(batch[i]), i
            for (g_a, t_a), (g_b, t_b) in zip(scalar, batch[i]):
                assert g_a.index == g_b.index, i
                assert t_a == t_b, i               # identical floats


def test_batch_empty_and_degenerate():
    proc = PROCS[0]
    assert two_gear_split_batch(proc, np.zeros(0), np.zeros(0)) == []
    out = two_gear_split_batch(proc, np.array([0.0, -1.0]),
                               np.array([1.0, 1.0]))
    assert out == [[], []]


def test_single_gear_table_runs_flat():
    tpu = make_tpu_like()
    for segs in two_gear_split_batch(tpu, np.array([1.0, 2.0]),
                                     np.array([0.5, 0.0])):
        assert len(segs) == 1
        assert segs[0][0].index == 0


# ------------------------------------------------- asymmetric (per-kind) tables
def _subtables(proc):
    """A spread of gear subtables: prefixes, suffixes, stride-2, singletons."""
    n = len(proc.gears)
    index_sets = {(0,), (n - 1,), tuple(range(n))}
    index_sets.add(tuple(range(0, n, 2)))
    if n >= 2:
        index_sets.add(tuple(range(n // 2 + 1)))       # top half
        index_sets.add(tuple(range(n // 2, n)))        # bottom half
        index_sets.add((0, n - 1))                     # extremes only
    return [proc.gear_subtable(idx) for idx in sorted(index_sets)]


@pytest.mark.parametrize("proc", PROCS, ids=lambda p: p.name)
def test_subtable_batch_matches_scalar_exactly(proc):
    """batch==scalar parity must hold under every asymmetric subtable."""
    d, s = _sweep(seed=11, n=150)
    rng = np.random.default_rng(12)
    betas = (1.0, rng.uniform(0.1, 1.0, len(d)))
    for gears in _subtables(proc):
        for beta in betas:
            batch = two_gear_split_batch(proc, d, s, beta, gears=gears)
            for i in range(len(d)):
                bi = beta if np.isscalar(beta) else float(beta[i])
                scalar = two_gear_split(proc, float(d[i]), float(s[i]), bi,
                                        gears=gears)
                assert len(scalar) == len(batch[i]), (i, gears)
                for (g_a, t_a), (g_b, t_b) in zip(scalar, batch[i]):
                    assert g_a.index == g_b.index, (i, gears)
                    assert t_a == t_b, (i, gears)      # identical floats


@pytest.mark.parametrize("proc", PROCS, ids=lambda p: p.name)
@pytest.mark.parametrize("beta", [1.0, 0.6])
def test_subtable_invariants(proc, beta):
    """Work conservation + confinement + adjacency within each subtable."""
    d, s = _sweep(seed=13, n=150)
    for gears in _subtables(proc):
        allowed = {g.index for g in gears}
        positions = {g.index: p for p, g in enumerate(gears)}
        for di, si in zip(d, s):
            segs = two_gear_split(proc, float(di), float(si), beta,
                                  gears=gears)
            assert all(g.index in allowed for g, _ in segs)
            if di > 0.0:
                work = sum(t / duration_at(di, proc.f_max, g.freq_ghz, beta)
                           for g, t in segs)
                assert work == pytest.approx(1.0, rel=1e-9)
            if len(segs) == 2:
                (g1, _), (g2, _) = segs
                # adjacent in the SUBTABLE (not necessarily the full ladder)
                assert positions[g1.index] + 1 == positions[g2.index]
            assert len(segs) <= 2
            # total time never exceeds the window... unless the subtable's
            # fastest gear forces an overrun (big.LITTLE semantics)
            total_t = sum(t for _, t in segs)
            d_at_top = duration_at(di, proc.f_max, gears[0].freq_ghz, beta) \
                if di > 0.0 else 0.0
            assert total_t <= max(di + si, d_at_top) + 1e-12


def test_restricted_table_overruns_when_forced():
    """A task pinned below f_max runs slow regardless of slack."""
    proc = PROCS[0]
    assert len(proc.gears) >= 2
    low_only = proc.gear_subtable((len(proc.gears) - 1,))
    d = 1.0
    segs = two_gear_split(proc, d, 0.0, 1.0, gears=low_only)
    assert len(segs) == 1
    g, t = segs[0]
    assert g.index == len(proc.gears) - 1
    assert t == pytest.approx(d * proc.f_max / proc.f_min, rel=1e-12)
    # tiny slack cannot help: same forced duration
    segs2 = two_gear_split(proc, d, 1e-3, 1.0, gears=low_only)
    assert segs2[0][1] >= segs[0][1] - 1e-12


def test_default_gears_kwarg_is_identity():
    """gears=proc.gears must be byte-for-byte the default behavior."""
    proc = make_processor("arc_opteron_6128")
    d, s = _sweep(seed=17, n=100)
    default = two_gear_split_batch(proc, d, s, 0.7)
    explicit = two_gear_split_batch(proc, d, s, 0.7, gears=proc.gears)
    for a, b in zip(default, explicit):
        assert [(g.index, t) for g, t in a] == [(g.index, t) for g, t in b]


@pytest.mark.parametrize("proc", PROCS, ids=lambda p: p.name)
def test_batch_by_table_matches_scalar(proc):
    """Random per-task table assignment == per-task scalar with that table."""
    tables = _subtables(proc)[:3]
    rng = np.random.default_rng(19)
    d, s = _sweep(seed=19, n=120)
    ids = rng.integers(0, len(tables), len(d))
    beta = rng.uniform(0.1, 1.0, len(d))
    out = two_gear_split_batch_by_table(proc, d, s, beta, ids, tables)
    assert len(out) == len(d)
    for i in range(len(d)):
        scalar = two_gear_split(proc, float(d[i]), float(s[i]),
                                float(beta[i]), gears=tables[ids[i]])
        assert [(g.index, t) for g, t in out[i]] == \
            [(g.index, t) for g, t in scalar], i


def test_batch_by_table_validates_ids():
    proc = PROCS[0]
    tables = [proc.gears]
    with pytest.raises(ValueError):
        two_gear_split_batch_by_table(proc, np.ones(3), np.zeros(3), 1.0,
                                      np.array([0, 1, 0]), tables)
    with pytest.raises(ValueError):
        two_gear_split_batch_by_table(proc, np.ones(3), np.zeros(3), 1.0,
                                      np.array([0, 0]), tables)


def test_gear_subtable_validation():
    proc = PROCS[0]
    with pytest.raises(ValueError):
        proc.gear_subtable(())
    with pytest.raises(ValueError):
        proc.gear_subtable((1, 0))          # not increasing
    with pytest.raises(ValueError):
        proc.gear_subtable((0, len(proc.gears)))
    sub = proc.gear_subtable((0, len(proc.gears) - 1))
    assert [g.index for g in sub] == [0, len(proc.gears) - 1]
    # prefixes by depth
    assert proc.gear_prefix(0.0) == proc.gears[:1]
    assert proc.gear_prefix(1.0) == proc.gears
    with pytest.raises(ValueError):
        proc.gear_prefix(1.5)


# ---------------------------------------------------------------- hypothesis
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # optional dev dependency (requirements-dev)
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @given(d=st.floats(1e-6, 10.0), slack_frac=st.floats(0.0, 4.0),
           beta=st.floats(0.1, 1.0), proc_i=st.integers(0, len(PROCS) - 1))
    @settings(max_examples=300, deadline=None)
    def test_two_gear_split_invariants_hypothesis(d, slack_frac, beta,
                                                  proc_i):
        proc = PROCS[proc_i]
        s = d * slack_frac
        segs = two_gear_split(proc, d, s, beta)
        _check_invariants(proc, d, s, beta, segs)
        batch = two_gear_split_batch(proc, np.array([d]), np.array([s]),
                                     beta)[0]
        assert len(batch) == len(segs)
        for (g_a, t_a), (g_b, t_b) in zip(segs, batch):
            assert g_a.index == g_b.index and t_a == t_b
