"""Property tests for the Ishihara-Yasuura two-gear split (core/dvfs.py).

Invariants checked over dense seeded sweeps of (duration, slack, beta) x
every gear table (plus hypothesis-driven cases when it is installed):

  * work conservation -- the segments perform exactly the task's work;
  * total time <= d + slack, with equality whenever the slack is
    reclaimable within the gear table's range (f_m >= f_min);
  * the gears of a two-segment split are adjacent in the table;
  * `two_gear_split_batch` reproduces the scalar function exactly
    (identical gears and identical floats), per task.
"""

import numpy as np
import pytest

from repro.core.dvfs import duration_at, two_gear_split, two_gear_split_batch
from repro.core.energy_model import GEAR_TABLES, make_processor, make_tpu_like

PROCS = [make_processor(name) for name in sorted(GEAR_TABLES)]
ALL_PROCS = PROCS + [make_tpu_like()]


def _sweep(seed=0, n=400):
    rng = np.random.default_rng(seed)
    d = np.concatenate([rng.uniform(1e-6, 10.0, n),
                        [0.0, 1e-12, 1.0, 1.0, 5.0]])
    s = np.concatenate([rng.uniform(0.0, 5.0, n),
                        [1.0, 1.0, 0.0, 1e-16, 100.0]])
    return d, s


def _check_invariants(proc, d, s, beta, segs):
    total_t = sum(t for _, t in segs)
    assert total_t <= d + s + 1e-12
    if d > 0.0:
        # work conservation: per-segment work fractions sum to 1
        work = sum(t / duration_at(d, proc.f_max, g.freq_ghz, beta)
                   for g, t in segs)
        assert work == pytest.approx(1.0, rel=1e-9)
        # equality when the slack is reclaimable within the gear range
        t_floor = duration_at(d, proc.f_max, proc.f_min, beta)
        if s > 1e-15 and t_floor >= d + s:
            assert total_t == pytest.approx(d + s, rel=1e-9)
    if len(segs) == 2:
        (g1, t1), (g2, t2) = segs
        assert abs(g1.index - g2.index) == 1     # adjacent gears
        assert g1.freq_ghz > g2.freq_ghz
        assert t1 > 0.0 and t2 > 0.0
    assert len(segs) <= 2


@pytest.mark.parametrize("proc", ALL_PROCS, ids=lambda p: p.name)
@pytest.mark.parametrize("beta", [1.0, 0.6])
def test_two_gear_split_invariants(proc, beta):
    d, s = _sweep()
    for di, si in zip(d, s):
        segs = two_gear_split(proc, float(di), float(si), beta)
        _check_invariants(proc, float(di), float(si), beta, segs)


@pytest.mark.parametrize("proc", ALL_PROCS, ids=lambda p: p.name)
def test_batch_matches_scalar_exactly(proc):
    d, s = _sweep(seed=7)
    rng = np.random.default_rng(8)
    for beta in (1.0, 0.5, rng.uniform(0.1, 1.0, len(d))):
        batch = two_gear_split_batch(proc, d, s, beta)
        assert len(batch) == len(d)
        for i in range(len(d)):
            bi = beta if np.isscalar(beta) else float(beta[i])
            scalar = two_gear_split(proc, float(d[i]), float(s[i]), bi)
            assert len(scalar) == len(batch[i]), i
            for (g_a, t_a), (g_b, t_b) in zip(scalar, batch[i]):
                assert g_a.index == g_b.index, i
                assert t_a == t_b, i               # identical floats


def test_batch_empty_and_degenerate():
    proc = PROCS[0]
    assert two_gear_split_batch(proc, np.zeros(0), np.zeros(0)) == []
    out = two_gear_split_batch(proc, np.array([0.0, -1.0]),
                               np.array([1.0, 1.0]))
    assert out == [[], []]


def test_single_gear_table_runs_flat():
    tpu = make_tpu_like()
    for segs in two_gear_split_batch(tpu, np.array([1.0, 2.0]),
                                     np.array([0.5, 0.0])):
        assert len(segs) == 1
        assert segs[0][0].index == 0


# ---------------------------------------------------------------- hypothesis
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # optional dev dependency (requirements-dev)
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @given(d=st.floats(1e-6, 10.0), slack_frac=st.floats(0.0, 4.0),
           beta=st.floats(0.1, 1.0), proc_i=st.integers(0, len(PROCS) - 1))
    @settings(max_examples=300, deadline=None)
    def test_two_gear_split_invariants_hypothesis(d, slack_frac, beta,
                                                  proc_i):
        proc = PROCS[proc_i]
        s = d * slack_frac
        segs = two_gear_split(proc, d, s, beta)
        _check_invariants(proc, d, s, beta, segs)
        batch = two_gear_split_batch(proc, np.array([d]), np.array([s]),
                                     beta)[0]
        assert len(batch) == len(segs)
        for (g_a, t_a), (g_b, t_b) in zip(segs, batch):
            assert g_a.index == g_b.index and t_a == t_b
