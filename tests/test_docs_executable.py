"""Docs-stay-executable gate (ISSUE 5).

Documentation that CI never touches rots; this module makes the written
surface load-bearing:

  * README.md and docs/ARCHITECTURE.md exist and cross-link, and
    ROADMAP.md links to both (the prose home moved out of the ROADMAP);
  * the README's strategy-registry table stays in sync with the live
    registry -- adding a strategy without documenting it fails CI;
  * every ```python fenced block in the README actually executes (the
    snippets are written to run in seconds on CPU);
  * the quickstart commands users copy-paste (tier-1 pytest invocation,
    benchmarks.run, check.sh, the examples) appear verbatim.

CI's `docs` job runs this module on every push; the nightly workflow
additionally executes the heavier examples end to end.
"""

import pathlib
import re

import pytest

from repro.core import registered_strategies

ROOT = pathlib.Path(__file__).resolve().parent.parent
README = ROOT / "README.md"
ARCH = ROOT / "docs" / "ARCHITECTURE.md"
ROOFLINE = ROOT / "docs" / "ROOFLINE.md"
ROADMAP = ROOT / "ROADMAP.md"


def test_docs_exist():
    for path in (README, ARCH, ROOFLINE, ROADMAP):
        assert path.is_file(), f"{path.name} is missing"
        assert len(path.read_text()) > 500, f"{path.name} is a stub"


def test_cross_links():
    """README <-> ARCHITECTURE <-> ROADMAP all reference each other."""
    readme = README.read_text()
    arch = ARCH.read_text()
    roadmap = ROADMAP.read_text()
    assert "docs/ARCHITECTURE.md" in readme
    assert "ROADMAP.md" in readme
    assert "README.md" in arch
    assert "docs/ARCHITECTURE.md" in roadmap, \
        "ROADMAP must link to the architecture doc instead of restating it"
    assert "README.md" in roadmap


def test_roofline_doc_cross_links():
    """The roofline contract page is reachable from both prose homes and
    links back to them; it documents the artifact + regeneration path."""
    readme = README.read_text()
    arch = ARCH.read_text()
    roofline = ROOFLINE.read_text()
    assert "docs/ROOFLINE.md" in readme
    assert "ROOFLINE.md" in arch
    assert "ARCHITECTURE.md" in roofline
    assert "README" in roofline
    for anchor in ("results/roofline.json", "roofline/v2",
                   "repro.launch.zoo", "beta_from_terms"):
        assert anchor in roofline, f"ROOFLINE.md lost {anchor!r}"
    # the generator command users copy-paste appears verbatim
    assert "python -m repro.launch.zoo" in readme


def test_registry_table_in_sync():
    """Every registered strategy appears (as `name`) in the README table;
    nothing documented is stale."""
    readme = README.read_text()
    documented = set(re.findall(r"^\| `([a-z_0-9]+)` \|", readme,
                                flags=re.MULTILINE))
    live = set(registered_strategies())
    missing = live - documented
    stale = documented - live
    assert not missing, f"README strategy table is missing {sorted(missing)}"
    assert not stale, f"README documents unregistered {sorted(stale)}"


def test_quickstart_commands_present():
    readme = README.read_text()
    for cmd in (
        "PYTHONPATH=src python -m pytest -x -q",
        "python -m benchmarks.run --json",
        "scripts/check.sh",
        "examples/energy_cholesky.py",
        "examples/quickstart.py",
    ):
        assert cmd in readme, f"README quickstart lost {cmd!r}"


def _python_blocks(text):
    return re.findall(r"```python\n(.*?)```", text, flags=re.DOTALL)


@pytest.mark.parametrize("idx,block",
                         list(enumerate(_python_blocks(README.read_text()))),
                         ids=lambda v: v if isinstance(v, int) else "block")
def test_readme_python_snippets_execute(idx, block):
    """The README's fenced python blocks run as written."""
    assert block.strip(), "empty snippet"
    exec(compile(block, f"README.md:block{idx}", "exec"), {})  # noqa: S102


def test_architecture_names_real_modules():
    """The layer map's module names must exist in the tree."""
    arch = ARCH.read_text()
    for mod in ("dag.py", "critical_path.py", "tds.py", "strategies.py",
                "dvfs.py", "scheduler.py", "fleet.py", "energy_model.py",
                "replan.py", "optimize.py", "serving.py"):
        assert mod in arch, f"ARCHITECTURE layer map lost {mod}"
        assert (ROOT / "src" / "repro" / "core" / mod).is_file(), mod
