"""Docstring-coverage gate for the public `repro.core` + `repro.launch` API.

A lightweight stand-in for `interrogate --fail-under` (which is not a
pinned dev dependency): walks every module of `repro.core` and
`repro.launch` and asserts

  * 100% docstring coverage over the public surface -- every public
    module, class, function, method, and property defined in the package
    (dataclass-generated and inherited members excluded);
  * NumPy-style sections (`Parameters` / `Returns`) on the named core
    entry points a new user meets first (the README / ARCHITECTURE
    surface): the simulator engines, the two-gear splits, the TDS and
    residual-graph analyses, the planning context views, the replay
    driver, and the roofline pipeline (docs/ROOFLINE.md).

Being a test (not a linter config), coverage cannot regress without
failing CI, and the required-sections list documents which APIs are held
to the fuller standard.
"""

import inspect
import os

import pytest

import repro.core as core
from repro.core import (critical_path, dag, dvfs, energy_aware_step,
                        energy_model, fleet, optimize, replan,
                        roofline_model, scheduler, serving, strategies, tds)

# repro.launch.dryrun sets XLA_FLAGS (fake host device count) at import,
# before jax's backend initializes; restore the env so the rest of the
# in-process suite keeps seeing the default single device.
_saved_xla_flags = os.environ.get("XLA_FLAGS")
from repro.launch import dryrun, hlo_analysis, specs, zoo  # noqa: E402
from repro.launch import roofline as launch_roofline       # noqa: E402
from repro.launch import train as launch_train             # noqa: E402
if _saved_xla_flags is None:
    os.environ.pop("XLA_FLAGS", None)
else:
    os.environ["XLA_FLAGS"] = _saved_xla_flags

MODULES = (core, critical_path, dag, dvfs, energy_aware_step, energy_model,
           fleet, optimize, replan, roofline_model, scheduler, serving,
           strategies, tds,
           dryrun, hlo_analysis, launch_roofline, specs, zoo, launch_train)

# Entry points that must carry full NumPy-style docstrings
# (module attribute path -> callable). Keep in sync with README.md's API
# table; tests/test_docs_executable.py checks the README side.
NUMPY_STYLE_APIS = {
    "scheduler.simulate": scheduler.simulate,
    "scheduler.simulate_reference": scheduler.simulate_reference,
    "scheduler.machine_nodal_const_power_w":
        scheduler.machine_nodal_const_power_w,
    "fleet.simulate_fleet": fleet.simulate_fleet,
    "dvfs.two_gear_split": dvfs.two_gear_split,
    "dvfs.two_gear_split_batch": dvfs.two_gear_split_batch,
    "dvfs.two_gear_split_batch_by_table": dvfs.two_gear_split_batch_by_table,
    "tds.analyze_tds": tds.analyze_tds,
    "tds.analyze_residual_tds": tds.analyze_residual_tds,
    "critical_path.cp_analysis": critical_path.cp_analysis,
    "critical_path.schedule_slack": critical_path.schedule_slack,
    "critical_path.residual_schedule_times":
        critical_path.residual_schedule_times,
    "critical_path.residual_schedule_slack":
        critical_path.residual_schedule_slack,
    "critical_path.validate_frozen_closure":
        critical_path.validate_frozen_closure,
    "strategies.PlanContext.restricted_to":
        strategies.PlanContext.restricted_to,
    "strategies.evaluate_strategies": strategies.evaluate_strategies,
    "strategies.make_plan": strategies.make_plan,
    "strategies.tx_policy_segments": strategies.tx_policy_segments,
    "replan.replan_tx": replan.replan_tx,
    "replan.iteration_waves": replan.iteration_waves,
    "dvfs.two_gear_split_arrays": dvfs.two_gear_split_arrays,
    "optimize.search_plan": optimize.search_plan,
    "optimize.CandidateEvaluator.evaluate":
        optimize.CandidateEvaluator.evaluate,
    "serving.traffic_rate_curve": serving.traffic_rate_curve,
    "serving.make_trace": serving.make_trace,
    "serving.serving_machine": serving.serving_machine,
    "serving.serving_cost_model": serving.serving_cost_model,
    "serving.build_serving_graph": serving.build_serving_graph,
    "serving.request_latencies": serving.request_latencies,
    "serving.p99_latency_s": serving.p99_latency_s,
    "serving.slo_violation_rate": serving.slo_violation_rate,
    "serving.profiles_from_roofline": serving.profiles_from_roofline,
    "serving.profile_for_arch": serving.profile_for_arch,
    "roofline_model.beta_from_terms": roofline_model.beta_from_terms,
    "roofline_model.roofline_cost_model": roofline_model.roofline_cost_model,
    "roofline_model.RooflineTable.load": roofline_model.RooflineTable.load,
    "roofline_model.RooflineTable.kind_betas":
        roofline_model.RooflineTable.kind_betas,
    "hlo_analysis.analyze": hlo_analysis.analyze,
    "dryrun.run_cell": dryrun.run_cell,
    "dryrun.roofline_terms": dryrun.roofline_terms,
    "roofline.corrected_terms": launch_roofline.corrected_terms,
    "specs.make_cell": specs.make_cell,
    "zoo.generate": zoo.generate,
    "zoo.zoo_row": zoo.zoo_row,
    "zoo.check": zoo.check,
}


def _is_dataclass_generated(obj) -> bool:
    """__init__/__repr__/__eq__ synthesized by @dataclass carry no source."""
    return getattr(obj, "__qualname__", "").startswith("__create_fn__")


def _public_members(module):
    """(name, obj) pairs the gate holds to the docstring requirement."""
    out = []
    for name, obj in vars(module).items():
        if name.startswith("_"):
            continue
        if not (inspect.isclass(obj) or inspect.isfunction(obj)):
            continue
        if getattr(obj, "__module__", None) != module.__name__:
            continue          # re-exported from elsewhere; checked there
        out.append((f"{module.__name__}.{name}", obj))
        if inspect.isclass(obj):
            for mname, mobj in vars(obj).items():
                if mname.startswith("_"):
                    continue
                if isinstance(mobj, (staticmethod, classmethod)):
                    mobj = mobj.__func__
                if isinstance(mobj, property):
                    mobj = mobj.fget
                elif hasattr(mobj, "func"):          # cached_property
                    mobj = mobj.func
                if not inspect.isfunction(mobj):
                    continue
                if _is_dataclass_generated(mobj):
                    continue
                out.append((f"{module.__name__}.{obj.__name__}.{mname}",
                            mobj))
    return out


@pytest.mark.parametrize("module", MODULES,
                         ids=lambda m: m.__name__.rsplit(".", 1)[-1])
def test_module_docstring(module):
    assert module.__doc__ and len(module.__doc__.strip()) > 40, \
        f"{module.__name__} needs a real module docstring"


def test_public_api_docstring_coverage():
    """Every public class/function/method in repro.core is documented."""
    missing = []
    total = 0
    for module in MODULES[1:]:                  # core itself: members re-exported
        for name, obj in _public_members(module):
            total += 1
            doc = inspect.getdoc(obj)
            if not doc or len(doc.strip()) < 10:
                missing.append(name)
    assert total > 100, "gate walked suspiciously few members"
    assert not missing, (
        f"{len(missing)}/{total} public members lack docstrings: "
        + ", ".join(sorted(missing)))


@pytest.mark.parametrize("path", sorted(NUMPY_STYLE_APIS),
                         ids=lambda p: p)
def test_numpy_style_sections(path):
    """Named entry points carry Parameters and Returns sections."""
    doc = inspect.getdoc(NUMPY_STYLE_APIS[path]) or ""
    for section in ("Parameters\n----------", "Returns\n-------"):
        assert section in doc, \
            f"{path} docstring is missing its NumPy-style {section.split()[0]} section"


def test_every_registered_strategy_documented():
    """Each strategy class (and its plan method) explains its policy."""
    for name in core.registered_strategies():
        cls = type(core.get_strategy(name))
        doc = inspect.getdoc(cls)
        assert doc and len(doc) > 30, f"strategy {name!r} is undocumented"
