"""Unit + property tests for the energy-saving core (the paper's contribution)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # only the @given property tests need hypothesis
    class _StStub:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StStub()

    def given(**_kw):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(**_kw):
        return lambda f: f

from repro.core import (CostModel, GEAR_TABLES, StrategyConfig, build_dag,
                        cp_analysis, duration_at, evaluate_strategies,
                        factorization_flops, machine_nodal_const_power_w,
                        make_plan, make_processor, make_tpu_like,
                        max_slack_ratio, plan_energy_j, schedule_slack,
                        simulate, simulate_fleet, simulate_reference,
                        strategy_gap_terms, two_gear_split,
                        verify_worked_example)
from repro.core.scheduler import RankSegment, Schedule

PROC = make_processor("arc_opteron_6128")
COST = CostModel()


# ---------------------------------------------------------------- DAG layer
@pytest.mark.parametrize("name", ["cholesky", "lu", "qr"])
def test_dag_topological_and_flops(name):
    g = build_dag(name, 8, 256, (2, 2))
    for t in g.tasks:
        assert all(d < t.tid for d in t.deps), "tasks must be emitted topo-sorted"
    # tiled flop count matches the analytic factorization count to leading order
    n = 8 * 256
    analytic = factorization_flops(name, n)
    ratio = g.total_flops() / analytic
    assert 0.8 < ratio < 2.6, ratio  # QR tile algorithms carry ~2x overhead


def test_block_cyclic_owner_coverage():
    g = build_dag("cholesky", 12, 128, (3, 4))
    owners = {t.owner for t in g.tasks}
    assert owners == set(range(12))   # all ranks get work


@pytest.mark.parametrize("name", ["cholesky", "lu", "qr"])
def test_critical_path_lower_bounds_makespan(name):
    g = build_dag(name, 6, 256, (2, 2))
    durs = np.array([COST.duration_top(t.flops, t.kind, PROC) for t in g.tasks])
    cp = cp_analysis(g, durs, COST.comm_time(g))
    sched = simulate(g, PROC, COST, make_plan("original", g, PROC, COST))
    assert cp.cp_length <= sched.makespan + 1e-12
    assert np.all(cp.total_float >= -1e-12)
    assert cp.on_cp.any()


def test_schedule_slack_nonnegative_and_safe():
    g = build_dag("cholesky", 8, 256, (2, 2))
    sched = simulate(g, PROC, COST, make_plan("original", g, PROC, COST))
    slack = schedule_slack(sched.start, sched.finish, g, COST.comm_time(g))
    assert np.all(slack >= 0.0)
    # stretching every task into its local slack must not delay the makespan
    res = evaluate_strategies(g, PROC, COST,
                              names=("original", "algorithmic"),
                              cfg=StrategyConfig(cp_detect_overhead=0.0,
                                                 monitor_overhead=0.0))
    assert res["algorithmic"].makespan_s <= res["original"].makespan_s * 1.02


# ------------------------------------------------------------- energy model
def test_worked_example_matches_paper_text():
    out = verify_worked_example()
    assert out["dEd"] == pytest.approx(-0.8785, abs=1e-4)
    assert out["dEl"] == pytest.approx(-0.0875, abs=1e-4)


@pytest.mark.parametrize("table", sorted(GEAR_TABLES))
def test_gear_tables_monotonic(table):
    proc = make_processor(table)
    freqs = [g.freq_ghz for g in proc.gears]
    volts = [g.voltage for g in proc.gears]
    assert freqs == sorted(freqs, reverse=True)
    assert volts == sorted(volts, reverse=True)
    # power is monotone in gear (highest gear draws the most)
    pw = [proc.core_power_w(g, True) for g in proc.gears]
    assert pw == sorted(pw, reverse=True)


@pytest.mark.parametrize("table", sorted(GEAR_TABLES))
def test_strategy_gap_shrinks_when_voltage_flat(table):
    """The paper's observation: dEd at moderate n is small when V barely
    scales with f (modern tables)."""
    proc = make_processor(table)
    n = min(1.25, max_slack_ratio(proc))
    d_ed, d_el = strategy_gap_terms(proc, n)
    assert d_ed <= 1e-9  # CP-aware never loses on dynamic energy
    v_h, v_l = proc.gears[0].voltage, proc.gears[-1].voltage
    rel_v_span = (v_h - v_l) / v_h
    # the gap per unit ACT is bounded by something proportional to V span
    assert abs(d_ed) <= 3.0 * proc.gears[0].freq_ghz * v_h**2


# ------------------------------------------------------------------- DVFS
@given(d=st.floats(1e-4, 10.0), slack_frac=st.floats(0.0, 3.0))
@settings(max_examples=200, deadline=None)
def test_two_gear_split_work_and_time(d, slack_frac):
    slack = d * slack_frac
    segs = two_gear_split(PROC, d, slack)
    total_t = sum(t for _, t in segs)
    # work conservation: sum f*t == f_h*d (beta=1)
    work = sum(g.freq_ghz * t for g, t in segs)
    assert work == pytest.approx(PROC.f_max * d, rel=1e-9)
    # never exceeds the slack window
    assert total_t <= d + slack + 1e-12


@given(d=st.floats(1e-4, 10.0), slack_frac=st.floats(0.05, 3.0))
@settings(max_examples=200, deadline=None)
def test_two_gear_split_saves_energy(d, slack_frac):
    slack = d * slack_frac
    segs = two_gear_split(PROC, d, slack)
    e_split = plan_energy_j(PROC, segs)
    e_top = plan_energy_j(PROC, [(PROC.gears[0], d)])
    # active energy at reduced gears is never above running flat-out
    # (leakage*extra_time can offset on near-flat tables; allow tiny margin)
    assert e_split <= e_top * 1.005


def test_duration_at_beta():
    assert duration_at(1.0, 2.0, 1.0, beta=1.0) == pytest.approx(2.0)
    assert duration_at(1.0, 2.0, 1.0, beta=0.0) == pytest.approx(1.0)
    assert duration_at(1.0, 2.0, 1.0, beta=0.5) == pytest.approx(1.5)


# -------------------------------------------------------------- strategies
@pytest.mark.parametrize("name", ["cholesky", "lu", "qr"])
def test_strategy_ordering(name):
    g = build_dag(name, 10, 384, (2, 4))
    res = evaluate_strategies(g, PROC, COST)
    e = {k: v.energy_j for k, v in res.items()}
    # every saving strategy beats original
    assert e["race_to_halt"] < e["original"]
    assert e["cp_aware"] < e["original"]
    assert e["algorithmic"] < e["original"]
    # the paper's algorithmic plan is at least as good as the online one
    assert e["algorithmic"] <= e["cp_aware"] * 1.001
    # acceptable slowdowns (paper reports ~3.5-3.9%)
    for k in ("race_to_halt", "cp_aware", "algorithmic"):
        assert res[k].slowdown_pct < 6.0


def test_power_trace_levels():
    g = build_dag("cholesky", 12, 512, (4, 4))
    res = evaluate_strategies(g, PROC, COST)
    sched = res["original"].schedule
    ts = np.linspace(0, sched.makespan, 512)
    tr_orig = res["original"].schedule.power_trace(ts, nodes=[0])
    tr_rth = res["race_to_halt"].schedule.power_trace(ts, nodes=[0])
    # race-to-halt's minimum power dips below original's
    assert tr_rth.min() < tr_orig.min() - 1.0
    # peaks comparable (both compute at top gear)
    assert abs(tr_rth.max() - tr_orig.max()) / tr_orig.max() < 0.05
    # all traces above the nodal constant floor
    assert tr_rth.min() >= PROC.p_const_watts


# ------------------------------------------------------- energy accounting
def test_node_count_ceils_partial_nodes():
    """24 ranks at 16 cores/node are TWO nodes: the partially filled second
    node burns its full constant power and its ranks 16..23 stay in nodal
    accounting (floor division used to drop both)."""
    g = build_dag("cholesky", 8, 128, (4, 6))          # 24 ranks
    sched = simulate(g, PROC, COST, make_plan("original", g, PROC, COST))
    assert sched.n_nodes == 2
    covered = sorted(r for nd in range(sched.n_nodes)
                     for r in sched._node_ranks(nd))
    assert covered == list(range(24)), "every rank must belong to a node"
    assert sched.nodal_const_power_w() == pytest.approx(
        2 * PROC.p_const_watts)
    assert machine_nodal_const_power_w(PROC, 24) == pytest.approx(
        2 * PROC.p_const_watts)


def test_power_trace_before_first_segment_uses_top_gear():
    """Samples before a rank's first segment idle at the STARTING gear
    (index 0 -- both engines boot every rank there), not at whatever gear
    the final segment happens to end in."""
    g = build_dag("cholesky", 2, 128, (1, 1))
    top, low = PROC.gears[0], PROC.gears[-1]
    n = len(g.tasks)
    sched = Schedule.from_rank_segments(
        g, PROC, np.full(n, 1.0), np.full(n, 2.0),
        [[RankSegment(1.0, 2.0, low, True)]], 0, 0.0)
    w = sched.power_trace(np.array([0.5, 1.5, 2.5]), nodes=[0])
    const = PROC.p_const_watts
    assert w[0] == pytest.approx(const + PROC.core_power_w(top, False))
    assert w[1] == pytest.approx(const + PROC.core_power_w(low, True))
    assert w[2] == pytest.approx(const + PROC.core_power_w(low, False))


@given(name=st.sampled_from(["cholesky", "lu", "qr"]),
       n_tiles=st.integers(4, 7),
       grid=st.sampled_from([(1, 2), (2, 2), (2, 4)]))
@settings(max_examples=10, deadline=None)
def test_fleet_matches_oracle_exactly(name, n_tiles, grid):
    """Property form of the three-engine contract: every fleet lane is
    bit-identical in time and 1e-9-close in energy to the pick-loop
    oracle, across factorizations, sizes, and grids."""
    g = build_dag(name, n_tiles, 192, grid)
    plans = [make_plan(s, g, PROC, COST)
             for s in ("original", "race_to_halt", "cp_aware",
                       "algorithmic", "tx")]
    fleet = simulate_fleet(g, PROC, COST, plans)
    energies = fleet.total_energy_j()
    for i, plan in enumerate(plans):
        ref = simulate_reference(g, PROC, COST, plan)
        assert np.array_equal(fleet.start[i], ref.start)
        assert np.array_equal(fleet.finish[i], ref.finish)
        assert int(fleet.switch_count[i]) == ref.switch_count
        assert energies[i] == pytest.approx(ref.total_energy_j(), rel=1e-9)


def test_tpu_like_device_collapses_to_race_to_halt():
    """On a single-gear device, cp_aware == race-to-halt-style savings only
    (no ladder to reclaim with) -- the hardware-adaptation observation."""
    g = build_dag("cholesky", 8, 256, (2, 2))
    tpu = make_tpu_like()
    res = evaluate_strategies(g, tpu, COST,
                              cfg=StrategyConfig(cp_detect_overhead=0.0,
                                                 monitor_overhead=0.0))
    assert res["cp_aware"].energy_j == pytest.approx(
        res["algorithmic"].energy_j, rel=1e-6)
    # with one gear, reclamation can't slow anything down: energy ==
    # race-to-halt up to switch-accounting noise
    assert res["algorithmic"].energy_j == pytest.approx(
        res["race_to_halt"].energy_j, rel=0.02)


# ------------------------------------------------ comm-energy exactness
def _three_task_graph(tile=256):
    """3 tasks on 2 ranks with exactly ONE cross-rank dependency edge
    (t0@rank0 -> t2@rank1); t1 keeps rank 0 busy locally."""
    from repro.core import TaskGraph, Task
    tasks = [
        Task(tid=0, kind="GEMM", k=0, i=0, j=0, owner=0, flops=4e8,
             deps=[], out_tile=(0, 0)),
        Task(tid=1, kind="GEMM", k=0, i=0, j=1, owner=0, flops=2e8,
             deps=[0], out_tile=(0, 1)),
        Task(tid=2, kind="GEMM", k=0, i=1, j=0, owner=1, flops=3e8,
             deps=[0], out_tile=(1, 0)),
    ]
    return TaskGraph("three_task", n_tiles=2, tile_size=tile, grid=(1, 2),
                     tasks=tasks)


def _one_edge_link():
    from repro.core import LinkModel
    return LinkModel(name="pairwise",
                     pair_bandwidth_gbs=((8.0, 2.5), (1.25, 8.0)),
                     pair_energy_per_byte_j=((0.0, 3e-9), (7e-9, 0.0)),
                     latency_s=2e-6)


def test_comm_energy_exact_homogeneous():
    """Hand-computed wire cost of the single cross-rank edge, verified to
    float precision: the transfer delays t2 by exactly
    bytes/(bw[0,1]*1e9) + latency, and the schedule's comm energy is
    exactly e[0,1] * bytes."""
    from repro.core import CostModel, plan_comm_energy_j
    g = _three_task_graph()
    link = _one_edge_link()
    cost = CostModel(link=link)
    sched = simulate(g, PROC, cost, make_plan("original", g, PROC, cost))
    n_bytes = g.tile_bytes
    assert n_bytes == 256 * 256 * 8
    t_expected = n_bytes / (2.5 * 1e9) + 2e-6        # rank0 -> rank1
    e_expected = 3e-9 * n_bytes
    # t2 is rank 1's first task: it starts exactly at t0's finish + wire
    assert sched.start[2] == sched.finish[0] + t_expected
    # t1 is same-rank: no delay at all
    assert sched.start[1] == sched.finish[0]
    assert sched.comm_energy_j == e_expected
    assert plan_comm_energy_j(g, cost) == e_expected
    # the total is the trivial-link total plus exactly the wire energy
    # minus nothing else time-independent: re-simulating with zero link
    # energy (same bandwidths) differs by exactly e_expected
    from repro.core import LinkModel
    link0 = LinkModel(name="free", pair_bandwidth_gbs=((8.0, 2.5),
                                                       (1.25, 8.0)),
                      latency_s=2e-6)
    cost0 = CostModel(link=link0)
    s0 = simulate(g, PROC, cost0, make_plan("original", g, PROC, cost0))
    assert np.array_equal(s0.start, sched.start)
    assert sched.total_energy_j() == s0.total_energy_j() + e_expected


def test_comm_energy_exact_big_little():
    """Same hand computation on a big.LITTLE machine: the cross-rank edge
    lands on the LITTLE rank, whose slower top gear changes the durations
    but not the wire pricing."""
    from repro.core import CostModel, make_big_little, plan_comm_energy_j
    g = _three_task_graph(tile=128)
    machine = make_big_little(PROC)
    link = _one_edge_link()
    cost = CostModel(link=link)
    sched = simulate(g, machine, cost,
                     make_plan("original", g, machine, cost))
    n_bytes = 128 * 128 * 8
    t_expected = n_bytes / (2.5 * 1e9) + 2e-6
    e_expected = 3e-9 * n_bytes
    assert sched.start[2] == sched.finish[0] + t_expected
    assert sched.comm_energy_j == e_expected
    assert plan_comm_energy_j(g, cost) == e_expected
    # exact three-engine agreement on the hand-checkable cell
    ref = simulate_reference(g, machine, cost,
                             make_plan("original", g, machine, cost))
    assert np.array_equal(sched.start, ref.start)
    assert sched.comm_energy_j == ref.comm_energy_j


def test_comm_energy_follows_migrated_mapping():
    """Wire energy is charged under the EFFECTIVE mapping: migrating t2
    onto rank 0 removes the only cross-rank edge; migrating t1 onto rank
    1 creates one priced at the same pair rate."""
    import dataclasses
    from repro.core import CostModel, plan_comm_energy_j
    g = _three_task_graph()
    cost = CostModel(link=_one_edge_link())
    n_bytes = g.tile_bytes
    plan = make_plan("original", g, PROC, cost)
    all0 = simulate(g, PROC, cost,
                    dataclasses.replace(plan, task_owners=[0, 0, 0]))
    assert all0.comm_energy_j == 0.0
    swapped = simulate(g, PROC, cost,
                       dataclasses.replace(plan, task_owners=[0, 1, 0]))
    assert swapped.comm_energy_j == 3e-9 * n_bytes
    assert plan_comm_energy_j(g, cost, [0, 1, 0]) == 3e-9 * n_bytes


def test_comm_low_annotation_is_model_derived():
    """benchmarks/power_trace.py's comm-low annotation comes from
    comm_low_power_w + LinkModel.transfer_power_w, not a hardcoded
    calibration constant: the level is exactly
    n_nodes * (halt-gear idle node power + in-flight wire power)."""
    import importlib.util
    import os
    from repro.core import LinkModel, comm_low_power_w
    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "benchmarks", "power_trace.py")
    spec = importlib.util.spec_from_file_location("power_trace_bench", path)
    pt = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(pt)
    cost = CostModel(link=pt.LINK)
    halt = PROC.gears[-1]
    # the benchmark's link: 2 nJ/byte at the 5 GB/s default = 10 W wire
    wire = pt.LINK.transfer_power_w(0, 1, cost.comm_bandwidth_gbs)
    assert wire == pytest.approx(2e-9 * cost.comm_bandwidth_gbs * 1e9)
    assert pt.comm_low_level_w(PROC, cost) == pytest.approx(
        3 * (PROC.node_power_w(halt, active=False) + wire))
    # a trivial link has zero wire power: the annotation collapses to the
    # pure halt-gear idle floor of the three metered nodes
    assert LinkModel().transfer_power_w(0, 1, cost.comm_bandwidth_gbs) == 0.0
    assert pt.comm_low_level_w(PROC, CostModel()) == pytest.approx(
        comm_low_power_w(PROC, 3))
    # the annotated metric is what bench() reports
    assert pt.LINK.pair_bandwidth_gbs is None, \
        "annotation link must not perturb transfer times"
