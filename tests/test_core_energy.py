"""Unit + property tests for the energy-saving core (the paper's contribution)."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import (CostModel, GEAR_TABLES, StrategyConfig, build_dag,
                        cp_analysis, duration_at, evaluate_strategies,
                        factorization_flops, machine_nodal_const_power_w,
                        make_plan, make_processor, make_tpu_like,
                        max_slack_ratio, plan_energy_j, schedule_slack,
                        simulate, simulate_fleet, simulate_reference,
                        strategy_gap_terms, two_gear_split,
                        verify_worked_example)
from repro.core.scheduler import RankSegment, Schedule

PROC = make_processor("arc_opteron_6128")
COST = CostModel()


# ---------------------------------------------------------------- DAG layer
@pytest.mark.parametrize("name", ["cholesky", "lu", "qr"])
def test_dag_topological_and_flops(name):
    g = build_dag(name, 8, 256, (2, 2))
    for t in g.tasks:
        assert all(d < t.tid for d in t.deps), "tasks must be emitted topo-sorted"
    # tiled flop count matches the analytic factorization count to leading order
    n = 8 * 256
    analytic = factorization_flops(name, n)
    ratio = g.total_flops() / analytic
    assert 0.8 < ratio < 2.6, ratio  # QR tile algorithms carry ~2x overhead


def test_block_cyclic_owner_coverage():
    g = build_dag("cholesky", 12, 128, (3, 4))
    owners = {t.owner for t in g.tasks}
    assert owners == set(range(12))   # all ranks get work


@pytest.mark.parametrize("name", ["cholesky", "lu", "qr"])
def test_critical_path_lower_bounds_makespan(name):
    g = build_dag(name, 6, 256, (2, 2))
    durs = np.array([COST.duration_top(t.flops, t.kind, PROC) for t in g.tasks])
    cp = cp_analysis(g, durs, COST.comm_time(g))
    sched = simulate(g, PROC, COST, make_plan("original", g, PROC, COST))
    assert cp.cp_length <= sched.makespan + 1e-12
    assert np.all(cp.total_float >= -1e-12)
    assert cp.on_cp.any()


def test_schedule_slack_nonnegative_and_safe():
    g = build_dag("cholesky", 8, 256, (2, 2))
    sched = simulate(g, PROC, COST, make_plan("original", g, PROC, COST))
    slack = schedule_slack(sched.start, sched.finish, g, COST.comm_time(g))
    assert np.all(slack >= 0.0)
    # stretching every task into its local slack must not delay the makespan
    res = evaluate_strategies(g, PROC, COST,
                              names=("original", "algorithmic"),
                              cfg=StrategyConfig(cp_detect_overhead=0.0,
                                                 monitor_overhead=0.0))
    assert res["algorithmic"].makespan_s <= res["original"].makespan_s * 1.02


# ------------------------------------------------------------- energy model
def test_worked_example_matches_paper_text():
    out = verify_worked_example()
    assert out["dEd"] == pytest.approx(-0.8785, abs=1e-4)
    assert out["dEl"] == pytest.approx(-0.0875, abs=1e-4)


@pytest.mark.parametrize("table", sorted(GEAR_TABLES))
def test_gear_tables_monotonic(table):
    proc = make_processor(table)
    freqs = [g.freq_ghz for g in proc.gears]
    volts = [g.voltage for g in proc.gears]
    assert freqs == sorted(freqs, reverse=True)
    assert volts == sorted(volts, reverse=True)
    # power is monotone in gear (highest gear draws the most)
    pw = [proc.core_power_w(g, True) for g in proc.gears]
    assert pw == sorted(pw, reverse=True)


@pytest.mark.parametrize("table", sorted(GEAR_TABLES))
def test_strategy_gap_shrinks_when_voltage_flat(table):
    """The paper's observation: dEd at moderate n is small when V barely
    scales with f (modern tables)."""
    proc = make_processor(table)
    n = min(1.25, max_slack_ratio(proc))
    d_ed, d_el = strategy_gap_terms(proc, n)
    assert d_ed <= 1e-9  # CP-aware never loses on dynamic energy
    v_h, v_l = proc.gears[0].voltage, proc.gears[-1].voltage
    rel_v_span = (v_h - v_l) / v_h
    # the gap per unit ACT is bounded by something proportional to V span
    assert abs(d_ed) <= 3.0 * proc.gears[0].freq_ghz * v_h**2


# ------------------------------------------------------------------- DVFS
@given(d=st.floats(1e-4, 10.0), slack_frac=st.floats(0.0, 3.0))
@settings(max_examples=200, deadline=None)
def test_two_gear_split_work_and_time(d, slack_frac):
    slack = d * slack_frac
    segs = two_gear_split(PROC, d, slack)
    total_t = sum(t for _, t in segs)
    # work conservation: sum f*t == f_h*d (beta=1)
    work = sum(g.freq_ghz * t for g, t in segs)
    assert work == pytest.approx(PROC.f_max * d, rel=1e-9)
    # never exceeds the slack window
    assert total_t <= d + slack + 1e-12


@given(d=st.floats(1e-4, 10.0), slack_frac=st.floats(0.05, 3.0))
@settings(max_examples=200, deadline=None)
def test_two_gear_split_saves_energy(d, slack_frac):
    slack = d * slack_frac
    segs = two_gear_split(PROC, d, slack)
    e_split = plan_energy_j(PROC, segs)
    e_top = plan_energy_j(PROC, [(PROC.gears[0], d)])
    # active energy at reduced gears is never above running flat-out
    # (leakage*extra_time can offset on near-flat tables; allow tiny margin)
    assert e_split <= e_top * 1.005


def test_duration_at_beta():
    assert duration_at(1.0, 2.0, 1.0, beta=1.0) == pytest.approx(2.0)
    assert duration_at(1.0, 2.0, 1.0, beta=0.0) == pytest.approx(1.0)
    assert duration_at(1.0, 2.0, 1.0, beta=0.5) == pytest.approx(1.5)


# -------------------------------------------------------------- strategies
@pytest.mark.parametrize("name", ["cholesky", "lu", "qr"])
def test_strategy_ordering(name):
    g = build_dag(name, 10, 384, (2, 4))
    res = evaluate_strategies(g, PROC, COST)
    e = {k: v.energy_j for k, v in res.items()}
    # every saving strategy beats original
    assert e["race_to_halt"] < e["original"]
    assert e["cp_aware"] < e["original"]
    assert e["algorithmic"] < e["original"]
    # the paper's algorithmic plan is at least as good as the online one
    assert e["algorithmic"] <= e["cp_aware"] * 1.001
    # acceptable slowdowns (paper reports ~3.5-3.9%)
    for k in ("race_to_halt", "cp_aware", "algorithmic"):
        assert res[k].slowdown_pct < 6.0


def test_power_trace_levels():
    g = build_dag("cholesky", 12, 512, (4, 4))
    res = evaluate_strategies(g, PROC, COST)
    sched = res["original"].schedule
    ts = np.linspace(0, sched.makespan, 512)
    tr_orig = res["original"].schedule.power_trace(ts, nodes=[0])
    tr_rth = res["race_to_halt"].schedule.power_trace(ts, nodes=[0])
    # race-to-halt's minimum power dips below original's
    assert tr_rth.min() < tr_orig.min() - 1.0
    # peaks comparable (both compute at top gear)
    assert abs(tr_rth.max() - tr_orig.max()) / tr_orig.max() < 0.05
    # all traces above the nodal constant floor
    assert tr_rth.min() >= PROC.p_const_watts


# ------------------------------------------------------- energy accounting
def test_node_count_ceils_partial_nodes():
    """24 ranks at 16 cores/node are TWO nodes: the partially filled second
    node burns its full constant power and its ranks 16..23 stay in nodal
    accounting (floor division used to drop both)."""
    g = build_dag("cholesky", 8, 128, (4, 6))          # 24 ranks
    sched = simulate(g, PROC, COST, make_plan("original", g, PROC, COST))
    assert sched.n_nodes == 2
    covered = sorted(r for nd in range(sched.n_nodes)
                     for r in sched._node_ranks(nd))
    assert covered == list(range(24)), "every rank must belong to a node"
    assert sched.nodal_const_power_w() == pytest.approx(
        2 * PROC.p_const_watts)
    assert machine_nodal_const_power_w(PROC, 24) == pytest.approx(
        2 * PROC.p_const_watts)


def test_power_trace_before_first_segment_uses_top_gear():
    """Samples before a rank's first segment idle at the STARTING gear
    (index 0 -- both engines boot every rank there), not at whatever gear
    the final segment happens to end in."""
    g = build_dag("cholesky", 2, 128, (1, 1))
    top, low = PROC.gears[0], PROC.gears[-1]
    n = len(g.tasks)
    sched = Schedule.from_rank_segments(
        g, PROC, np.full(n, 1.0), np.full(n, 2.0),
        [[RankSegment(1.0, 2.0, low, True)]], 0, 0.0)
    w = sched.power_trace(np.array([0.5, 1.5, 2.5]), nodes=[0])
    const = PROC.p_const_watts
    assert w[0] == pytest.approx(const + PROC.core_power_w(top, False))
    assert w[1] == pytest.approx(const + PROC.core_power_w(low, True))
    assert w[2] == pytest.approx(const + PROC.core_power_w(low, False))


@given(name=st.sampled_from(["cholesky", "lu", "qr"]),
       n_tiles=st.integers(4, 7),
       grid=st.sampled_from([(1, 2), (2, 2), (2, 4)]))
@settings(max_examples=10, deadline=None)
def test_fleet_matches_oracle_exactly(name, n_tiles, grid):
    """Property form of the three-engine contract: every fleet lane is
    bit-identical in time and 1e-9-close in energy to the pick-loop
    oracle, across factorizations, sizes, and grids."""
    g = build_dag(name, n_tiles, 192, grid)
    plans = [make_plan(s, g, PROC, COST)
             for s in ("original", "race_to_halt", "cp_aware",
                       "algorithmic", "tx")]
    fleet = simulate_fleet(g, PROC, COST, plans)
    energies = fleet.total_energy_j()
    for i, plan in enumerate(plans):
        ref = simulate_reference(g, PROC, COST, plan)
        assert np.array_equal(fleet.start[i], ref.start)
        assert np.array_equal(fleet.finish[i], ref.finish)
        assert int(fleet.switch_count[i]) == ref.switch_count
        assert energies[i] == pytest.approx(ref.total_energy_j(), rel=1e-9)


def test_tpu_like_device_collapses_to_race_to_halt():
    """On a single-gear device, cp_aware == race-to-halt-style savings only
    (no ladder to reclaim with) -- the hardware-adaptation observation."""
    g = build_dag("cholesky", 8, 256, (2, 2))
    tpu = make_tpu_like()
    res = evaluate_strategies(g, tpu, COST,
                              cfg=StrategyConfig(cp_detect_overhead=0.0,
                                                 monitor_overhead=0.0))
    assert res["cp_aware"].energy_j == pytest.approx(
        res["algorithmic"].energy_j, rel=1e-6)
    # with one gear, reclamation can't slow anything down: energy ==
    # race-to-halt up to switch-accounting noise
    assert res["algorithmic"].energy_j == pytest.approx(
        res["race_to_halt"].energy_j, rel=0.02)
