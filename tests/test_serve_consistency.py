"""Serving-path correctness: prefill + decode caches must reproduce the
teacher-forcing forward exactly (same logits at every position)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, make_smoke
from repro.models import get_model, lm

FAMILIES = ["qwen2.5-3b", "gemma2-2b", "recurrentgemma-2b", "mamba2-370m"]


@pytest.mark.parametrize("arch", FAMILIES)
def test_decode_matches_teacher_forcing(arch):
    cfg = make_smoke(get_config(arch))
    api = get_model(cfg)
    params = api.param_tree("init", jax.random.key(0))
    b, s_p, s_total = 2, 8, 14
    tokens = jax.random.randint(jax.random.key(1), (b, s_total), 0,
                                cfg.vocab_size)

    # teacher forcing over the full sequence
    h, _ = lm.hidden_states(params, tokens, cfg)
    full_logits = lm.logits_from_hidden(params, h, cfg)   # [B, S, V]

    # prefill on the prefix, then decode token by token
    cache = api.init_cache(b, s_total, "init")
    logits_p, cache = api.prefill(params, {"tokens": tokens[:, :s_p]}, cache)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(full_logits[:, s_p - 1]),
                               rtol=2e-2, atol=2e-3)
    for pos in range(s_p, s_total):
        logits_d, cache = api.decode_step(
            params, tokens[:, pos:pos + 1], cache,
            jnp.asarray(pos, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(full_logits[:, pos]),
            rtol=2e-2, atol=2e-3,
            err_msg=f"{arch}: decode diverges at pos {pos}")


def test_whisper_decode_matches_teacher_forcing():
    from repro.models import encdec
    cfg = make_smoke(get_config("whisper-small"))
    api = get_model(cfg)
    params = api.param_tree("init", jax.random.key(0))
    b, s_p, s_total = 2, 4, 8
    tokens = jax.random.randint(jax.random.key(1), (b, s_total), 0,
                                cfg.vocab_size)
    audio = jax.random.normal(jax.random.key(2),
                              (b, cfg.frontend_len, cfg.d_model))
    h, _ = encdec.hidden_states(params, tokens, audio, cfg)
    full = jnp.einsum("bsd,vd->bsv", h, params["embed"])
    cache = api.init_cache(b, s_total, "init")
    logits_p, cache = api.prefill(
        params, {"tokens": tokens[:, :s_p], "audio_embeds": audio}, cache)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(full[:, s_p - 1]),
                               rtol=2e-2, atol=2e-3)
    for pos in range(s_p, s_total):
        logits_d, cache = api.decode_step(
            params, tokens[:, pos:pos + 1], cache,
            jnp.asarray(pos, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(full[:, pos]),
            rtol=2e-2, atol=2e-3)


def test_generate_rejects_undersized_cache():
    """`generate` with max_len < prompt + n_new must raise up front instead
    of silently wrapping (ring KV) or dropping (linear KV) late positions."""
    from repro.serve.engine import generate
    cfg = make_smoke(get_config("qwen2.5-3b"))
    api = get_model(cfg)
    params = api.param_tree("init", jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (1, 8), 0,
                                cfg.vocab_size)
    with pytest.raises(ValueError, match="exceed max_len"):
        generate(api, params, {"tokens": tokens}, n_new=8, max_len=10)
    # boundary: an exactly-sized cache is fine
    out = generate(api, params, {"tokens": tokens}, n_new=2, max_len=10)
    assert out.tokens.shape == (1, 2)
