"""Serving-layer correctness: trace generation, the wave compiler, the
hand-computed SLO arithmetic, and the three-engine differential on
serving-class TaskGraphs.

The load-bearing pins:

  * seeded determinism   -- (shape, seed) fully determines a trace;
  * rate conservation    -- every traffic shape is mean-normalized, so
                            equal `rate_rps` means equal offered load;
  * SLO exactness        -- a 3-request trace on one unit-rate server is
                            worked out by hand (every prefill/decode
                            start and finish) and the simulator must
                            reproduce the latencies to float precision;
  * engine differential  -- every registered strategy's plan on a
                            serving graph must agree bit-identically
                            across simulate / simulate_reference /
                            simulate_fleet (the clock-rank construction
                            must not break the three-engine contract);
  * SLO cap plumbing     -- `slo_latency_s` tightens (never loosens) the
                            makespan cap used by single_freq_opt and
                            plan_search, and is a no-op when unset.
"""

import dataclasses
import math

import numpy as np
import pytest

from repro.core import (CostModel, Gear, MachineModel, PlanContext,
                        ProcessorModel, StrategyConfig, build_serving_graph,
                        get_strategy, make_server_proc, make_trace,
                        p99_latency_s, registered_strategies,
                        request_latencies, scale_processor, serving_cost_model,
                        serving_machine, simulate, simulate_fleet,
                        simulate_reference, slo_violation_rate,
                        traffic_rate_curve)
from repro.core.serving import MODEL_PROFILES, TRAFFIC_SHAPES, ServingTrace

ALL_STRATEGIES = registered_strategies()


# ------------------------------------------------------- traffic generation
def test_trace_seeded_determinism():
    for shape in TRAFFIC_SHAPES:
        a = make_trace(shape, rate_rps=12.0, duration_s=10.0, seed=7)
        b = make_trace(shape, rate_rps=12.0, duration_s=10.0, seed=7)
        np.testing.assert_array_equal(a.arrival_s, b.arrival_s)
        np.testing.assert_array_equal(a.prompt_tokens, b.prompt_tokens)
        np.testing.assert_array_equal(a.decode_tokens, b.decode_tokens)
    # different seeds diverge, and shapes diverge even at equal seeds
    a = make_trace("diurnal", rate_rps=12.0, duration_s=10.0, seed=7)
    c = make_trace("diurnal", rate_rps=12.0, duration_s=10.0, seed=8)
    d = make_trace("flat", rate_rps=12.0, duration_s=10.0, seed=7)
    assert not (a.n_requests == c.n_requests
                and np.array_equal(a.arrival_s, c.arrival_s))
    assert not (a.n_requests == d.n_requests
                and np.array_equal(a.arrival_s, d.arrival_s))


def test_trace_basic_invariants():
    for shape in TRAFFIC_SHAPES:
        tr = make_trace(shape, rate_rps=9.0, duration_s=12.0, seed=3)
        assert np.all(np.diff(tr.arrival_s) >= 0)           # sorted
        assert np.all(tr.arrival_s >= 0)
        assert np.all(tr.arrival_s < tr.duration_s)
        assert np.all(tr.decode_tokens >= 1)
        assert tr.total_decode_tokens == int(tr.decode_tokens.sum())
    with pytest.raises(ValueError, match="decode_tokens"):
        make_trace("flat", decode_tokens=(0, 4))
    with pytest.raises(ValueError, match="unknown traffic shape"):
        make_trace("square")


def test_rate_curves_are_mean_normalized():
    """Every shape's modulation averages to 1.0 over the horizon, so equal
    rate_rps means equal offered load regardless of shape."""
    duration = 30.0
    # midpoint grid; 3600 divides the bursty square wave's burst windows
    # exactly, so every shape's midpoint mean is analytically 1.0
    t = (np.arange(3600) + 0.5) / 3600 * duration
    for shape in TRAFFIC_SHAPES:
        curve = traffic_rate_curve(shape, t, duration)
        assert np.all(curve >= 0)
        assert abs(float(curve.mean()) - 1.0) < 1e-9, shape
    with pytest.raises(ValueError, match="unknown traffic shape"):
        traffic_rate_curve("square", t, duration)


def test_arrival_rate_conservation_across_shapes():
    """rate_rps * duration_s requests on average, for every shape (the
    law-of-large-numbers check behind cross-shape J/token comparisons)."""
    rate, duration = 20.0, 50.0
    for shape in TRAFFIC_SHAPES:
        counts = [make_trace(shape, rate_rps=rate, duration_s=duration,
                             seed=s).n_requests for s in range(4)]
        mean = float(np.mean(counts))
        assert abs(mean - rate * duration) < 0.10 * rate * duration, \
            (shape, mean)


# --------------------------------------------------- hand-computed SLO case
def _unit_cell():
    """One unit-rate server (1 Gflop/s at every kind), period 0.5 s,
    4 decode tokens per wave; comm is exactly free."""
    cost = CostModel(
        flops_per_cycle=1.0,
        kind_efficiency={"PREFILL": 1.0, "DECODE": 1.0, "CLOCK": 1.0},
        freq_sensitivity={"PREFILL": 1.0, "DECODE": 0.25, "CLOCK": 0.0},
        comm_bandwidth_gbs=math.inf, comm_latency_s=0.0)
    server = ProcessorModel(name="unit", gears=(Gear(0, 1.0, 1.0),),
                            n_cores=1, p_const_watts=0.0)
    profile = MODEL_PROFILES["dense"].__class__(
        name="unit", arch="dense",
        prefill_flops_per_token=1e8, decode_flops_per_token=5e7,
        decode_beta=0.25)
    trace = ServingTrace(
        shape="flat", seed=0, rate_rps=1.0, duration_s=2.0,
        arrival_s=np.array([0.2, 0.3, 1.4]),
        prompt_tokens=np.array([1, 2, 1]),
        decode_tokens=np.array([4, 8, 4]))
    sg = build_serving_graph(trace, n_servers=1, step_period_s=0.5,
                             cost=cost, profile=profile, tokens_per_wave=4)
    machine = serving_machine(server, 1)
    return sg, machine, cost


def test_slo_exactness_hand_computed():
    """3 requests, 1 server at 1 Gflop/s, period 0.5, 4 tok/wave.

    Wave 1 (tick 0.5) admits r0 (1 prompt tok -> 0.1 s) and r1 (2 -> 0.2 s):
    prefills run 0.5-0.6 and 0.6-0.8; the fused decode covers
    min(4,4) + min(4,8) = 8 tokens -> 0.4 s, runs 0.8-1.2 and finishes r0.
    Wave 2 (tick 1.0, server busy until 1.2) decodes r1's last 4 tokens
    1.2-1.4. Wave 3 (tick 1.5) admits r2: prefill 1.5-1.6, decode 1.6-1.8.
    Latencies: [1.2-0.2, 1.4-0.3, 1.8-1.4] = [1.0, 1.1, 0.4].
    """
    sg, machine, cost = _unit_cell()
    assert sg.n_waves == 3
    assert abs(sg.horizon_s - 1.5) < 1e-12
    ctx = PlanContext(sg.graph, machine, cost, StrategyConfig())
    sched = simulate(sg.graph, machine, cost,
                     get_strategy("original").plan(ctx))
    assert abs(sched.makespan - 1.8) < 1e-12
    lat = request_latencies(sg, sched.finish)
    np.testing.assert_allclose(lat, [1.0, 1.1, 0.4], rtol=1e-12)
    # metric helpers, against numpy ground truth / hand counts
    assert float(p99_latency_s(lat)) == np.percentile(lat, 99.0)
    np.testing.assert_allclose(float(p99_latency_s(lat)),
                               np.percentile([1.0, 1.1, 0.4], 99.0),
                               rtol=1e-12)
    assert float(slo_violation_rate(lat, 1.05)) == pytest.approx(1.0 / 3.0)
    assert float(slo_violation_rate(lat, 2.0)) == 0.0
    assert float(slo_violation_rate(lat, 0.3)) == 1.0
    # batched finish times broadcast: a (B, T) fleet gives (B, R) latencies
    fleet = simulate_fleet(sg.graph, machine, cost,
                           [get_strategy("original").plan(ctx)] * 2,
                           cores_per_node=1)
    lat2 = request_latencies(sg, fleet.finish)
    assert lat2.shape == (2, 3)
    np.testing.assert_array_equal(lat2[0], lat)


def test_empty_metrics_do_not_crash():
    empty = np.zeros((0,))
    assert float(p99_latency_s(empty)) == 0.0
    assert float(slo_violation_rate(empty, 1.0)) == 0.0


# ----------------------------------------------------------- wave compiler
def _small_cell(n_servers=2, shape="bursty", family="dense", servers=None):
    profile = MODEL_PROFILES[family]
    cost = serving_cost_model(profile)
    trace = make_trace(shape, rate_rps=6.0, duration_s=6.0, seed=3)
    sg = build_serving_graph(trace, n_servers=n_servers, step_period_s=0.25,
                             cost=cost, profile=profile)
    machine = serving_machine(servers or make_server_proc(), n_servers)
    return sg, machine, cost


def test_serving_graph_invariants():
    sg, machine, cost = _small_cell()
    tasks = sg.graph.tasks
    # topological tid order and per-rank program order (the simulate_fleet
    # layout contract), wave recorded in t.k
    per_rank_last = {}
    for t in tasks:
        assert all(d < t.tid for d in t.deps), t
        assert per_rank_last.get(t.owner, -1) < t.tid
        per_rank_last[t.owner] = t.tid
    clock = [t for t in tasks if t.kind == "CLOCK"]
    assert [t.k for t in clock] == list(range(1, sg.n_waves + 1))
    assert all(t.owner == sg.n_servers for t in clock)
    assert all(tasks[i].kind == "DECODE" for i in sg.done_tid)
    assert np.all(sg.done_tid >= 0)
    # every admitted request's arrival precedes its admission tick
    np.testing.assert_array_less(sg.trace.arrival_s,
                                 sg.admit_wave * sg.step_period_s + 1e-9)

    ctx = PlanContext(sg.graph, machine, cost, StrategyConfig())
    for name in ("original", "race_to_halt", "tx"):
        sched = simulate(sg.graph, machine, cost,
                         get_strategy(name).plan(ctx))
        # CLOCK durations are gear-invariant (beta 0): exactly one period
        # under every plan, however the gears are set
        for t in clock:
            assert sched.finish[t.tid] - sched.start[t.tid] \
                == pytest.approx(sg.step_period_s, rel=1e-12), (name, t.k)
            # ...and the chain never runs ahead of the wall clock (plans
            # with per-task overheads, e.g. race_to_halt's monitoring tax,
            # may tick late -- never early)
            assert sched.finish[t.tid] >= t.k * sg.step_period_s - 1e-12
        # no server task starts before its wave tick
        for t in tasks:
            if t.kind != "CLOCK":
                tick = sched.finish[clock[t.k - 1].tid]
                assert sched.start[t.tid] >= tick - 1e-9, (name, t.tid)
    # overhead-free plans tick at exactly w * period
    sched = simulate(sg.graph, machine, cost,
                     get_strategy("original").plan(ctx))
    for t in clock:
        assert sched.finish[t.tid] == pytest.approx(
            t.k * sg.step_period_s, rel=1e-12), t.k


def test_build_rejects_nonzero_clock_beta():
    profile = MODEL_PROFILES["dense"]
    bad = serving_cost_model(profile)
    bad.freq_sensitivity["CLOCK"] = 1.0
    with pytest.raises(ValueError, match="CLOCK"):
        build_serving_graph(make_trace("flat", duration_s=2.0), n_servers=2,
                            step_period_s=0.25, cost=bad, profile=profile)


# ------------------------------------------------- three-engine differential
def _bl_servers():
    big = make_server_proc()
    little = scale_processor(big, big.name + "_little", freq_scale=0.6,
                             volt_scale=0.85, cap_scale=0.45, leak_scale=0.6)
    return MachineModel(name="serve_bl_pattern", procs=(big, little))


@pytest.mark.parametrize("machine_kind", ["homog", "big_little"])
def test_three_engine_differential_on_serving_graphs(machine_kind):
    """Every registered strategy, both engines vs the oracle, plus one
    batched fleet pass -- on a serving graph with its clock rank. Any
    engine-visible semantic the serving layer relies on (beta-0 kinds,
    zero-power single-gear ranks, per-rank program order from the wave
    compiler) must hold identically in all three engines."""
    servers = None if machine_kind == "homog" else _bl_servers()
    sg, machine, cost = _small_cell(servers=servers)
    cfg = StrategyConfig(plan_search_rounds=1, plan_search_lanes=16,
                         replan_every=8,
                         slo_latency_s=sg.horizon_s + 2.0)
    ctx = PlanContext(sg.graph, machine, cost, cfg)
    plans = [get_strategy(n).plan(ctx) for n in ALL_STRATEGIES]
    refs = []
    for name, plan in zip(ALL_STRATEGIES, plans):
        ref = simulate_reference(sg.graph, machine, cost, plan)
        fast = simulate(sg.graph, machine, cost, plan)
        np.testing.assert_array_equal(fast.start, ref.start, err_msg=name)
        np.testing.assert_array_equal(fast.finish, ref.finish, err_msg=name)
        assert fast.switch_count == ref.switch_count, name
        assert fast.total_energy_j() == pytest.approx(
            ref.total_energy_j(), rel=1e-9), name
        refs.append(ref)
    fleet = simulate_fleet(sg.graph, machine, cost, plans, cores_per_node=1)
    for i, (name, ref) in enumerate(zip(ALL_STRATEGIES, refs)):
        np.testing.assert_array_equal(fleet.start[i], ref.start,
                                      err_msg=name)
        np.testing.assert_array_equal(fleet.finish[i], ref.finish,
                                      err_msg=name)
        assert int(fleet.switch_count[i]) == ref.switch_count, name
        # energy at the serving node granularity (one rank per node)
        ref1 = dataclasses.replace(ref, cores_per_node=1)
        assert float(fleet.total_energy_j()[i]) == pytest.approx(
            ref1.total_energy_j(), rel=1e-9), name


# ------------------------------------------------------- SLO cap plumbing
def test_makespan_cap_slo_semantics():
    sg, machine, cost = _small_cell()
    base_ctx = PlanContext(sg.graph, machine, cost, StrategyConfig())
    base = base_ctx.baseline.makespan
    # unset SLO: bit-identical to the pre-SLO expression
    assert base_ctx.makespan_cap(0.25) == base * 1.25
    # a loose SLO changes nothing; a tight one tightens
    loose = PlanContext(sg.graph, machine, cost,
                        StrategyConfig(slo_latency_s=base * 10))
    assert loose.makespan_cap(0.25) == base * 1.25
    tight = PlanContext(sg.graph, machine, cost,
                        StrategyConfig(slo_latency_s=base * 1.1))
    assert tight.makespan_cap(0.25) == pytest.approx(base * 1.1, rel=1e-12)
    # an over-tight SLO clamps at the baseline (top gear stays feasible)
    impossible = PlanContext(sg.graph, machine, cost,
                             StrategyConfig(slo_latency_s=base * 0.5))
    assert impossible.makespan_cap(0.25) == base


@pytest.mark.parametrize("name", ["single_freq_opt", "plan_search"])
def test_cap_honoring_planners_respect_slo(name):
    """With slo_latency_s == baseline makespan, the cap-honoring planners
    may not stretch the schedule at all."""
    sg, machine, cost = _small_cell()
    base = PlanContext(sg.graph, machine, cost,
                       StrategyConfig()).baseline.makespan
    cfg = StrategyConfig(plan_search_rounds=1, plan_search_lanes=16,
                         slo_latency_s=base)
    ctx = PlanContext(sg.graph, machine, cost, cfg)
    sched = simulate(sg.graph, machine, cost, get_strategy(name).plan(ctx))
    assert sched.makespan <= base * (1 + 1e-9), name
