"""Sequence-parallel collectives: numerical equivalence of the manual
shard_map paths (column_parallel_ag / row_parallel_rs / sp_gather_seq)
against the plain einsum reference, values AND gradients, on a real
multi-device mesh (subprocess with 8 host devices)."""

import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_BODY = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from repro.sharding.rules import (use_sharding, sp_gather_seq,
                                  row_parallel_rs, column_parallel_ag)

mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = {"res_seq": "model", "act_ff": "model", "heads": "model",
         "batch": ("data",), "seq": None, "embed": None}
b, s, d, f = 4, 16, 8, 32
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
w1 = jnp.asarray(rng.standard_normal((d, f)), jnp.float32)
w3 = jnp.asarray(rng.standard_normal((d, f)), jnp.float32)
w2 = jnp.asarray(rng.standard_normal((f, d)), jnp.float32)

def f_sp(x, w1, w3, w2):
    h1, h3 = column_parallel_ag(x, [w1, w3], ["bsd,df->bsf"] * 2, "act_ff")
    h = jnp.tanh(h1) * h3
    y = row_parallel_rs(h, w2, "bsf,fd->bsd", "act_ff")
    return (y ** 2).sum()

def f_ref(x, w1, w3, w2):
    h = jnp.tanh(x @ w1) * (x @ w3)
    return ((h @ w2) ** 2).sum()

with use_sharding(mesh, rules):
    v_sp, g_sp = jax.jit(jax.value_and_grad(f_sp, argnums=(0, 1, 2, 3)))(
        x, w1, w3, w2)
v_rf, g_rf = jax.jit(jax.value_and_grad(f_ref, argnums=(0, 1, 2, 3)))(
    x, w1, w3, w2)
assert abs(float(v_sp) - float(v_rf)) / abs(float(v_rf)) < 1e-5
for a, b_, name in zip(g_sp, g_rf, "x w1 w3 w2".split()):
    err = np.abs(np.asarray(a) - np.asarray(b_)).max()
    scale = np.abs(np.asarray(b_)).max()
    assert err < 1e-4 * max(scale, 1.0), (name, err, scale)

# gather path alone
with use_sharding(mesh, rules):
    xg = jax.jit(sp_gather_seq)(x)
np.testing.assert_allclose(np.asarray(xg), np.asarray(x), atol=1e-6)

# the compiled SP module must contain a true reduce-scatter, no big AR
with use_sharding(mesh, rules):
    txt = jax.jit(jax.value_and_grad(f_sp, argnums=(0,))) \
        .lower(x, w1, w3, w2).compile().as_text()
assert txt.count("reduce-scatter") >= 1, "expected explicit reduce-scatter"
print("ALL OK")
"""


@pytest.mark.slow
def test_sp_paths_match_reference_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _BODY],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "ALL OK" in res.stdout


def test_sp_fallback_without_ctx():
    """No sharding ctx (CPU smoke path): SP helpers are plain einsums."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.sharding.rules import (column_parallel_ag, row_parallel_rs,
                                      sp_gather_seq)
    x = jnp.ones((2, 4, 8))
    w = jnp.ones((8, 16))
    (h,) = column_parallel_ag(x, [w], ["bsd,df->bsf"], "act_ff")
    np.testing.assert_allclose(np.asarray(h), 8.0)
    y = row_parallel_rs(h, jnp.ones((16, 8)), "bsf,fd->bsd", "act_ff")
    np.testing.assert_allclose(np.asarray(y), 128.0)
    np.testing.assert_allclose(np.asarray(sp_gather_seq(x)), 1.0)
