"""Heterogeneous (per-rank processor) machine model tests: MachineModel
semantics, the homogeneous no-op guarantee, per-rank energy accounting,
and heterogeneity-aware strategy policy.

Three layers:

  * MachineModel unit tests -- rank cycling, homogeneity detection, the
    canned asymmetric machines (`make_big_little`, `make_tpu_mixed`).
  * Homogeneous equivalence -- `MachineModel.homogeneous(proc)` must
    reproduce the bare-ProcessorModel path bit-identically: all four
    legacy strategies re-pinned against tests/data/strategy_golden.json
    through the machine wrapper, plus full segment-column identity.
  * Per-rank accounting + policy -- hand-computed mixed-rank energies,
    per-rank power traces, owner-ladder gear confinement, per-rank
    durations, and the per-rank-uniform single_freq_opt sweep.

Engine agreement on mixed machines is covered by the differential suite
(tests/test_scheduler_differential.py's heterogeneous generators).
"""

import json
import os

import numpy as np
import pytest

from repro.core import (CostModel, MachineModel, StrategyConfig, build_dag,
                        as_machine, evaluate_strategies, make_big_little,
                        make_plan, make_processor, make_tpu_like,
                        make_tpu_mixed, registered_strategies,
                        scale_processor, simulate)
from repro.core.dag import Task, TaskGraph
from repro.core.strategies import PlanContext, get_strategy

COST = CostModel()
BIG = make_processor("arc_opteron_6128")
LITTLE = scale_processor(BIG, "arc_little", freq_scale=0.5, volt_scale=0.85,
                         cap_scale=0.45, leak_scale=0.6)
GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "data",
                           "strategy_golden.json")


# ------------------------------------------------------------- MachineModel
def test_machine_model_rank_cycling():
    m = MachineModel("bl", (BIG, LITTLE))
    assert m.proc_for_rank(0) is BIG
    assert m.proc_for_rank(1) is LITTLE
    assert m.proc_for_rank(2) is BIG          # pattern repeats over ranks
    assert m.rank_procs(5) == [BIG, LITTLE, BIG, LITTLE, BIG]
    assert m.distinct_procs(5) == [BIG, LITTLE]
    assert m.distinct_procs(1) == [BIG]


def test_machine_model_homogeneity_detection():
    assert MachineModel.homogeneous(BIG).is_homogeneous
    assert MachineModel("same", (BIG, BIG, BIG)).is_homogeneous
    # equal-by-value counts as homogeneous even without object identity
    assert MachineModel("eq", (BIG, make_processor("arc_opteron_6128"))
                        ).is_homogeneous
    assert not MachineModel("bl", (BIG, LITTLE)).is_homogeneous
    assert as_machine(BIG).is_homogeneous
    assert as_machine(MachineModel("bl", (BIG, LITTLE))).procs == (BIG, LITTLE)


def test_machine_model_rejects_empty():
    with pytest.raises(ValueError):
        MachineModel("empty", ())


def test_scale_processor_scales_curve():
    assert LITTLE.f_max == pytest.approx(BIG.f_max * 0.5)
    assert len(LITTLE.gears) == len(BIG.gears)
    for g_big, g_lil in zip(BIG.gears, LITTLE.gears):
        assert g_lil.index == g_big.index
        assert g_lil.freq_ghz == pytest.approx(g_big.freq_ghz * 0.5)
    # the LITTLE's top-gear active power sits genuinely below the big's
    assert LITTLE.core_power_w(LITTLE.gears[0], True) \
        < 0.5 * BIG.core_power_w(BIG.gears[0], True)


def test_make_big_little_canned():
    m = make_big_little(n_big=1, n_little=3)
    assert not m.is_homogeneous
    assert len(m.procs) == 4
    assert m.procs[0].f_max > m.procs[1].f_max
    assert m.procs[1] is m.procs[2] is m.procs[3]
    with pytest.raises(ValueError):
        make_big_little(n_big=0)


def test_make_tpu_mixed_canned():
    m = make_tpu_mixed()
    assert not m.is_homogeneous
    full, lite = m.procs
    assert len(full.gears) == len(lite.gears) == 1   # single-gear parts
    assert lite.gears[0].freq_ghz == pytest.approx(
        full.gears[0].freq_ghz * 0.7)


# ------------------------------------------- homogeneous no-op (golden pins)
def _golden_cases():
    with open(GOLDEN_PATH) as f:
        return json.load(f)


@pytest.mark.parametrize("case", _golden_cases(),
                         ids=lambda c: f"{c['fact']}-T{c['n_tiles']}-{c['proc']}")
def test_homogeneous_machine_matches_seed_golden(case):
    """MachineModel.homogeneous must reproduce every legacy strategy's
    golden numbers exactly -- the provable-no-op obligation."""
    graph = build_dag(case["fact"], case["n_tiles"], case["tile"],
                      tuple(case["grid"]))
    machine = MachineModel.homogeneous(make_processor(case["proc"]))
    for strategy, exp in case["results"].items():
        sched = simulate(graph, machine, COST,
                         make_plan(strategy, graph, machine, COST))
        assert sched.switch_count == exp["switches"], strategy
        assert sched.makespan == pytest.approx(exp["makespan"], rel=1e-9), \
            strategy
        assert sched.total_energy_j() == pytest.approx(exp["energy"],
                                                       rel=1e-9), strategy


def test_homogeneous_machine_bit_identical_to_bare_proc():
    """Stronger than the golden pins: identical floats everywhere (segment
    columns, switch energy, total energy) for every registered strategy."""
    graph = build_dag("qr", 5, 256, (2, 2))
    machine = MachineModel.homogeneous(BIG)
    for strategy in registered_strategies():
        a = simulate(graph, BIG, COST, make_plan(strategy, graph, BIG, COST))
        b = simulate(graph, machine, COST,
                     make_plan(strategy, graph, machine, COST))
        np.testing.assert_array_equal(a.start, b.start, err_msg=strategy)
        np.testing.assert_array_equal(a.finish, b.finish, err_msg=strategy)
        assert a.switch_count == b.switch_count, strategy
        assert a.switch_energy_j == b.switch_energy_j, strategy
        assert a.total_energy_j() == b.total_energy_j(), strategy
        for ca, cb in zip(a.seg_columns, b.seg_columns):
            for x, y in zip(ca, cb):
                np.testing.assert_array_equal(x, y, err_msg=strategy)


# ------------------------------------------------- per-rank energy accounting
def _two_rank_graph():
    """Two independent equal-flops tasks, one per rank, on a (1, 2) grid."""
    tasks = [
        Task(tid=0, kind="GEMM", k=0, i=0, j=0, owner=0, flops=1e9,
             deps=[], out_tile=(0, 0)),
        Task(tid=1, kind="GEMM", k=0, i=0, j=1, owner=1, flops=1e9,
             deps=[], out_tile=(0, 1)),
    ]
    return TaskGraph("synthetic", n_tiles=1, tile_size=128, grid=(1, 2),
                     tasks=tasks)


def test_per_rank_durations_top():
    g = _two_rank_graph()
    machine = MachineModel("bl", (BIG, LITTLE))
    d = COST.durations_top(g, machine)
    # same flops, half the clock -> exactly twice the duration
    assert d[1] == pytest.approx(2.0 * d[0], rel=1e-12)
    d_hom = COST.durations_top(g, BIG)
    assert d_hom[0] == d[0]


def test_per_rank_energy_accounting_hand_computed():
    """Mixed 2-rank machine, `original` strategy: total energy decomposes
    into each rank's own power curve plus the mean nodal constant."""
    g = _two_rank_graph()
    machine = MachineModel("bl", (BIG, LITTLE))
    sched = simulate(g, machine, COST,
                     make_plan("original", g, machine, COST))
    d = COST.durations_top(g, machine)
    d_a, d_b = float(d[0]), float(d[1])
    assert sched.makespan == pytest.approx(d_b, rel=1e-12)
    # rank 0: active at BIG top for d_a, then idles at top (original) to d_b;
    # rank 1: active at LITTLE top the whole makespan. No gear switches.
    assert sched.switch_count == 0
    expect_core = (BIG.core_power_w(BIG.gears[0], True) * d_a
                   + BIG.core_power_w(BIG.gears[0], False) * (d_b - d_a)
                   + LITTLE.core_power_w(LITTLE.gears[0], True) * d_b)
    assert sched.core_energy_j() == pytest.approx(expect_core, rel=1e-12)
    # one node (2 ranks, 16 cores/node): mean of the two models' P_const
    p_const = 0.5 * (BIG.p_const_watts + LITTLE.p_const_watts)
    assert sched.nodal_const_power_w() == pytest.approx(p_const, rel=1e-12)
    assert sched.total_energy_j() == pytest.approx(
        expect_core + p_const * d_b, rel=1e-12)


def test_per_rank_power_trace_levels():
    g = _two_rank_graph()
    machine = MachineModel("bl", (BIG, LITTLE))
    sched = simulate(g, machine, COST,
                     make_plan("original", g, machine, COST))
    d = COST.durations_top(g, machine)
    p_const = sched.nodal_const_power_w()
    both = sched.power_trace(np.array([0.5 * float(d[0])]))[0]
    tail = sched.power_trace(np.array([1.5 * float(d[0])]))[0]
    assert both == pytest.approx(
        p_const + BIG.core_power_w(BIG.gears[0], True)
        + LITTLE.core_power_w(LITTLE.gears[0], True), rel=1e-12)
    assert tail == pytest.approx(
        p_const + BIG.core_power_w(BIG.gears[0], False)
        + LITTLE.core_power_w(LITTLE.gears[0], True), rel=1e-12)


def test_rank_segments_resolve_per_rank_gear_tables():
    """Gear indices in the columns resolve against each rank's own ladder
    (a single-gear TPU rank next to a 5-gear CPU rank must not collide)."""
    g = _two_rank_graph()
    machine = MachineModel("mix", (BIG, make_tpu_like()))
    sched = simulate(g, machine, COST,
                     make_plan("race_to_halt", g, machine, COST))
    segs = sched.rank_segments
    for s in segs[0]:
        assert s.gear in BIG.gears
    for s in segs[1]:
        assert s.gear.freq_ghz == pytest.approx(0.94)   # the TPU's one gear


# ----------------------------------------------- heterogeneity-aware policy
def test_plans_confined_to_owner_ladder():
    """Every strategy's segments and idle gears come from the owning
    rank's own gear table -- the EFFECTIVE owner's when the plan carries
    a `task_owners` migration override."""
    graph = build_dag("cholesky", 6, 256, (2, 2))
    machine = MachineModel("bl", (BIG, LITTLE, make_tpu_like(), BIG))
    procs = machine.rank_procs(graph.n_ranks)
    for strategy in registered_strategies():
        plan = make_plan(strategy, graph, machine, COST)
        assert plan.rank_idle_gears is not None, strategy
        for r, p in enumerate(procs):
            assert plan.idle_gear_for(r) in p.gears, (strategy, r)
        for t in graph.tasks:
            own = t.owner if plan.task_owners is None \
                else plan.task_owners[t.tid]
            table = procs[own].gears
            for gear, _ in plan.task_segments[t.tid]:
                assert gear in table, (strategy, t.tid)


def test_task_type_gears_uses_per_rank_prefixes():
    """Class-depth confinement applies within each rank's OWN ladder."""
    graph = build_dag("qr", 6, 256, (2, 2))
    machine = MachineModel("bl", (BIG, LITTLE))
    procs = machine.rank_procs(graph.n_ranks)
    cfg = StrategyConfig()
    ctx = PlanContext(graph, machine, COST, cfg)
    plan = get_strategy("task_type_gears").plan(ctx)
    from repro.core.tds import GEAR_CLASS_NAMES, task_gear_classes
    classes = task_gear_classes(graph)
    for t in graph.tasks:
        depth = cfg.kind_gear_depth[GEAR_CLASS_NAMES[classes[t.tid]]]
        allowed = {g.index for g in procs[t.owner].gear_prefix(depth)}
        for gear, _ in plan.task_segments[t.tid]:
            assert gear.index in allowed, (t.tid, t.kind)


def test_single_freq_opt_per_rank_uniform():
    """On a mixed machine each rank runs at ONE gear of its own ladder and
    the shared makespan cap still holds."""
    graph = build_dag("cholesky", 8, 256, (2, 2))
    machine = MachineModel("bl", (BIG, LITTLE))
    procs = machine.rank_procs(graph.n_ranks)
    cfg = StrategyConfig(single_freq_slowdown_cap=0.10)
    ctx = PlanContext(graph, machine, COST, cfg)
    plan = get_strategy("single_freq_opt").plan(ctx)
    per_rank_gears = [set() for _ in range(graph.n_ranks)]
    for t in graph.tasks:
        for gear, _ in plan.task_segments[t.tid]:
            assert gear in procs[t.owner].gears
            per_rank_gears[t.owner].add(gear.index)
    for gears in per_rank_gears:
        assert len(gears) <= 1
    sched = simulate(graph, machine, COST, plan)
    assert sched.makespan <= ctx.baseline.makespan * 1.10 + 1e-9


def test_big_little_strategies_save_energy():
    """The paper's strategies keep paying off on an asymmetric cluster,
    and nothing slower than the LITTLE-bound baseline appears."""
    graph = build_dag("cholesky", 8, 512, (2, 2))
    machine = make_big_little(n_big=1, n_little=1)
    res = evaluate_strategies(graph, machine, COST,
                              names=registered_strategies())
    assert res["algorithmic"].energy_j < res["original"].energy_j
    assert res["tx"].energy_j < res["original"].energy_j
    for name, r in res.items():
        assert r.slowdown_pct < 8.0, name


def test_tds_classification_respects_slow_ranks():
    """A slow rank's long task genuinely binds its consumers: with the
    producer on a LITTLE rank, the consumer's wait grows accordingly."""
    tasks = [
        Task(tid=0, kind="POTRF", k=0, i=0, j=0, owner=1, flops=1e9,
             deps=[], out_tile=(0, 0)),
        Task(tid=1, kind="TRSM", k=0, i=1, j=0, owner=0, flops=1e8,
             deps=[0], out_tile=(1, 0)),
    ]
    g = TaskGraph("synthetic", n_tiles=2, tile_size=128, grid=(1, 2),
                  tasks=tasks)
    hom = PlanContext(g, BIG, COST)
    het = PlanContext(g, MachineModel("bl", (BIG, LITTLE)), COST)
    from repro.core.tds import WAIT_PANEL
    assert hom.tds.wait_class[1] == WAIT_PANEL
    assert het.tds.wait_class[1] == WAIT_PANEL
    # the LITTLE producer runs 2x as long -> the panel wait roughly doubles
    assert het.tds.wait_s[1] > 1.5 * hom.tds.wait_s[1]
