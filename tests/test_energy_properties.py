"""Property-based tests (hypothesis) on the energy core's invariants."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.dag import build_dag
from repro.core.critical_path import cp_analysis, schedule_slack
from repro.core.energy_aware_step import (StepProfile, evaluate_step,
                                          strategy_gap_pct)
from repro.core.energy_model import (GEAR_TABLES, make_processor,
                                     max_slack_ratio, strategy_gap_terms)
from repro.core.scheduler import CostModel, simulate
from repro.core.strategies import evaluate_strategies, make_plan

FACTS = ("cholesky", "lu", "qr")
PROCS = tuple(GEAR_TABLES)


@settings(max_examples=20, deadline=None)
@given(st.sampled_from(FACTS), st.integers(2, 6), st.integers(1, 2),
       st.integers(1, 3))
def test_schedule_invariants(fact, n_tiles, p, q):
    """Every simulated schedule respects dependencies, program order, and
    produces non-negative realized slack."""
    graph = build_dag(fact, n_tiles, 64, (p, q))
    proc = make_processor("arc_opteron_6128")
    cost = CostModel()
    sched = simulate(graph, proc, cost,
                     make_plan("algorithmic", graph, proc, cost))
    comm = cost.comm_time(graph)
    for t in graph.tasks:
        for d in t.deps:
            delay = comm if graph.tasks[d].owner != t.owner else 0.0
            assert sched.start[t.tid] >= sched.finish[d] + delay - 1e-9
    for rank_tasks in graph.tasks_by_rank():
        for a, b in zip(rank_tasks[:-1], rank_tasks[1:]):
            assert sched.start[b] >= sched.finish[a] - 1e-9
    slack = schedule_slack(sched.start, sched.finish, graph, comm)
    assert (slack >= 0).all()


@settings(max_examples=15, deadline=None)
@given(st.sampled_from(FACTS), st.integers(2, 6))
def test_cp_length_lower_bounds_makespan(fact, n_tiles):
    graph = build_dag(fact, n_tiles, 64, (2, 2))
    proc = make_processor("arc_opteron_6128")
    cost = CostModel()
    durs = np.array([cost.duration_top(t.flops, t.kind, proc)
                     for t in graph.tasks])
    cp = cp_analysis(graph, durs, cost.comm_time(graph))
    base = simulate(graph, proc, cost,
                    make_plan("original", graph, proc, cost))
    assert base.makespan >= cp.cp_length - 1e-9
    assert cp.on_cp.any()


@settings(max_examples=10, deadline=None)
@given(st.sampled_from(FACTS), st.integers(3, 6), st.sampled_from(PROCS))
def test_strategy_energy_ordering(fact, n_tiles, proc_name):
    """In the paper's regime (ms-scale tasks), original never saves energy;
    every saving strategy stays within the paper's observed slowdown
    envelope (<5%); the algorithmic plan's overhead is no worse than
    cp_aware's."""
    graph = build_dag(fact, n_tiles, 768, (2, 2))
    proc = make_processor(proc_name)
    res = evaluate_strategies(graph, proc, CostModel())
    e0 = res["original"].energy_j
    for name in ("race_to_halt", "cp_aware", "algorithmic"):
        assert res[name].energy_j <= e0 * 1.001
        assert res[name].slowdown_pct < 5.0
    assert res["algorithmic"].slowdown_pct <= \
        res["cp_aware"].slowdown_pct + 1e-9


def test_dvfs_does_not_pay_below_granularity_threshold():
    """Found by hypothesis: with microsecond tasks (3x3 tiles of 96), the
    gear-switch energy and reactive wake-up stalls cost MORE than the idle
    savings recoup -- race-to-halt burns more energy than doing nothing.
    The scheduler models switch costs faithfully enough to show DVFS's
    granularity floor; the paper's workloads sit far above it."""
    graph = build_dag("cholesky", 3, 96, (2, 2))
    proc = make_processor("amd_opteron_2380")
    res = evaluate_strategies(graph, proc, CostModel())
    assert res["race_to_halt"].energy_j > res["original"].energy_j
    assert res["race_to_halt"].switch_count > 0


@settings(max_examples=40, deadline=None)
@given(st.floats(0.0, 10.0), st.floats(0.001, 10.0), st.floats(0.0, 10.0))
def test_step_profile_invariants(mxu, hbm, ici):
    p = StepProfile("x", "y", mxu, hbm, ici)
    slack = p.slack()
    assert all(s >= -1e-9 for s in slack.values())
    assert abs(slack[p.critical_lane]) < 1e-9
    res = evaluate_step(p, "tpu_like")
    # race-to-halt may only lose by its monitoring overhead (zero-slack
    # profiles: nothing to halt, the 0.1% monitor tax remains)
    assert res["race_to_halt"].energy_j <= \
        res["original"].energy_j * 1.002


@settings(max_examples=40, deadline=None)
@given(st.sampled_from(PROCS), st.floats(1.0, 3.0))
def test_gap_terms_nonpositive_dynamic(proc_name, n):
    """dEd <= 0 always (Eq. 8 is monotonically decreasing from 0 at n=1)."""
    proc = make_processor(proc_name)
    n = min(n, max_slack_ratio(proc))
    d_ed, _ = strategy_gap_terms(proc, n)
    assert d_ed <= 1e-12


def test_gap_collapses_on_voltage_flat_device():
    """The paper's conclusion: reclamation's edge over race-to-halt shrinks
    below 0.5% of total energy on a voltage-flat (TPU-like) device, while
    paper-era ladders keep a >0.5% edge at the same profile."""
    p = StepProfile("x", "train", 0.4, 1.0, 0.2)
    flat = strategy_gap_pct(p, "tpu_like")
    ladder = strategy_gap_pct(p, "intel_core_i7_2760qm")
    assert abs(flat) < 0.5
    assert ladder > 0.5
