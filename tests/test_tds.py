"""Unit tests for the Task Dependency Set analysis (core/tds.py).

Two layers:

  * hand-checked classifications on tiny synthetic DAGs where the binding
    dependency / consumer and the resulting wait/slack class are derivable
    on paper;
  * tiny real Cholesky/LU/QR graphs (T=2 on a (1,2) grid) where each
    rank-1 head task's wait is forced by construction, plus structural
    invariants on slightly larger graphs of all three factorizations.
"""

import numpy as np
import pytest

from repro.core import (CostModel, PlanContext, build_dag, make_processor,
                        simulate)
from repro.core.dag import Task, TaskGraph
from repro.core.strategies import make_plan
from repro.core.tds import (WAIT_COMM, WAIT_IMBALANCE, WAIT_NONE, WAIT_PANEL,
                            analyze_tds, compute_tds)

PROC = make_processor("arc_opteron_6128")
COST = CostModel()


def _graph(tasks, grid=(1, 2)):
    return TaskGraph("synthetic", n_tiles=2, tile_size=128, grid=grid,
                     tasks=tasks)


def _task(tid, kind, owner, flops, deps, tile):
    return Task(tid=tid, kind=kind, k=0, i=tile[0], j=tile[1], owner=owner,
                flops=flops, deps=deps, out_tile=tile)


def _tds_of(graph, cost=COST):
    base = simulate(graph, PROC, cost, make_plan("original", graph, PROC,
                                                 cost))
    return analyze_tds(graph, base.start, base.finish, cost.comm_time(graph))


# --------------------------------------------------- hand-built wait classes
def test_panel_wait_class():
    """rank1's task waits on a cross-rank POTRF -> panel wait."""
    g = _graph([
        _task(0, "POTRF", 0, 1e9, [], (0, 0)),
        _task(1, "TRSM", 1, 1e8, [0], (1, 0)),
    ])
    tds = _tds_of(g)
    assert tds.wait_class[0] == WAIT_NONE
    assert tds.wait_class[1] == WAIT_PANEL
    assert tds.binding_dep[1] == 0
    assert tds.wait_s[1] > 0.0


def test_comm_wait_class():
    """The producer finished while rank1 was still busy: the residual wait
    is pure wire time -> communication wait."""
    # rank0 and rank1 run equal-duration local tasks, so the producer is
    # done exactly when rank1 goes idle: the whole wait is the transfer.
    g = _graph([
        _task(0, "GEMM", 0, 1e8, [], (0, 0)),      # producer on rank0
        _task(1, "GEMM", 1, 1e8, [], (1, 1)),      # same duration on rank1
        _task(2, "GEMM", 1, 1e8, [0, 1], (0, 1)),  # consumer on rank1
    ])
    tds = _tds_of(g)
    assert tds.wait_class[2] == WAIT_COMM
    assert tds.binding_dep[2] == 0
    assert tds.wait_s[2] == pytest.approx(COST.comm_time(g), rel=1e-9)


def test_imbalance_wait_class():
    """rank1 runs out of work while the (non-panel) producer still
    computes -> load-imbalance wait."""
    g = _graph([
        _task(0, "GEMM", 0, 1e10, [], (0, 0)),     # long producer
        _task(1, "GEMM", 1, 1e8, [0], (1, 1)),     # rank1 idles from t=0
    ])
    tds = _tds_of(g)
    assert tds.wait_class[1] == WAIT_IMBALANCE
    assert tds.wait_s[1] > COST.comm_time(g)


# --------------------------------------------------- hand-built slack classes
def test_panel_slack_class():
    """Early-finishing producer whose tightest consumer is a (late) panel
    task -> panel-bound slack."""
    g = _graph([
        _task(0, "GEMM", 0, 1e8, [], (0, 0)),       # finishes early
        _task(1, "GEMM", 1, 1e10, [], (1, 1)),      # delays the panel
        _task(2, "POTRF", 1, 1e9, [0, 1], (0, 1)),  # panel consumer
    ])
    tds = _tds_of(g)
    assert tds.slack_s[0] > 0.0
    assert tds.slack_class[0] == WAIT_PANEL
    assert tds.binding_consumer[0] == 2


def test_comm_slack_class():
    """Same shape with a non-panel cross-rank consumer -> comm slack."""
    g = _graph([
        _task(0, "GEMM", 0, 1e8, [], (0, 0)),
        _task(1, "GEMM", 1, 1e10, [], (1, 1)),
        _task(2, "SYRK", 1, 1e9, [0, 1], (0, 1)),
    ])
    tds = _tds_of(g)
    assert tds.slack_s[0] > 0.0
    assert tds.slack_class[0] == WAIT_COMM
    assert tds.binding_consumer[0] == 2


def test_imbalance_slack_class():
    """A terminal task on an early-finishing rank stretches to the
    makespan -> imbalance slack, no binding consumer."""
    g = _graph([
        _task(0, "GEMM", 0, 1e8, [], (0, 0)),      # rank0 done early
        _task(1, "GEMM", 1, 1e10, [], (1, 1)),     # rank1 sets the makespan
    ])
    tds = _tds_of(g)
    assert tds.slack_class[0] == WAIT_IMBALANCE
    assert tds.binding_consumer[0] == -1
    assert tds.slack_class[1] == WAIT_NONE         # defines the makespan


# --------------------------------------------------- tiny real factorizations
def test_cholesky_t2_hand_checked():
    """T=2 Cholesky on (1,2): rank1's first task (SYRK) waits on the
    cross-rank TRSM that is still computing when rank1 starts idle ->
    imbalance; POTRF(1) follows its own rank's SYRK -> no wait."""
    g = build_dag("cholesky", 2, 256, (1, 2))
    kinds = {t.tid: (t.kind, t.owner) for t in g.tasks}
    tds = compute_tds(g, PROC, COST)
    (syrk,) = [t.tid for t in g.tasks if t.kind == "SYRK"]
    (potrf1,) = [t.tid for t in g.tasks if t.kind == "POTRF" and t.k == 1]
    assert kinds[syrk][1] == 1                    # block-cyclic: rank 1
    assert tds.wait_class[syrk] == WAIT_IMBALANCE
    assert g.tasks[tds.binding_dep[syrk]].kind == "TRSM"
    assert tds.wait_class[potrf1] == WAIT_NONE


def test_lu_t2_hand_checked():
    """T=2 LU on (1,2): TRSM_ROW (rank1) waits on the cross-rank GETRF ->
    panel wait; the GEMM's cross-rank input (TRSM_COL: equal duration,
    started comm earlier than TRSM_ROW) arrives exactly at rank-ready ->
    no wait."""
    g = build_dag("lu", 2, 256, (1, 2))
    tds = compute_tds(g, PROC, COST)
    (trsm_row,) = [t.tid for t in g.tasks if t.kind == "TRSM_ROW"]
    (gemm,) = [t.tid for t in g.tasks if t.kind == "GEMM"]
    assert tds.wait_class[trsm_row] == WAIT_PANEL
    assert g.tasks[tds.binding_dep[trsm_row]].kind == "GETRF"
    # TRSM_ROW paid the GETRF broadcast delay before starting, so the
    # TRSM_COL transfer fully overlaps rank1's own work: zero wait
    assert tds.wait_class[gemm] == WAIT_NONE
    assert tds.wait_s[gemm] == pytest.approx(0.0, abs=1e-12)


def test_qr_t2_hand_checked():
    """T=2 QR on (1,2): UNMQR (rank1) waits on the cross-rank GEQRT ->
    panel wait; SSRFB's binding dep is the slower TSQRT (also a panel
    kind) -> panel wait."""
    g = build_dag("qr", 2, 256, (1, 2))
    tds = compute_tds(g, PROC, COST)
    (unmqr,) = [t.tid for t in g.tasks if t.kind == "UNMQR"]
    (ssrfb,) = [t.tid for t in g.tasks if t.kind == "SSRFB"]
    assert tds.wait_class[unmqr] == WAIT_PANEL
    assert g.tasks[tds.binding_dep[unmqr]].kind == "GEQRT"
    assert tds.wait_class[ssrfb] == WAIT_PANEL
    assert g.tasks[tds.binding_dep[ssrfb]].kind == "TSQRT"


# --------------------------------------------------- structural invariants
@pytest.mark.parametrize("fact", ["cholesky", "lu", "qr"])
def test_tds_invariants(fact):
    g = build_dag(fact, 6, 256, (2, 2))
    ctx = PlanContext(g, PROC, COST)
    tds = ctx.tds
    n = len(g.tasks)
    assert tds.wait_s.shape == tds.slack_s.shape == (n,)
    assert np.all(tds.wait_s >= 0) and np.all(tds.slack_s >= 0)
    assert set(np.unique(tds.wait_class)) <= {0, 1, 2, 3}
    assert set(np.unique(tds.slack_class)) <= {0, 1, 2, 3}
    # every classified wait has a binding dependency, and vice versa a
    # zero wait is classified none
    waiting = tds.wait_s > 1e-15
    assert np.all(tds.binding_dep[waiting] >= 0)
    assert np.all(tds.wait_class[~waiting] == WAIT_NONE)
    assert np.all(tds.wait_class[waiting] != WAIT_NONE)
    # binding deps really are dependencies
    for tid in np.flatnonzero(waiting):
        assert tds.binding_dep[tid] in tds.dependency_set(tid)
    # slack matches PlanContext's (same baseline, same analysis)
    np.testing.assert_array_equal(tds.slack_s, ctx.slack)
    # wait seconds decompose the schedule's idle-before-task time exactly
    base = ctx.baseline
    total_wait = sum(tds.wait_seconds_by_class().values())
    gaps = base.start - tds.rank_ready
    assert total_wait == pytest.approx(float(np.maximum(gaps, 0.0).sum()))
    # dependency sets are exactly the DAG's deps
    assert tds.dependency_counts().sum() == sum(len(t.deps) for t in g.tasks)


def test_empty_graph_tds():
    g = TaskGraph("empty", 1, 128, (1, 1), [])
    tds = analyze_tds(g, np.zeros(0), np.zeros(0), 1e-4)
    assert len(tds.wait_s) == 0
    assert tds.wait_seconds_by_class()["panel"] == 0.0
