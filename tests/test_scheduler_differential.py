"""Differential testing: fast event-driven `simulate` and the batched
fleet engine `simulate_fleet` vs the pick-loop oracle `simulate_reference`.

The fast engine replaced an O(tasks x ranks x deps) scan with a ready-heap,
and the fleet engine replaced per-lane dispatch with one vectorized
tid-order pass over B lanes; the three implementations share no dispatch
code, so agreement across randomized inputs is strong evidence of
correctness. Three generators:

  * strategy cases    -- real factorization DAGs (cholesky/lu/qr), random
                         tile counts, grids, and gear tables, through
                         EVERY strategy in the registry (the paper's four
                         plus `tx` and anything registered later --
                         registering a strategy automatically enrolls it
                         here: the differential-suite obligation);
  * random plans      -- adversarial StrategyPlans on factorization DAGs:
                         random per-task gear segments (including empty
                         segment lists), overheads, idle gears, and both
                         switch-hiding policies;
  * synthetic DAGs    -- random task graphs (random deps/owners/flops) that
                         need not look like a factorization at all;
  * heterogeneous     -- the same strategy/random-plan generators on
                         randomized *mixed-rank* MachineModels (2-3 distinct
                         ProcessorModels with different ladders, power
                         curves, and switch latencies assigned randomly to
                         ranks) -- any per-rank change to one engine must be
                         mirrored in the other to stay green.

The fleet section feeds the same generators -- registry strategies,
adversarial random plans, synthetic DAGs, and mixed MachineModels -- into
single `simulate_fleet` calls with per-lane machines and checks EVERY lane
against its own oracle run: the three-engine contract (any engine-visible
semantic change must land in all three engines in lockstep).

Agreement asserted to 1e-9 (relative) on makespan, total energy, and
exactly on switch count and per-task start/finish times. A golden corpus
(tests/data/strategy_golden.json, recorded from the pre-registry seed
implementation) additionally pins the four legacy strategies' makespan/
energy/switch-count to the refactored planner's output
(tests/test_heterogeneous.py re-pins it through MachineModel.homogeneous).
"""

import json
import os

import numpy as np
import pytest

import dataclasses

from repro.core import (CostModel, GEAR_TABLES, LinkModel, MachineModel,
                        StrategyPlan, build_dag, make_processor, make_plan,
                        registered_strategies, scale_processor, simulate,
                        simulate_fleet, simulate_reference)
from repro.core.dag import Task, TaskGraph

FACTS = ("cholesky", "lu", "qr")
GRIDS = ((1, 1), (1, 2), (2, 2), (2, 3), (4, 2), (3, 3))
PROCS = tuple(sorted(GEAR_TABLES))
ALL_STRATEGIES = registered_strategies()
GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "data",
                           "strategy_golden.json")


def assert_schedules_match(a, b, label=""):
    np.testing.assert_array_equal(a.start, b.start, err_msg=f"start {label}")
    np.testing.assert_array_equal(a.finish, b.finish,
                                  err_msg=f"finish {label}")
    assert a.switch_count == b.switch_count, label
    mk_a, mk_b = a.makespan, b.makespan
    assert abs(mk_a - mk_b) <= 1e-9 * max(1.0, abs(mk_b)), (label, mk_a, mk_b)
    e_a, e_b = a.total_energy_j(), b.total_energy_j()
    assert abs(e_a - e_b) <= 1e-9 * max(1.0, abs(e_b)), (label, e_a, e_b)


def _random_graph_params(rng):
    name = FACTS[rng.integers(len(FACTS))]
    n_tiles = int(rng.integers(3, 9))
    tile = int(rng.choice([64, 128, 256]))
    grid = GRIDS[rng.integers(len(GRIDS))]
    proc_name = PROCS[rng.integers(len(PROCS))]
    return name, n_tiles, tile, grid, proc_name


# ------------------------------------------------------ strategy-level cases
# 16 seeds x every registered strategy (>= 80 cases) over cholesky/lu/qr.
@pytest.mark.parametrize("seed", range(16))
@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_strategies_differential(seed, strategy):
    rng = np.random.default_rng(1000 + seed)
    name, n_tiles, tile, grid, proc_name = _random_graph_params(rng)
    graph = build_dag(name, n_tiles, tile, grid)
    proc = make_processor(proc_name)
    cost = CostModel(comm_bandwidth_gbs=float(rng.uniform(1.0, 40.0)))
    plan = make_plan(strategy, graph, proc, cost)
    fast = simulate(graph, proc, cost, plan)
    ref = simulate_reference(graph, proc, cost, plan)
    assert_schedules_match(fast, ref,
                           f"{name} T={n_tiles} {grid} {proc_name} {strategy}")


# ------------------------------------------------------ adversarial plans
def _random_plan(rng, graph, proc, cost):
    """A plan no real strategy would emit: stresses every engine branch."""
    durs = cost.durations_top(graph, proc)
    segs = []
    for t in graph.tasks:
        k = int(rng.integers(0, 4))        # 0 => empty segment list
        if k == 0:
            segs.append([])
        else:
            segs.append([(proc.gears[int(rng.integers(len(proc.gears)))],
                          float(durs[t.tid]) * float(rng.uniform(0.2, 2.0)))
                         for _ in range(k)])
    overhead = np.where(rng.random(len(graph.tasks)) < 0.5,
                        rng.uniform(0.0, 2e-4, len(graph.tasks)), 0.0)
    return StrategyPlan(
        name="random",
        task_segments=segs,
        idle_gear=proc.gears[int(rng.integers(len(proc.gears)))],
        per_task_overhead=overhead,
        hide_switch_in_wait=bool(rng.integers(2)),
        min_halt_window_s=float(rng.choice([0.0, 1e-4, 1e-2])),
    )


@pytest.mark.parametrize("seed", range(20))
def test_random_plans_differential(seed):
    rng = np.random.default_rng(2000 + seed)
    name, n_tiles, tile, grid, proc_name = _random_graph_params(rng)
    graph = build_dag(name, n_tiles, tile, grid)
    proc = make_processor(proc_name)
    cost = CostModel()
    plan = _random_plan(rng, graph, proc, cost)
    fast = simulate(graph, proc, cost, plan)
    ref = simulate_reference(graph, proc, cost, plan)
    assert_schedules_match(fast, ref, f"random plan seed={seed}")


# ------------------------------------------------------ synthetic DAGs
def _random_dag(rng, n_tasks, n_ranks):
    """Arbitrary DAG: deps point to earlier tids, owners are random."""
    p = int(rng.choice([1, 2, 4]))
    q = max(1, n_ranks // p)
    real_ranks = p * q     # grid only determines n_ranks for the simulator
    tasks = []
    for tid in range(n_tasks):
        n_deps = int(rng.integers(0, min(tid, 4) + 1))
        deps = sorted(rng.choice(tid, size=n_deps, replace=False).tolist()) \
            if n_deps else []
        tasks.append(Task(
            tid=tid, kind="GEMM", k=0, i=0, j=0,
            owner=int(rng.integers(n_ranks)) % real_ranks,
            flops=float(rng.uniform(1e6, 1e9)),
            deps=[int(d) for d in deps],
            out_tile=(0, tid)))
    return TaskGraph("synthetic", n_tiles=1, tile_size=128, grid=(p, q),
                     tasks=tasks)


@pytest.mark.parametrize("seed", range(12))
def test_synthetic_dags_differential(seed):
    rng = np.random.default_rng(3000 + seed)
    n_ranks = int(rng.choice([1, 2, 4, 8]))
    graph = _random_dag(rng, n_tasks=int(rng.integers(20, 200)),
                        n_ranks=n_ranks)
    proc = make_processor(PROCS[rng.integers(len(PROCS))])
    cost = CostModel()
    plan = _random_plan(rng, graph, proc, cost)
    fast = simulate(graph, proc, cost, plan)
    ref = simulate_reference(graph, proc, cost, plan)
    assert_schedules_match(fast, ref, f"synthetic seed={seed}")


# ------------------------------------------------------ heterogeneous machines
def _random_machine(rng, n_ranks) -> MachineModel:
    """A genuinely mixed per-rank machine: 2-3 distinct processors (possibly
    derated siblings with different ladders/power/switch latency) assigned
    randomly to ranks, with at least two types present when ranks allow."""
    base = make_processor(PROCS[rng.integers(len(PROCS))])
    pool = [base,
            scale_processor(base, base.name + "_lil",
                            freq_scale=float(rng.uniform(0.4, 0.8)),
                            volt_scale=float(rng.uniform(0.7, 1.0)),
                            cap_scale=float(rng.uniform(0.3, 0.8))),
            make_processor(PROCS[rng.integers(len(PROCS))],
                           switch_latency_s=float(rng.choice([50e-6,
                                                              200e-6])))]
    k = int(rng.integers(2, len(pool) + 1))
    assign = rng.integers(0, k, size=max(n_ranks, 1))
    if n_ranks >= 2 and len(set(assign.tolist())) < 2:
        assign[0], assign[1] = 0, 1       # force a real mix
    return MachineModel(name="random_mix",
                        procs=tuple(pool[i] for i in assign))


def _random_hetero_plan(rng, graph, machine, cost):
    """Adversarial plan on a mixed machine: every gear is drawn from the
    owning rank's own ladder, idle gears are random per rank."""
    procs = machine.rank_procs(graph.n_ranks)
    durs = cost.durations_top(graph, machine)
    segs = []
    for t in graph.tasks:
        p = procs[t.owner]
        k = int(rng.integers(0, 4))        # 0 => empty segment list
        segs.append([(p.gears[int(rng.integers(len(p.gears)))],
                      float(durs[t.tid]) * float(rng.uniform(0.2, 2.0)))
                     for _ in range(k)])
    overhead = np.where(rng.random(len(graph.tasks)) < 0.5,
                        rng.uniform(0.0, 2e-4, len(graph.tasks)), 0.0)
    rank_idle = [p.gears[int(rng.integers(len(p.gears)))] for p in procs]
    return StrategyPlan(
        name="random_hetero",
        task_segments=segs,
        idle_gear=rank_idle[0],
        per_task_overhead=overhead,
        hide_switch_in_wait=bool(rng.integers(2)),
        min_halt_window_s=float(rng.choice([0.0, 1e-4, 1e-2])),
        rank_idle_gears=rank_idle,
    )


# 4 seeds x every registered strategy (>= 32 cases) on mixed machines, plus
# 8 adversarial random heterogeneous plans below: >= 40 heterogeneous
# differential cases in total (ISSUE 4 acceptance: >= 20).
@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_heterogeneous_strategies_differential(seed, strategy):
    rng = np.random.default_rng(4000 + seed)
    name, n_tiles, tile, grid, _ = _random_graph_params(rng)
    graph = build_dag(name, n_tiles, tile, grid)
    machine = _random_machine(rng, graph.n_ranks)
    if graph.n_ranks >= 2:
        assert not machine.is_homogeneous     # a real mix, not a degenerate one
    cost = CostModel(comm_bandwidth_gbs=float(rng.uniform(1.0, 40.0)))
    plan = make_plan(strategy, graph, machine, cost)
    fast = simulate(graph, machine, cost, plan)
    ref = simulate_reference(graph, machine, cost, plan)
    assert_schedules_match(fast, ref,
                           f"hetero {name} T={n_tiles} {grid} {strategy}")


@pytest.mark.parametrize("seed", range(8))
def test_heterogeneous_random_plans_differential(seed):
    rng = np.random.default_rng(5000 + seed)
    name, n_tiles, tile, grid, _ = _random_graph_params(rng)
    graph = build_dag(name, n_tiles, tile, grid)
    machine = _random_machine(rng, graph.n_ranks)
    cost = CostModel()
    plan = _random_hetero_plan(rng, graph, machine, cost)
    fast = simulate(graph, machine, cost, plan)
    ref = simulate_reference(graph, machine, cost, plan)
    assert_schedules_match(fast, ref, f"hetero random plan seed={seed}")


def test_heterogeneous_segment_columns_bit_identical():
    """Stronger than 1e-9: identical per-rank timelines on a mixed machine."""
    graph = build_dag("lu", 6, 128, (2, 2))
    big = make_processor("arc_opteron_6128")
    little = scale_processor(big, "arc_little", freq_scale=0.6,
                             volt_scale=0.85, cap_scale=0.45)
    machine = MachineModel("bl", (big, little, little, big))
    cost = CostModel()
    for strategy in ALL_STRATEGIES:
        plan = make_plan(strategy, graph, machine, cost)
        fast = simulate(graph, machine, cost, plan)
        ref = simulate_reference(graph, machine, cost, plan)
        for ca, cb in zip(fast.seg_columns, ref.seg_columns):
            for x, y in zip(ca, cb):
                np.testing.assert_array_equal(x, y)


# ------------------------------------------------------ nonuniform links
def _random_link(rng) -> LinkModel:
    """A random per-rank-pair LinkModel: asymmetric bandwidth and transfer
    energy pattern tables (tiled over ranks), random shared latency."""
    p = int(rng.integers(1, 4))
    bw = rng.uniform(0.5, 20.0, (p, p))
    en = rng.uniform(0.0, 5e-9, (p, p))
    return LinkModel(name="random_link",
                     pair_bandwidth_gbs=tuple(map(tuple, bw.tolist())),
                     pair_energy_per_byte_j=tuple(map(tuple, en.tolist())),
                     latency_s=float(rng.uniform(0.0, 2e-5)))


def _random_owner_override(rng, graph):
    """A random full task->rank remapping (exercises `task_owners`)."""
    return [int(o) for o in rng.integers(0, graph.n_ranks,
                                         len(graph.tasks))]


# 4 seeds x every registered strategy on randomized nonuniform-link
# machines: the comm matrix prices every cross-rank edge per rank pair,
# so any engine disagreeing on a single edge gather goes red here.
@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_nonuniform_link_strategies_differential(seed, strategy):
    rng = np.random.default_rng(8000 + seed)
    name, n_tiles, tile, grid, _ = _random_graph_params(rng)
    graph = build_dag(name, n_tiles, tile, grid)
    machine = _random_machine(rng, graph.n_ranks)
    cost = CostModel(link=_random_link(rng))
    plan = make_plan(strategy, graph, machine, cost)
    fast = simulate(graph, machine, cost, plan)
    ref = simulate_reference(graph, machine, cost, plan)
    assert_schedules_match(fast, ref,
                           f"link {name} T={n_tiles} {grid} {strategy}")
    assert fast.comm_energy_j == ref.comm_energy_j


@pytest.mark.parametrize("seed", range(8))
def test_nonuniform_link_random_plans_differential(seed):
    """Adversarial plans -- including random `task_owners` migration
    overrides -- under random per-pair link matrices."""
    rng = np.random.default_rng(8500 + seed)
    name, n_tiles, tile, grid, _ = _random_graph_params(rng)
    graph = build_dag(name, n_tiles, tile, grid)
    machine = _random_machine(rng, graph.n_ranks)
    cost = CostModel(link=_random_link(rng))
    plan = _random_hetero_plan(rng, graph, machine, cost)
    if rng.integers(2):
        # remap randomly; segments keep gears of the ORIGINAL owners'
        # ladders, which is engine-legal only when ladders coincide, so
        # restrict the override to homogeneous random machines
        machine = MachineModel("homog",
                               (make_processor(PROCS[rng.integers(
                                   len(PROCS))]),))
        plan = _random_plan(rng, graph, machine.procs[0], cost)
        plan = dataclasses.replace(
            plan, task_owners=_random_owner_override(rng, graph))
    fast = simulate(graph, machine, cost, plan)
    ref = simulate_reference(graph, machine, cost, plan)
    assert_schedules_match(fast, ref, f"link random plan seed={seed}")
    assert fast.comm_energy_j == ref.comm_energy_j


@pytest.mark.parametrize("seed", range(6))
def test_nonuniform_link_synthetic_dags_differential(seed):
    rng = np.random.default_rng(8800 + seed)
    n_ranks = int(rng.choice([1, 2, 4, 8]))
    graph = _random_dag(rng, n_tasks=int(rng.integers(20, 150)),
                        n_ranks=n_ranks)
    proc = make_processor(PROCS[rng.integers(len(PROCS))])
    cost = CostModel(link=_random_link(rng))
    plan = _random_plan(rng, graph, proc, cost)
    if rng.integers(2):
        plan = dataclasses.replace(
            plan, task_owners=_random_owner_override(rng, graph))
    fast = simulate(graph, proc, cost, plan)
    ref = simulate_reference(graph, proc, cost, plan)
    assert_schedules_match(fast, ref, f"link synthetic seed={seed}")


@pytest.mark.parametrize("seed", range(4))
def test_fleet_nonuniform_link_lanes_differential(seed):
    """Fleet lanes under a random link matrix, mixing frozen-mapping plans
    with `task_owners`-overridden lanes (different mappings per lane force
    the fleet engine down its mapping-partition path); every lane must
    match its own oracle run, wire energy included."""
    rng = np.random.default_rng(9000 + seed)
    name, n_tiles, tile, grid, proc_name = _random_graph_params(rng)
    graph = build_dag(name, n_tiles, tile, grid)
    proc = make_processor(proc_name)
    cost = CostModel(link=_random_link(rng))
    plans = [make_plan(s, graph, proc, cost)
             for s in ("original", "race_to_halt", "tx")]
    for _ in range(3):
        plans.append(_random_plan(rng, graph, proc, cost))
        plans.append(dataclasses.replace(
            _random_plan(rng, graph, proc, cost),
            task_owners=_random_owner_override(rng, graph)))
    fleet = simulate_fleet(graph, proc, cost, plans)
    assert fleet.comm_energy_j is not None
    for i, plan in enumerate(plans):
        ref = simulate_reference(graph, proc, cost, plan)
        assert_fleet_lane_matches(fleet, i, ref,
                                  f"link fleet seed={seed} lane={i}")
        assert float(fleet.comm_energy_j[i]) == ref.comm_energy_j


def test_task_owners_validation():
    """Malformed migration overrides are rejected up front."""
    graph = build_dag("cholesky", 3, 128, (1, 2))
    proc = make_processor("arc_opteron_6128")
    cost = CostModel()
    plan = make_plan("original", graph, proc, cost)
    with pytest.raises(ValueError, match="task_owners"):
        simulate(graph, proc, cost,
                 dataclasses.replace(plan, task_owners=[0]))
    bad = [0] * len(graph.tasks)
    bad[0] = graph.n_ranks
    with pytest.raises(ValueError, match="task_owners"):
        simulate(graph, proc, cost,
                 dataclasses.replace(plan, task_owners=bad))


# ------------------------------------------------------ edge cases
def test_empty_graph():
    graph = TaskGraph("empty", 1, 128, (1, 1), [])
    proc = make_processor("arc_opteron_6128")
    cost = CostModel()
    plan = StrategyPlan("empty", [], proc.gears[0], np.zeros(0), True)
    fast = simulate(graph, proc, cost, plan)
    ref = simulate_reference(graph, proc, cost, plan)
    assert fast.makespan == ref.makespan == 0.0
    assert fast.total_energy_j() == ref.total_energy_j()


def test_single_task():
    graph = build_dag("cholesky", 1, 128, (1, 1))
    proc = make_processor("amd_opteron_2380")
    cost = CostModel()
    for strategy in ALL_STRATEGIES:
        plan = make_plan(strategy, graph, proc, cost)
        assert_schedules_match(simulate(graph, proc, cost, plan),
                               simulate_reference(graph, proc, cost, plan),
                               f"single task {strategy}")


def test_segment_columns_bit_identical():
    """Stronger than the 1e-9 criterion: identical per-rank timelines."""
    graph = build_dag("lu", 6, 128, (2, 2))
    proc = make_processor("arc_opteron_6128")
    cost = CostModel()
    for strategy in ALL_STRATEGIES:
        plan = make_plan(strategy, graph, proc, cost)
        fast = simulate(graph, proc, cost, plan)
        ref = simulate_reference(graph, proc, cost, plan)
        for ca, cb in zip(fast.seg_columns, ref.seg_columns):
            for x, y in zip(ca, cb):
                np.testing.assert_array_equal(x, y)


# ------------------------------------------------------ fleet lanes
def assert_fleet_lane_matches(fleet, i, ref, label=""):
    """Lane i of a FleetSchedule vs one oracle Schedule: bit-identical
    timelines and switch counts, 1e-9 on the energy sums."""
    np.testing.assert_array_equal(fleet.start[i], ref.start,
                                  err_msg=f"start {label}")
    np.testing.assert_array_equal(fleet.finish[i], ref.finish,
                                  err_msg=f"finish {label}")
    assert int(fleet.switch_count[i]) == ref.switch_count, label
    se, se_ref = float(fleet.switch_energy_j[i]), ref.switch_energy_j
    assert abs(se - se_ref) <= 1e-9 * max(1.0, abs(se_ref)), \
        (label, se, se_ref)
    mk, mk_ref = float(fleet.makespan[i]), ref.makespan
    assert mk == mk_ref, (label, mk, mk_ref)     # max of identical floats
    e, e_ref = float(fleet.total_energy_j()[i]), ref.total_energy_j()
    assert abs(e - e_ref) <= 1e-9 * max(1.0, abs(e_ref)), (label, e, e_ref)


@pytest.mark.parametrize("seed", range(6))
def test_fleet_lanes_differential(seed):
    """One batched call mixing registry strategies, adversarial random
    plans, and per-lane machines (homogeneous AND mixed); every lane must
    match its own oracle run."""
    rng = np.random.default_rng(6000 + seed)
    name, n_tiles, tile, grid, proc_name = _random_graph_params(rng)
    graph = build_dag(name, n_tiles, tile, grid)
    proc = make_processor(proc_name)
    machine = _random_machine(rng, graph.n_ranks)
    cost = CostModel(comm_bandwidth_gbs=float(rng.uniform(1.0, 40.0)))
    lanes = [(proc, make_plan(s, graph, proc, cost))
             for s in ALL_STRATEGIES]
    lanes += [(machine, make_plan(s, graph, machine, cost))
              for s in ("original", "race_to_halt", "algorithmic", "tx")]
    for _ in range(3):
        lanes.append((proc, _random_plan(rng, graph, proc, cost)))
        lanes.append((machine,
                      _random_hetero_plan(rng, graph, machine, cost)))
    fleet = simulate_fleet(graph, [m for m, _ in lanes], cost,
                           [p for _, p in lanes])
    assert fleet.n_lanes == len(lanes)
    for i, (m, p) in enumerate(lanes):
        ref = simulate_reference(graph, m, cost, p)
        assert_fleet_lane_matches(fleet, i, ref,
                                  f"seed={seed} lane={i} {p.name}")


@pytest.mark.parametrize("seed", range(6))
def test_fleet_synthetic_dags_differential(seed):
    """Fleet lanes over random synthetic DAGs (random deps/owners)."""
    rng = np.random.default_rng(7000 + seed)
    n_ranks = int(rng.choice([1, 2, 4, 8]))
    graph = _random_dag(rng, n_tasks=int(rng.integers(20, 150)),
                        n_ranks=n_ranks)
    proc = make_processor(PROCS[rng.integers(len(PROCS))])
    cost = CostModel()
    plans = [_random_plan(rng, graph, proc, cost) for _ in range(8)]
    fleet = simulate_fleet(graph, proc, cost, plans)    # broadcast machine
    for i, plan in enumerate(plans):
        ref = simulate_reference(graph, proc, cost, plan)
        assert_fleet_lane_matches(fleet, i, ref,
                                  f"synthetic seed={seed} lane={i}")


def test_fleet_lane_escape_hatch_bit_identical():
    """`FleetSchedule.lane(i)` materializes a full Schedule whose per-rank
    segment columns match the oracle's bit for bit."""
    graph = build_dag("lu", 5, 128, (2, 2))
    proc = make_processor("arc_opteron_6128")
    cost = CostModel()
    plans = [make_plan(s, graph, proc, cost)
             for s in ("original", "race_to_halt", "tx")]
    fleet = simulate_fleet(graph, proc, cost, plans)
    for i, plan in enumerate(plans):
        sched = fleet.lane(i)
        ref = simulate_reference(graph, proc, cost, plan)
        assert_schedules_match(sched, ref, f"lane({i})")
        for ca, cb in zip(sched.seg_columns, ref.seg_columns):
            for x, y in zip(ca, cb):
                np.testing.assert_array_equal(x, y)
        assert_fleet_lane_matches(fleet, i, ref, f"lane({i})")


def test_fleet_empty_lanes_and_empty_graph():
    graph = build_dag("cholesky", 3, 128, (1, 2))
    proc = make_processor("arc_opteron_6128")
    cost = CostModel()
    fleet = simulate_fleet(graph, proc, cost, [])
    assert fleet.n_lanes == 0
    assert fleet.start.shape == (0, len(graph.tasks))
    assert fleet.total_energy_j().shape == (0,)
    empty = TaskGraph("empty", 1, 128, (1, 1), [])
    plan = StrategyPlan("empty", [], proc.gears[0], np.zeros(0), True)
    fleet = simulate_fleet(empty, proc, cost, [plan, plan])
    assert np.array_equal(fleet.makespan, np.zeros(2))
    ref = simulate_reference(empty, proc, cost, plan)
    assert float(fleet.total_energy_j()[0]) == ref.total_energy_j()


def test_fleet_input_validation():
    """Machine-count mismatch and non-topological tids are rejected."""
    graph = build_dag("cholesky", 3, 128, (1, 2))
    proc = make_processor("arc_opteron_6128")
    cost = CostModel()
    plans = [make_plan("original", graph, proc, cost)]
    with pytest.raises(ValueError, match="machines"):
        simulate_fleet(graph, [proc, proc], cost, plans)
    bad = TaskGraph("bad", 1, 128, (1, 1), [
        Task(tid=0, kind="GEMM", k=0, i=0, j=0, owner=0, flops=1e6,
             deps=[1], out_tile=(0, 0)),
        Task(tid=1, kind="GEMM", k=0, i=0, j=0, owner=0, flops=1e6,
             deps=[], out_tile=(0, 1))])
    bad_plan = StrategyPlan("bad", [[], []], proc.gears[0], np.zeros(2), True)
    with pytest.raises(ValueError, match="topologically"):
        simulate_fleet(bad, proc, cost, [bad_plan])


# ------------------------------------------------------ registry coverage
def test_registry_covers_legacy_and_tx():
    """The randomized cases above parametrize over the live registry; this
    pins the minimum population they must cover."""
    for name in ("original", "race_to_halt", "cp_aware", "algorithmic", "tx",
                 "task_type_gears", "single_freq_opt", "tx_online",
                 "tx_migrate", "tx_replan", "plan_search"):
        assert name in ALL_STRATEGIES


# ------------------------------------------------------ golden corpus
# Recorded from the seed (pre-registry if/elif) implementation: the
# refactored planner must reproduce the legacy strategies' schedules.
def _golden_cases():
    with open(GOLDEN_PATH) as f:
        return json.load(f)


@pytest.mark.parametrize("case", _golden_cases(),
                         ids=lambda c: f"{c['fact']}-T{c['n_tiles']}-{c['proc']}")
def test_legacy_strategies_match_seed_golden(case):
    graph = build_dag(case["fact"], case["n_tiles"], case["tile"],
                      tuple(case["grid"]))
    proc = make_processor(case["proc"])
    cost = CostModel()
    for strategy, exp in case["results"].items():
        sched = simulate(graph, proc, cost,
                         make_plan(strategy, graph, proc, cost))
        assert sched.switch_count == exp["switches"], strategy
        assert sched.makespan == pytest.approx(exp["makespan"], rel=1e-9), \
            strategy
        assert sched.total_energy_j() == pytest.approx(exp["energy"],
                                                       rel=1e-9), strategy
