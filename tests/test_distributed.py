"""Distributed (shard_map, 2-D block-cyclic) factorization correctness.

The main test body runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=4 so the grid is a real
2x2 mesh (the rest of the suite keeps seeing 1 device). The in-process
tests cover the degenerate 1x1 mesh path and the layout round-trip.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SUBPROCESS_BODY = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
import jax.numpy as jnp
import numpy as np

from repro.linalg import distributed as D

mesh = jax.make_mesh((2, 2), ("data", "model"))
rng = np.random.default_rng(0)

def spd(n):
    a = rng.standard_normal((n, n))
    return (a @ a.T + n * np.eye(n)).astype(np.float32)

def general(n):
    return (rng.standard_normal((n, n)) + 2 * np.eye(n)).astype(np.float32)

T, B = 4, 16
N = T * B

# --- cholesky -------------------------------------------------------------
a = spd(N)
l = np.asarray(D.factorize("cholesky", jnp.asarray(a), B, mesh))
np.testing.assert_allclose(l @ l.T, a, rtol=2e-4, atol=2e-3)
assert np.allclose(l, np.tril(l))
print("cholesky ok")

# --- lu (no pivoting; diagonally dominant input) ---------------------------
a = general(N) + N * np.eye(N, dtype=np.float32)
packed = np.asarray(D.factorize("lu", jnp.asarray(a), B, mesh))
lmat = np.tril(packed, -1) + np.eye(N)
umat = np.triu(packed)
np.testing.assert_allclose(lmat @ umat, a, rtol=2e-4, atol=2e-3)
print("lu ok")

# --- qr: R^T R == A^T A (Q orthogonality identity) --------------------------
a = general(N)
r = np.asarray(D.factorize("qr", jnp.asarray(a), B, mesh))
np.testing.assert_allclose(r.T @ r, a.T @ a, rtol=2e-3, atol=5e-2)
assert np.allclose(r, np.triu(r))
print("qr ok")

# --- qr-cholqr2 (hillclimbed panel): same identity --------------------------
r2 = np.asarray(D.factorize("qr-cholqr2", jnp.asarray(a), B, mesh))
np.testing.assert_allclose(r2.T @ r2, a.T @ a, rtol=2e-3, atol=5e-2)
print("qr-cholqr2 ok")

# --- non-square grid (4x1): exercises pr != pc ------------------------------
mesh41 = jax.make_mesh((4, 1), ("data", "model"))
a = spd(N)
l = np.asarray(D.factorize("cholesky", jnp.asarray(a), B, mesh41))
np.testing.assert_allclose(l @ l.T, a, rtol=2e-4, atol=2e-3)
print("cholesky 4x1 ok")
print("ALL OK")
"""


@pytest.mark.slow
def test_distributed_factorizations_4dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _SUBPROCESS_BODY],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    assert "ALL OK" in res.stdout


def test_block_cyclic_roundtrip():
    import jax.numpy as jnp
    from repro.linalg.distributed import from_block_cyclic, to_block_cyclic
    rng = np.random.default_rng(1)
    tiles = jnp.asarray(rng.standard_normal((8, 8, 3, 3)))
    for grid in [(2, 2), (4, 2), (2, 4), (1, 1), (8, 8)]:
        bc = to_block_cyclic(tiles, grid)
        back = from_block_cyclic(bc, grid)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(tiles))


def test_degenerate_single_device_mesh():
    """P=Q=1 mesh runs the same kernel in-process on 1 CPU device."""
    import jax
    import jax.numpy as jnp
    from repro.linalg import distributed as D

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rng = np.random.default_rng(2)
    n = 32
    a = rng.standard_normal((n, n))
    a = (a @ a.T + n * np.eye(n)).astype(np.float32)
    l = np.asarray(D.factorize("cholesky", jnp.asarray(a), 8, mesh))
    np.testing.assert_allclose(l @ l.T, a, rtol=2e-4, atol=2e-3)


def test_cholqr2_wy_form():
    """cholqr2's (W, T~, R) satisfies the same compact-WY contract as the
    Householder panel: Q_full = I - W T~ W^T orthogonal, Q_full^T A = [R;0]."""
    import jax.numpy as jnp
    from repro.kernels import ref
    rng = np.random.default_rng(7)
    m, b = 40, 8
    a = jnp.asarray(rng.standard_normal((m, b)).astype(np.float32))
    w, t_til, r = ref.cholqr2(a)
    wn, tn, rn = np.asarray(w), np.asarray(t_til), np.asarray(r)
    h = np.eye(m) - wn @ tn @ wn.T
    np.testing.assert_allclose(h @ h.T, np.eye(m), atol=1e-4)
    hta = h.T @ np.asarray(a)
    np.testing.assert_allclose(hta[:b], rn, atol=1e-4)
    np.testing.assert_allclose(hta[b:], 0.0, atol=1e-4)
    # identical trailing-update semantics as the Householder form
    c = rng.standard_normal((m, 5)).astype(np.float32)
    upd = c - wn @ (tn.T @ (wn.T @ c))
    np.testing.assert_allclose(upd, h.T @ c, atol=1e-4)


def test_householder_loop_matches_unrolled():
    import jax.numpy as jnp
    from repro.kernels import ref
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.standard_normal((24, 8)).astype(np.float32))
    v1, t1, r1 = ref.householder_qr_ref(a)
    v2, t2, r2 = ref.householder_qr_loop(a)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(t1), np.asarray(t2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=1e-5)
