"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes, plus the chunked-jnp attention path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gemm import gemm_pallas
from repro.kernels.potrf import potrf_pallas
from repro.kernels.syrk import syrk_pallas
from repro.kernels.trsm import trsm_pallas
from repro.kernels.ops import attention_chunked

jax.config.update("jax_enable_x64", False)


def _tol(dtype):
    return {"rtol": 2e-2, "atol": 2e-2} if dtype == jnp.bfloat16 \
        else {"rtol": 2e-5, "atol": 2e-5}


def _spd(key, n, dtype):
    a = jax.random.normal(key, (n, n), jnp.float32)
    return (a @ a.T / n + jnp.eye(n)).astype(dtype)


# ------------------------------------------------------------------- GEMM
@pytest.mark.parametrize("m,n,k", [(128, 128, 128), (256, 128, 384),
                                   (384, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_matches_ref(m, n, k, dtype):
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    a = jax.random.normal(k1, (m, k), dtype)
    b = jax.random.normal(k2, (k, n), dtype)
    c = jax.random.normal(k3, (m, n), dtype)
    got = gemm_pallas(a, b, c, alpha=-1.0, beta=1.0,
                      bm=128, bn=128, bk=128, interpret=True)
    want = ref.gemm_ref(a, b, c, alpha=-1.0, beta=1.0)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_gemm_no_c_operand():
    k1, k2 = jax.random.split(jax.random.key(1))
    a = jax.random.normal(k1, (256, 256), jnp.float32)
    b = jax.random.normal(k2, (256, 256), jnp.float32)
    got = gemm_pallas(a, b, bm=128, bn=128, bk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------------- SYRK
@pytest.mark.parametrize("m,k", [(256, 128), (256, 256), (384, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_syrk_matches_ref_lower(m, k, dtype):
    k1, k2 = jax.random.split(jax.random.key(2))
    a = jax.random.normal(k1, (m, k), dtype)
    c = jax.random.normal(k2, (m, m), dtype)
    got = syrk_pallas(a, c, alpha=-1.0, beta=1.0, bm=128, bk=128,
                      interpret=True)
    want = ref.syrk_ref(a, c, alpha=-1.0, beta=1.0)
    tril = np.tril_indices(m)
    np.testing.assert_allclose(np.asarray(got, np.float32)[tril],
                               np.asarray(want, np.float32)[tril],
                               **_tol(dtype))
    # strict upper blocks pass C through untouched (block granularity 128)
    np.testing.assert_allclose(np.asarray(got)[:128, 128:],
                               np.asarray(c)[:128, 128:])


# ------------------------------------------------------------------- TRSM
@pytest.mark.parametrize("m,nb", [(128, 128), (384, 128), (256, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_trsm_matches_ref(m, nb, dtype):
    k1, k2 = jax.random.split(jax.random.key(3))
    # well-conditioned L: unit-ish diagonal dominating the strict lower part
    l = jnp.tril(jax.random.normal(k1, (nb, nb), dtype), -1) / nb + \
        (1.0 + 0.1 * jnp.abs(jax.random.normal(k2, (nb,), dtype))) * \
        jnp.eye(nb, dtype=dtype)
    b = jax.random.normal(k2, (m, nb), dtype)
    got = trsm_pallas(l, b, bm=128, interpret=True)
    want = ref.trsm_ref(l, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # residual check: X L^T == B
    np.testing.assert_allclose(np.asarray(got @ l.T), np.asarray(b),
                               rtol=1e-4, atol=1e-4)


def test_trsm_unit_diag():
    k1, k2 = jax.random.split(jax.random.key(4))
    nb = 128
    l = jnp.tril(jax.random.normal(k1, (nb, nb)), -1) / nb + jnp.eye(nb)
    b = jax.random.normal(k2, (256, nb))
    got = trsm_pallas(l, b, unit_diag=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got @ l.T), np.asarray(b),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ POTRF
@pytest.mark.parametrize("n", [128, 256])
def test_potrf_matches_lapack(n):
    a = _spd(jax.random.key(5), n, jnp.float32)
    got = potrf_pallas(a, interpret=True)
    want = ref.potrf_ref(a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # reconstruction
    np.testing.assert_allclose(np.asarray(got @ got.T), np.asarray(a),
                               rtol=1e-4, atol=1e-4)


def test_potrf_matches_unblocked_ref_exactly():
    """Kernel algorithm == ref.potrf_unblocked_ref (same sweep order)."""
    a = _spd(jax.random.key(6), 128, jnp.float32)
    got = potrf_pallas(a, interpret=True)
    want = ref.potrf_unblocked_ref(a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


# -------------------------------------------------------------- attention
ATTN_CASES = [
    # (b, hq, hkv, sq, skv, causal, window, softcap)
    (2, 4, 4, 256, 256, True, None, None),       # MHA causal
    (2, 8, 2, 256, 256, True, None, None),       # GQA 4:1
    (1, 4, 1, 256, 256, True, None, None),       # MQA
    (2, 4, 2, 256, 256, True, 128, None),        # sliding window
    (1, 4, 4, 256, 256, True, None, 30.0),       # gemma softcap
    (1, 4, 2, 128, 256, True, None, None),       # decode-ish: kv longer
    (1, 2, 2, 256, 256, False, None, None),      # bidirectional (encoder)
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_pallas_vs_ref(case):
    b, hq, hkv, sq, skv, causal, window, softcap = case
    ks = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(ks[0], (b, hq, sq, 64), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, skv, 64), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, skv, 64), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 softcap=softcap, bq=128, bk=128,
                                 interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("case", ATTN_CASES)
def test_attention_chunked_vs_ref(case):
    b, hq, hkv, sq, skv, causal, window, softcap = case
    ks = jax.random.split(jax.random.key(8), 3)
    q = jax.random.normal(ks[0], (b, hq, sq, 64), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, skv, 64), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, skv, 64), jnp.float32)
    got = attention_chunked(q, k, v, causal=causal, window=window,
                            softcap=softcap, q_chunk=128, k_chunk=64)
    want = ref.attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_attention_chunked_grads_flow():
    ks = jax.random.split(jax.random.key(9), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 32))
    k = jax.random.normal(ks[1], (1, 2, 128, 32))
    v = jax.random.normal(ks[2], (1, 2, 128, 32))

    def loss(q, k, v):
        return attention_chunked(q, k, v, q_chunk=64, k_chunk=64).sum()

    g = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    for gi in g:
        assert np.isfinite(np.asarray(gi)).all()


def test_bf16_attention_tolerance():
    ks = jax.random.split(jax.random.key(10), 3)
    q = jax.random.normal(ks[0], (1, 4, 256, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 2, 256, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 2, 256, 64), jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, bq=128, bk=128, interpret=True)
    want = ref.attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                             v.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=3e-2, atol=3e-2)
