"""Tests for the expanded strategy family (PR 3): `task_type_gears`
(asymmetric per-task-type gear tables), `single_freq_opt` (optimal uniform
frequency under a makespan bound), and `tx_online` (TX planned from
noise-perturbed duration estimates).

Engine agreement for all three is covered by the differential suite (they
are registered, so `tests/test_scheduler_differential.py` auto-enrolls
them); this module checks the *policy* semantics:

  * task_type_gears confines every task's segments to its class table and
    never uses a gear the policy forbids;
  * single_freq_opt emits a uniform-gear plan whose simulated makespan
    respects the slowdown cap and whose energy is minimal among the
    feasible uniform candidates;
  * tx_online is deterministic for a fixed (seed, rel_err), bit-identical
    to `tx` at rel_err = 0, varies with the seed, and always executes the
    true work.
"""

import numpy as np
import pytest

from repro.core import (CostModel, PlanContext, StrategyConfig, build_dag,
                        duration_at, get_strategy, make_plan, make_processor,
                        registered_strategies, simulate, task_gear_classes)
from repro.core.tds import GEAR_CLASS_NAMES

PROC = make_processor("arc_opteron_6128")
COST = CostModel()
NEW_STRATEGIES = ("task_type_gears", "single_freq_opt", "tx_online")


def _ctx(fact="cholesky", n_tiles=8, tile=256, grid=(2, 2), cfg=None):
    return PlanContext(build_dag(fact, n_tiles, tile, grid), PROC, COST, cfg)


def _plans_equal(a, b):
    if len(a.task_segments) != len(b.task_segments):
        return False
    for sa, sb in zip(a.task_segments, b.task_segments):
        if [(g.index, t) for g, t in sa] != [(g.index, t) for g, t in sb]:
            return False
    return True


def test_new_strategies_registered():
    names = registered_strategies()
    for s in NEW_STRATEGIES:
        assert s in names


# ------------------------------------------------------------ task_type_gears
@pytest.mark.parametrize("fact", ["cholesky", "lu", "qr"])
def test_task_type_gears_confinement(fact):
    """Every task's segments stay inside its gear class's table."""
    ctx = _ctx(fact)
    plan = get_strategy("task_type_gears").plan(ctx)
    classes = task_gear_classes(ctx.graph)
    depth = ctx.cfg.kind_gear_depth
    allowed = [
        {g.index for g in PROC.gear_prefix(depth[name])}
        for name in GEAR_CLASS_NAMES
    ]
    assert any(len(a) < len(PROC.gears) for a in allowed)   # policy bites
    for tid, segs in enumerate(plan.task_segments):
        ok = allowed[classes[tid]]
        for g, _ in segs:
            assert g.index in ok, (fact, tid, ctx.graph.tasks[tid].kind)


def test_task_type_gears_panel_stays_on_top_gear():
    """Default policy: panel tasks never leave the top gear, whatever their
    slack."""
    ctx = _ctx("qr", n_tiles=6)
    plan = get_strategy("task_type_gears").plan(ctx)
    classes = task_gear_classes(ctx.graph)
    for tid in np.flatnonzero(classes == 0):
        for g, _ in plan.task_segments[tid]:
            assert g.index == 0


def test_task_type_gears_custom_depths():
    """Restricting the update class is honored (all classes on top 2 gears)."""
    cfg = StrategyConfig(kind_gear_depth={"panel": 0.0, "solve": 0.25,
                                          "update": 0.25})
    ctx = _ctx(cfg=cfg)
    plan = get_strategy("task_type_gears").plan(ctx)
    deepest = max(g.index for segs in plan.task_segments for g, _ in segs)
    assert deepest <= len(PROC.gear_prefix(0.25)) - 1


# ------------------------------------------------------------ single_freq_opt
def test_single_freq_opt_is_uniform():
    ctx = _ctx()
    plan = get_strategy("single_freq_opt").plan(ctx)
    gears = {g.index for segs in plan.task_segments for g, _ in segs}
    assert len(gears) == 1


def test_single_freq_opt_respects_makespan_cap():
    for cap in (0.0, 0.05, 0.5, 10.0):
        cfg = StrategyConfig(single_freq_slowdown_cap=cap)
        ctx = _ctx(cfg=cfg)
        plan = get_strategy("single_freq_opt").plan(ctx)
        sched = simulate(ctx.graph, PROC, COST, plan)
        assert sched.makespan <= ctx.baseline.makespan * (1.0 + cap) + 1e-9


def test_single_freq_opt_minimizes_among_feasible():
    """Re-enumerate the uniform candidates by hand; the chosen one must be
    the cheapest feasible."""
    from repro.core.scheduler import StrategyPlan
    cfg = StrategyConfig(single_freq_slowdown_cap=0.5)
    ctx = _ctx(cfg=cfg)
    plan = get_strategy("single_freq_opt").plan(ctx)
    chosen = simulate(ctx.graph, PROC, COST, plan)
    cap = ctx.baseline.makespan * 1.5
    best_e = None
    for gear in PROC.gears:
        segs = [[(gear, duration_at(float(d), PROC.f_max, gear.freq_ghz,
                                    float(b)))]
                for d, b in zip(ctx.durations, ctx.betas)]
        cand = StrategyPlan("u", segs, idle_gear=PROC.gears[-1],
                            per_task_overhead=np.zeros(ctx.n_tasks),
                            hide_switch_in_wait=True)
        sched = simulate(ctx.graph, PROC, COST, cand)
        if sched.makespan <= cap + 1e-12:
            e = sched.total_energy_j()
            best_e = e if best_e is None else min(best_e, e)
    assert chosen.total_energy_j() == pytest.approx(best_e, rel=1e-9)


def test_single_freq_opt_loose_cap_picks_cheaper_gear():
    """Where dynamic (f V^2) energy dominates -- steep-voltage ladder, no
    nodal constant -- an unbounded cap makes a lower gear the optimum. (On
    the ARC model the 150 W nodal constant keeps the top gear optimal: the
    paper's flat-voltage conclusion; covered by the cap=0 case above.)"""
    proc = make_processor("amd_opteron_846", p_const_watts=0.0,
                          i_sub_amps=0.0)
    cfg = StrategyConfig(single_freq_slowdown_cap=100.0)
    ctx = PlanContext(build_dag("cholesky", 8, 256, (2, 2)), proc, COST, cfg)
    plan = get_strategy("single_freq_opt").plan(ctx)
    (gear,) = {g.index for segs in plan.task_segments for g, _ in segs}
    assert gear > 0


# ------------------------------------------------------------------ tx_online
def test_tx_online_deterministic():
    """Same seed + rel_err => bit-identical plans across calls/contexts."""
    cfg = StrategyConfig(tx_online_rel_err=0.2, tx_online_seed=42)
    p1 = get_strategy("tx_online").plan(_ctx(cfg=cfg))
    p2 = get_strategy("tx_online").plan(_ctx(cfg=cfg))
    assert _plans_equal(p1, p2)


def test_tx_online_seed_changes_plan():
    a = get_strategy("tx_online").plan(
        _ctx(cfg=StrategyConfig(tx_online_rel_err=0.3, tx_online_seed=0)))
    b = get_strategy("tx_online").plan(
        _ctx(cfg=StrategyConfig(tx_online_rel_err=0.3, tx_online_seed=1)))
    assert not _plans_equal(a, b)


def test_tx_online_zero_error_equals_tx():
    """rel_err = 0 must reproduce the offline TX plan exactly."""
    cfg = StrategyConfig(tx_online_rel_err=0.0)
    ctx = _ctx(cfg=cfg)
    online = get_strategy("tx_online").plan(ctx)
    offline = get_strategy("tx").plan(ctx)
    assert _plans_equal(online, offline)


def test_tx_online_executes_true_work():
    """Whatever the noise, the emitted segments perform the task's real
    work (the planner may misjudge the *window*, never the work)."""
    cfg = StrategyConfig(tx_online_rel_err=0.4, tx_online_seed=7)
    ctx = _ctx(cfg=cfg)
    plan = get_strategy("tx_online").plan(ctx)
    for tid, segs in enumerate(plan.task_segments):
        d = float(ctx.durations[tid])
        if d <= 0.0 or not segs:
            continue
        b = float(ctx.betas[tid])
        work = sum(t / duration_at(d, PROC.f_max, g.freq_ghz, b)
                   for g, t in segs)
        assert work == pytest.approx(1.0, rel=1e-9), tid


def test_tx_online_rejects_invalid_rel_err():
    """err >= 1 could drive an estimate negative; must be refused."""
    for bad in (1.0, 1.5, -0.1):
        cfg = StrategyConfig(tx_online_rel_err=bad)
        with pytest.raises(ValueError):
            get_strategy("tx_online").plan(_ctx(n_tiles=3, cfg=cfg))


def test_tx_online_savings_degrade_with_noise():
    """More cost-model error must not *improve* realized savings (checked on
    the seed-averaged trend ends: perfect knowledge vs 40% error)."""
    graph = build_dag("cholesky", 8, 512, (2, 2))

    def mean_saved(err):
        vals = []
        for seed in range(3):
            cfg = StrategyConfig(tx_online_rel_err=err, tx_online_seed=seed)
            ctx = PlanContext(graph, PROC, COST, cfg)
            ref = ctx.baseline
            sched = simulate(graph, PROC, COST,
                             get_strategy("tx_online").plan(ctx))
            vals.append(1.0 - sched.total_energy_j() / ref.total_energy_j())
        return float(np.mean(vals))

    assert mean_saved(0.0) > mean_saved(0.4)


def test_strategy_config_rejects_unknown_knob():
    """A misspelled knob set after construction used to pass silently and
    leave the real knob at its default; it must raise, naming the bad
    knob and the valid set (constructor typos already die in __init__)."""
    cfg = StrategyConfig()
    with pytest.raises(ValueError, match="tx_panel_slack_us"):
        cfg.tx_panel_slack_us = 1.0
    with pytest.raises(ValueError, match="plan_search_rounds"):
        cfg.plan_search_round = 9           # singular typo of a real knob
    cfg.plan_search_rounds = 9              # the real knob still settable
    assert cfg.plan_search_rounds == 9
    with pytest.raises(TypeError):
        StrategyConfig(not_a_knob=1)


def test_make_plan_dispatches_new_strategies():
    g = build_dag("lu", 5, 256, (2, 2))
    for name in NEW_STRATEGIES:
        plan = make_plan(name, g, PROC, COST)
        assert plan.name == name
        assert len(plan.task_segments) == len(g.tasks)
