"""Tests for closed-loop online re-planning (`core/replan.py`, ISSUE 5).

Engine agreement for `tx_replan` is covered by the differential suite (it
is registered, so `tests/test_scheduler_differential.py` auto-enrolls it);
this module checks the *policy* and *substrate* semantics:

  * fixed points -- with `rel_err = 0` the composite plan is bit-identical
    to `tx` (the "model" anchor makes perfect knowledge a provable fixed
    point of the wave loop), and a single-wave run (`replan_every` >= the
    iteration count) is bit-identical to `tx_online`;
  * retention -- on a seeded noise sweep (the `strategy_gap` benchmark's
    configuration), `tx_replan`'s mean realized savings are never worse
    than `tx_online`'s at any error level, for both anchoring modes;
  * residual substrate -- `residual_schedule_times`, `analyze_residual_tds`
    and `PlanContext.restricted_to` invariants on hand-built DAGs where
    the anchored starts/waits/slacks are derivable on paper, plus the
    closure validation that rejects ill-formed frozen sets;
  * driver bookkeeping -- wave partitioning, commit counts, trace records,
    and config validation.
"""

import numpy as np
import pytest

from repro.core import (CostModel, PlanContext, StrategyConfig, build_dag,
                        make_big_little, make_plan, make_processor,
                        registered_strategies, replan_tx, simulate)
from repro.core.critical_path import (residual_schedule_times,
                                      validate_frozen_closure)
from repro.core.dag import Task, TaskGraph
from repro.core.replan import iteration_waves
from repro.core.tds import WAIT_NONE, WAIT_PANEL, analyze_residual_tds

PROC = make_processor("arc_opteron_6128")
COST = CostModel()


def _ctx(fact="cholesky", n_tiles=8, tile=512, grid=(2, 2), cfg=None,
         proc=PROC):
    return PlanContext(build_dag(fact, n_tiles, tile, grid), proc, COST, cfg)


def _segments_identical(a, b):
    """Exact (gear-index, seconds) equality of two plans' segment lists."""
    if len(a.task_segments) != len(b.task_segments):
        return False
    return all([(g.index, t) for g, t in sa] == [(g.index, t) for g, t in sb]
               for sa, sb in zip(a.task_segments, b.task_segments))


# ------------------------------------------------------------- registration
def test_registered_and_enrolled():
    """tx_replan is in the registry => auto-enrolled in the differential
    suite (which parametrizes over `registered_strategies()`)."""
    assert "tx_replan" in registered_strategies()


# -------------------------------------------------------------- fixed points
@pytest.mark.parametrize("fact", ["cholesky", "lu", "qr"])
def test_zero_error_plan_identical_to_tx(fact):
    """rel_err = 0: every wave re-derives the perfect-knowledge TX plan,
    so the composite is bit-identical to the one-shot `tx`."""
    cfg = StrategyConfig(tx_online_rel_err=0.0)
    ctx = _ctx(fact, n_tiles=6, cfg=cfg)
    out = replan_tx(ctx)
    tx = make_plan("tx", ctx.graph, PROC, COST, cfg)
    assert out.n_waves == 6
    assert _segments_identical(out.plan, tx)


def test_zero_error_plan_identical_to_tx_heterogeneous():
    """The fixed point survives per-rank machines (per-owner floors and
    per-ladder splits throughout)."""
    machine = make_big_little(n_big=2, n_little=2)
    cfg = StrategyConfig(tx_online_rel_err=0.0)
    ctx = _ctx("cholesky", n_tiles=6, cfg=cfg, proc=machine)
    out = replan_tx(ctx)
    tx = make_plan("tx", ctx.graph, machine, COST, cfg)
    assert _segments_identical(out.plan, tx)


def test_single_wave_equals_tx_online():
    """replan_every >= iteration count => one wave => exactly the
    tx_online plan (same seeded noise draw, same policy, same rescale)."""
    cfg = StrategyConfig(tx_online_rel_err=0.25, tx_online_seed=5,
                         replan_every=1000)
    ctx = _ctx(cfg=cfg)
    out = replan_tx(ctx)
    online = make_plan("tx_online", ctx.graph, PROC, COST, cfg)
    assert out.n_waves == 1
    assert _segments_identical(out.plan, online)


def test_deterministic():
    """Same (seed, rel_err, cadence) => identical plans across calls."""
    cfg = StrategyConfig(tx_online_rel_err=0.2, tx_online_seed=11)
    a = replan_tx(_ctx(cfg=cfg)).plan
    b = replan_tx(_ctx(cfg=cfg)).plan
    assert _segments_identical(a, b)


def test_executes_true_work():
    """Whatever the noise and cadence, the committed segments perform each
    task's real work (the planner may misjudge windows, never the work)."""
    from repro.core import duration_at
    cfg = StrategyConfig(tx_online_rel_err=0.4, tx_online_seed=7,
                         replan_every=2)
    ctx = _ctx(cfg=cfg)
    plan = replan_tx(ctx).plan
    for tid, segs in enumerate(plan.task_segments):
        d = float(ctx.durations[tid])
        if d <= 0.0 or not segs:
            continue
        b = float(ctx.betas[tid])
        work = sum(t / duration_at(d, PROC.f_max, g.freq_ghz, b)
                   for g, t in segs)
        assert work == pytest.approx(1.0, rel=1e-9), tid


# ------------------------------------------------------------------ retention
def _mean_saved(graph, name, err, seeds=(0, 1, 2), **cfg_kw):
    base = simulate(graph, PROC, COST,
                    make_plan("original", graph, PROC, COST))
    e0 = base.total_energy_j()
    vals = []
    for seed in seeds:
        cfg = StrategyConfig(tx_online_rel_err=err, tx_online_seed=seed,
                             **cfg_kw)
        sched = simulate(graph, PROC, COST,
                         make_plan(name, graph, PROC, COST, cfg))
        vals.append(1.0 - sched.total_energy_j() / e0)
    return float(np.mean(vals))


@pytest.mark.parametrize("anchor", ["model", "observed"])
def test_retention_never_worse_than_tx_online(anchor):
    """Seeded sweep (the strategy_gap benchmark's graph): at every error
    level the closed loop retains at least tx_online's savings."""
    graph = build_dag("cholesky", 8, 512, (2, 2))
    for err in (0.0, 0.05, 0.10, 0.20, 0.40):
        online = _mean_saved(graph, "tx_online", err)
        closed = _mean_saved(graph, "tx_replan", err, replan_anchor=anchor)
        assert closed >= online - 1e-12, (anchor, err, online, closed)


def test_equal_savings_at_zero_error():
    """rel_err = 0 (default "model" anchor): savings equal tx's exactly."""
    graph = build_dag("cholesky", 8, 512, (2, 2))
    tx = simulate(graph, PROC, COST,
                  make_plan("tx", graph, PROC, COST,
                            StrategyConfig(tx_online_rel_err=0.0)))
    rp = simulate(graph, PROC, COST,
                  make_plan("tx_replan", graph, PROC, COST,
                            StrategyConfig(tx_online_rel_err=0.0)))
    assert rp.total_energy_j() == tx.total_energy_j()
    assert rp.makespan == tx.makespan


# ------------------------------------------------- residual substrate (tiny)
def _task(tid, kind, owner, flops, deps, tile):
    return Task(tid=tid, kind=kind, k=0, i=tile[0], j=tile[1], owner=owner,
                flops=flops, deps=deps, out_tile=tile)


def _graph(tasks, grid=(1, 2)):
    return TaskGraph("synthetic", n_tiles=2, tile_size=128, grid=grid,
                     tasks=tasks)


def test_residual_times_no_frozen_matches_baseline():
    """With nothing frozen the residual recursion IS the baseline,
    bit-identically, for all three factorizations."""
    for fact in ("cholesky", "lu", "qr"):
        ctx = _ctx(fact, n_tiles=5, tile=256)
        start, finish = residual_schedule_times(
            ctx.graph, ctx.durations, COST.comm_time(ctx.graph))
        base = ctx.baseline
        np.testing.assert_array_equal(start, base.start, err_msg=fact)
        np.testing.assert_array_equal(finish, base.finish, err_msg=fact)


def test_residual_times_anchor_on_observed():
    """A frozen producer's observed (late) finish pushes its consumer's
    predicted start by exactly the observation + wire time."""
    g = _graph([
        _task(0, "POTRF", 0, 1e9, [], (0, 0)),
        _task(1, "TRSM", 1, 1e8, [0], (1, 0)),
    ])
    d = COST.durations_top(g, PROC)
    comm = COST.comm_time(g)
    frozen = np.array([True, False])
    late = float(d[0]) * 3.0
    start, finish = residual_schedule_times(
        g, d, comm, frozen=frozen, observed_finish=np.array([late, 0.0]))
    assert start[1] == late + comm
    assert finish[1] == start[1] + d[1]


def test_residual_slack_and_tds_masking():
    """Frozen entries come back neutral; pending entries match a hand
    derivation: B waits on frozen A's observed finish (panel wait), C's
    slack is bounded by the makespan."""
    g = _graph([
        _task(0, "POTRF", 0, 1e9, [], (0, 0)),     # frozen
        _task(1, "TRSM", 1, 1e8, [0], (1, 0)),     # pending, rank 1
        _task(2, "GEMM", 0, 5e7, [], (0, 1)),      # pending, rank 0
    ])
    d = COST.durations_top(g, PROC)
    comm = COST.comm_time(g)
    frozen = np.array([True, False, False])
    obs = np.array([float(d[0]) * 2.0, 0.0, 0.0])
    start, finish = residual_schedule_times(g, d, comm, frozen=frozen,
                                            observed_finish=obs)
    tds = analyze_residual_tds(g, start, finish, comm, pending=~frozen)
    # frozen task: fully neutral
    assert tds.wait_class[0] == WAIT_NONE
    assert tds.slack_s[0] == 0.0
    assert tds.binding_dep[0] == -1 and tds.binding_consumer[0] == -1
    # B is rank 1's head: waits from 0 until A's observed output arrives
    assert tds.wait_s[1] == pytest.approx(obs[0] + comm)
    assert tds.wait_class[1] == WAIT_PANEL
    assert tds.binding_dep[1] == 0
    # C runs immediately after frozen A on rank 0; its slack reaches the
    # makespan (B finishes last)
    assert start[2] == obs[0]
    assert tds.slack_s[2] == pytest.approx(finish[1] - finish[2])


def test_restricted_to_all_pending_matches_parent():
    """An all-pending view anchored on the parent baseline's finishes
    reproduces the parent's slack/TDS bit-identically."""
    ctx = _ctx("lu", n_tiles=5, tile=256)
    view = ctx.restricted_to(np.ones(ctx.n_tasks, dtype=bool),
                             ctx.baseline.finish)
    np.testing.assert_array_equal(view.slack, ctx.slack)
    np.testing.assert_array_equal(view.tds.slack_class, ctx.tds.slack_class)
    np.testing.assert_array_equal(view.tds.wait_s, ctx.tds.wait_s)


def test_restricted_to_validates_shapes():
    ctx = _ctx(n_tiles=3)
    with pytest.raises(ValueError):
        ctx.restricted_to(np.ones(2, dtype=bool), np.zeros(ctx.n_tasks))
    with pytest.raises(ValueError):
        ctx.restricted_to(np.ones(ctx.n_tasks, dtype=bool), np.zeros(3))


def test_frozen_closure_validation():
    """Non-prefix / non-dependency-closed frozen sets are rejected."""
    g = _graph([
        _task(0, "POTRF", 0, 1e9, [], (0, 0)),
        _task(1, "GEMM", 0, 1e8, [], (0, 1)),      # independent, same rank
        _task(2, "GEMM", 1, 1e8, [1], (1, 1)),
    ])
    # freezing a consumer without its dependency
    with pytest.raises(ValueError, match="dependency-closed"):
        validate_frozen_closure(g, np.array([False, False, True]))
    # freezing rank 0's 2nd task without its 1st (deps are fine: none)
    with pytest.raises(ValueError, match="prefix"):
        validate_frozen_closure(g, np.array([False, True, False]))
    # a valid prefix passes
    validate_frozen_closure(g, np.array([True, True, False]))
    with pytest.raises(ValueError, match="observed_finish"):
        residual_schedule_times(g, np.ones(3), 0.0,
                                frozen=np.array([True, False, False]))


# ------------------------------------------------------------ driver details
def test_iteration_waves_partition():
    g = build_dag("cholesky", 7, 256, (2, 2))
    for every, expect in ((1, 7), (2, 4), (3, 3), (7, 1), (100, 1)):
        w = iteration_waves(g, every)
        assert int(w.max()) + 1 == expect, every
        # wave ids are non-decreasing in iteration k
        iters = np.asarray([t.k for t in g.tasks])
        order = np.argsort(iters, kind="stable")
        assert (np.diff(w[order]) >= 0).all()
    with pytest.raises(ValueError):
        iteration_waves(g, 0)


def test_wave_records():
    cfg = StrategyConfig(tx_online_rel_err=0.2, replan_every=2)
    ctx = _ctx(n_tiles=7, cfg=cfg)
    out = replan_tx(ctx)
    assert out.n_waves == 4
    assert sum(w.n_committed for w in out.waves) == ctx.n_tasks
    assert out.waves[0].n_observed == 0 and out.waves[0].max_drift_s == 0.0
    observed = [w.n_observed for w in out.waves]
    assert observed == sorted(observed) and observed[-1] > 0
    # under noise the loop must actually be observing drift
    assert any(w.max_drift_s > 0.0 for w in out.waves[1:])


def test_invalid_config_rejected():
    ctx = _ctx(n_tiles=3)
    with pytest.raises(ValueError, match="replan_every"):
        replan_tx(ctx, every=0)
    with pytest.raises(ValueError, match="replan_anchor"):
        replan_tx(ctx, anchor="psychic")
    with pytest.raises(ValueError, match="tx_online_rel_err"):
        replan_tx(_ctx(n_tiles=3,
                       cfg=StrategyConfig(tx_online_rel_err=1.5)))


def test_make_plan_dispatches():
    g = build_dag("qr", 4, 256, (2, 2))
    plan = make_plan("tx_replan", g, PROC, COST)
    assert plan.name == "tx_replan"
    assert len(plan.task_segments) == len(g.tasks)
