"""Blocked + tiled factorization correctness (pure-jnp backend on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.linalg import (cholesky_blocked, dense_to_tiles, lu_blocked_nopiv,
                          qr_blocked, tiled_cholesky, tiled_lu, tiled_qr,
                          tiles_to_dense)

@pytest.fixture(autouse=True, scope="module")
def _x64():
    """fp64 for tight factorization tolerances -- restored afterwards so
    other test modules see the default dtype regime."""
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", prev)


def _spd(key, n):
    a = jax.random.normal(key, (n, n), jnp.float64)
    return a @ a.T / n + 2.0 * jnp.eye(n)


def _diag_dominant(key, n):
    a = jax.random.normal(key, (n, n), jnp.float64)
    return a / n + 2.0 * jnp.eye(n)


@pytest.mark.parametrize("n,block", [(64, 16), (128, 32), (96, 32)])
def test_cholesky_blocked(n, block):
    a = _spd(jax.random.key(0), n)
    l = cholesky_blocked(a, block)
    np.testing.assert_allclose(np.asarray(l @ l.T), np.asarray(a),
                               rtol=1e-10, atol=1e-10)
    np.testing.assert_allclose(np.asarray(l), np.asarray(jnp.tril(l)))


@pytest.mark.parametrize("n,block", [(64, 16), (128, 32)])
def test_lu_blocked(n, block):
    a = _diag_dominant(jax.random.key(1), n)
    lu = lu_blocked_nopiv(a, block)
    l = jnp.tril(lu, -1) + jnp.eye(n)
    u = jnp.triu(lu)
    np.testing.assert_allclose(np.asarray(l @ u), np.asarray(a),
                               rtol=1e-10, atol=1e-10)


@pytest.mark.parametrize("n,block", [(64, 16), (96, 32)])
def test_qr_blocked(n, block):
    a = jax.random.normal(jax.random.key(2), (n, n), jnp.float64)
    q, r = qr_blocked(a, block)
    np.testing.assert_allclose(np.asarray(q @ r), np.asarray(a),
                               rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(np.asarray(q.T @ q), np.eye(n),
                               rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(np.asarray(r), np.asarray(jnp.triu(r)))


def test_tile_roundtrip():
    a = jax.random.normal(jax.random.key(3), (96, 96))
    tm = dense_to_tiles(a, 32)
    assert tm.tiles.shape == (3, 3, 32, 32)
    np.testing.assert_allclose(np.asarray(tiles_to_dense(tm)), np.asarray(a))


@pytest.mark.parametrize("n,tile", [(64, 16), (128, 32)])
def test_tiled_cholesky_matches_blocked(n, tile):
    a = _spd(jax.random.key(4), n)
    l_tiled = tiles_to_dense(tiled_cholesky(dense_to_tiles(a, tile)))
    np.testing.assert_allclose(np.asarray(l_tiled @ l_tiled.T),
                               np.asarray(a), rtol=1e-10, atol=1e-10)
    l_blocked = cholesky_blocked(a, tile)
    np.testing.assert_allclose(np.asarray(l_tiled), np.asarray(l_blocked),
                               rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("n,tile", [(64, 16), (128, 32)])
def test_tiled_lu(n, tile):
    a = _diag_dominant(jax.random.key(5), n)
    lu = tiles_to_dense(tiled_lu(dense_to_tiles(a, tile)))
    l = jnp.tril(lu, -1) + jnp.eye(n)
    u = jnp.triu(lu)
    np.testing.assert_allclose(np.asarray(l @ u), np.asarray(a),
                               rtol=1e-10, atol=1e-10)


@pytest.mark.parametrize("n,tile", [(64, 16), (96, 32)])
def test_tiled_qr_r_factor(n, tile):
    """R from tiled QR satisfies R^T R == A^T A (Q orthogonality implied)."""
    a = jax.random.normal(jax.random.key(6), (n, n), jnp.float64)
    r = tiles_to_dense(tiled_qr(dense_to_tiles(a, tile)))
    np.testing.assert_allclose(np.asarray(r), np.asarray(jnp.triu(r)))
    np.testing.assert_allclose(np.asarray(r.T @ r), np.asarray(a.T @ a),
                               rtol=1e-9, atol=1e-9)
    # |R| matches the LAPACK R up to column signs
    _, r_ref = jnp.linalg.qr(a)
    np.testing.assert_allclose(np.abs(np.asarray(r)),
                               np.abs(np.asarray(r_ref)),
                               rtol=1e-8, atol=1e-8)
