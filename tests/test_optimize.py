"""Tests for the batched plan search (PR 7): `CandidateEvaluator` must
score candidate extra-time vectors exactly as the fast engine scores the
equivalent `StrategyPlan`s (bit-identical makespans, 1e-9-relative
energies -- the contract `benchmarks/sim_speed.py` times), and
`search_plan` must respect its slowdown cap, dominate every registered
heuristic on the same context, and be deterministic for a fixed seed.
The engine-agreement side of plan_search itself (fast vs reference vs
fleet on its emitted plan) is covered by the differential suite.
"""

import numpy as np
import pytest

from repro.core import (CandidateEvaluator, CostModel, PlanContext,
                        StrategyConfig, StrategyPlan, build_dag,
                        get_strategy, make_big_little, make_processor,
                        registered_strategies, simulate)

COST = CostModel()
MACHINES = {
    "homog": make_processor("arc_opteron_6128"),
    "big_little": make_big_little("arc_opteron_6128"),
}


def _ctx(machine, fact="cholesky", n_tiles=6, tile=256, grid=(2, 2),
         cfg=None):
    return PlanContext(build_dag(fact, n_tiles, tile, grid),
                       MACHINES[machine], COST, cfg)


def _serial_score(ctx, e):
    """What a search WITHOUT the batched evaluator would compute: render
    the candidate through `reclaimed_segments` and run `simulate`."""
    idle, rank_idle = ctx._idle_gears(-1)
    plan = StrategyPlan("cand", ctx.reclaimed_segments(e, 0.0),
                        idle_gear=idle,
                        per_task_overhead=np.zeros(ctx.n_tasks),
                        hide_switch_in_wait=True,
                        rank_idle_gears=rank_idle)
    s = simulate(ctx.graph, ctx.proc, ctx.cost, plan)
    return s.total_energy_j(), s.makespan


@pytest.mark.parametrize("machine", sorted(MACHINES))
@pytest.mark.parametrize("fact", ["cholesky", "lu", "qr"])
def test_evaluator_matches_fast_engine(machine, fact):
    """37 random candidates through a 16-lane evaluator (odd chunking:
    16 + 16 + 5) must reproduce the fast engine's (energy, makespan)
    pair for every row, including an all-zero row (the baseline plan)."""
    ctx = _ctx(machine, fact)
    n = ctx.n_tasks
    rng = np.random.default_rng(3)
    slack = np.maximum(ctx.slack, 0.0)
    E = (slack[None, :] * rng.uniform(0.0, 1.4, (37, n))
         + rng.uniform(0.0, 0.15, (37, n)) * ctx.durations[None, :])
    E[5] = 0.0
    ev = CandidateEvaluator(ctx, 16)
    energy, make = ev.evaluate(E)
    for i in range(len(E)):
        e_ref, m_ref = _serial_score(ctx, E[i])
        assert make[i] == m_ref, (machine, fact, i)
        assert energy[i] == pytest.approx(e_ref, rel=1e-9), (machine, fact, i)


def test_evaluator_rejects_wrong_width():
    ctx = _ctx("homog")
    with pytest.raises(ValueError):
        CandidateEvaluator(ctx).evaluate(np.zeros((3, ctx.n_tasks + 1)))


def test_evaluator_buffers_reused_across_calls():
    """Back-to-back evaluations of different batches must not leak state
    between calls (the buffers are preallocated and reused)."""
    ctx = _ctx("big_little")
    ev = CandidateEvaluator(ctx, 8)
    slack = np.maximum(ctx.slack, 0.0)
    a1, _ = ev.evaluate(slack[None, :])
    ev.evaluate(np.zeros((11, ctx.n_tasks)))          # dirty the buffers
    a2, _ = ev.evaluate(slack[None, :])
    assert a1[0] == a2[0]


@pytest.mark.parametrize("machine", sorted(MACHINES))
def test_search_respects_slowdown_cap(machine):
    for cap in (0.0, 0.05):
        cfg = StrategyConfig(plan_search_slowdown_cap=cap,
                             plan_search_rounds=2, plan_search_lanes=96)
        ctx = _ctx(machine, cfg=cfg)
        plan = get_strategy("plan_search").plan(ctx)
        sched = simulate(ctx.graph, ctx.proc, COST, plan)
        assert sched.makespan <= ctx.baseline.makespan * (1.0 + cap) + 1e-9


@pytest.mark.parametrize("machine", sorted(MACHINES))
def test_search_dominates_every_heuristic(machine):
    """The peer-seeded search must never lose to a registered heuristic
    that itself stays under the cap (the oracle_gap denominator
    guarantee)."""
    ctx = _ctx(machine)
    cap = ctx.baseline.makespan * (1.0 + ctx.cfg.plan_search_slowdown_cap)
    best = simulate(ctx.graph, ctx.proc, COST,
                    get_strategy("plan_search").plan(ctx)).total_energy_j()
    for name in registered_strategies():
        if name in ("plan_search", "original"):
            continue
        sched = simulate(ctx.graph, ctx.proc, COST,
                         get_strategy(name).plan(ctx))
        if sched.makespan <= cap + 1e-12:
            assert best <= sched.total_energy_j() * (1.0 + 1e-7), \
                (machine, name)


def test_search_deterministic():
    cfg = StrategyConfig(plan_search_seed=11, plan_search_rounds=2)
    p1 = get_strategy("plan_search").plan(_ctx("homog", cfg=cfg))
    p2 = get_strategy("plan_search").plan(_ctx("homog", cfg=cfg))
    assert len(p1.task_segments) == len(p2.task_segments)
    for sa, sb in zip(p1.task_segments, p2.task_segments):
        assert [(g.index, t) for g, t in sa] == [(g.index, t) for g, t in sb]


def test_search_plan_name_and_registration():
    assert "plan_search" in registered_strategies()
    plan = get_strategy("plan_search").plan(_ctx("homog", n_tiles=4))
    assert plan.name == "plan_search"
