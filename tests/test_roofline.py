"""Roofline pipeline gates: the committed artifact's schema, the golden
pin of one config's rows, the beta derivation, the roofline-derived
serving profiles, and the three-engine differential under
roofline-informed betas (docs/ROOFLINE.md).

The load-bearing pins:

  * schema validity      -- `results/roofline.json` is a ``roofline/v2``
                            document with all 11 configs x 3 phases and
                            internally consistent rows (bottleneck is the
                            argmax term, beta = floored compute fraction,
                            terms match the hardware constants);
  * golden stability     -- the gemma2-2b rows match
                            `tests/data/roofline_golden.json` bit-for-bit
                            (modulo compile timing): the generator is
                            deterministic for a pinned jax version;
  * measured profiles    -- `MODEL_PROFILES` on a fresh checkout is
                            roofline-derived (decode anchored, measured
                            ratio + betas), NOT the hand-set fallback;
  * engine lockstep      -- roofline-informed betas flow to all three
                            engines purely through `CostModel`
                            (the PR 5 corollary: plans carry `(gear,
                            seconds)` segments, so no engine changes).
"""

import json
import math
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.configs import list_archs
from repro.core import (BETA_FLOOR, DECODE_FLOPS_ANCHORS, FAMILY_ARCHS,
                        MODEL_PROFILES, PlanContext, StrategyConfig,
                        beta_from_terms, build_serving_graph, get_strategy,
                        load_roofline, make_server_proc, make_trace,
                        profile_for_arch,
                        profiles_from_roofline, registered_strategies,
                        roofline_cost_model, serving_cost_model,
                        serving_machine, simulate, simulate_fleet,
                        simulate_reference)
from repro.core.roofline_model import PHASES, RooflineTable
from repro.core.serving import _HAND_SET_PROFILES

REPO = os.path.join(os.path.dirname(__file__), "..")
ARTIFACT = os.path.join(REPO, "results", "roofline.json")
GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "roofline_golden.json")

ROW_FIELDS = {
    "arch", "family", "phase", "seq_len", "global_batch", "tokens",
    "dot_flops_per_device", "hbm_bytes_per_device", "ici_bytes_per_device",
    "dcn_bytes_per_device", "compute_s", "memory_s", "collective_s",
    "step_s_lower_bound", "bottleneck", "arithmetic_intensity", "beta",
    "flops_per_token", "model_flops_global", "useful_flop_ratio", "n_while",
    "compile_s",
}

# generator-dependent timing, excluded from golden comparison
TIMING_FIELDS = ("compile_s",)


# ----------------------------------------------------------- schema gate
def test_artifact_exists_and_loads():
    """The committed artifact parses as a roofline/v2 document."""
    table = load_roofline()
    assert table.meta["schema"].startswith("roofline/")
    assert table.meta["n_devices"] == 8
    assert table.meta["beta_floor"] == BETA_FLOOR
    hw = table.meta["hardware"]
    assert set(hw) == {"peak_flops", "hbm_bw", "ici_bw", "dcn_bw"}


def test_artifact_covers_the_full_zoo():
    """One row per (registered arch x phase) -- 11 x 3."""
    table = load_roofline()
    assert set(table.archs()) == set(list_archs())
    for arch in list_archs():
        for phase in PHASES:
            assert table.get(arch, phase)["phase"] == phase


def test_rows_are_internally_consistent():
    table = load_roofline()
    hw = table.meta["hardware"]
    for r in table.rows:
        assert set(r) == ROW_FIELDS, r["arch"]
        terms = {k: r[k] for k in ("compute_s", "memory_s", "collective_s")}
        assert r["bottleneck"] == max(terms, key=lambda k: terms[k])
        assert r["step_s_lower_bound"] == pytest.approx(max(terms.values()))
        # beta is the floored compute fraction of the binding term
        assert r["beta"] == pytest.approx(
            beta_from_terms(**terms), rel=1e-4)
        assert BETA_FLOOR <= r["beta"] <= 1.0
        # terms come from the per-device counts at the header constants
        assert r["compute_s"] == pytest.approx(
            r["dot_flops_per_device"] / hw["peak_flops"], rel=1e-4)
        assert r["memory_s"] == pytest.approx(
            r["hbm_bytes_per_device"] / hw["hbm_bw"], rel=1e-4)
        assert r["tokens"] == (r["global_batch"] if r["phase"] == "decode"
                               else r["global_batch"] * r["seq_len"])
        assert r["flops_per_token"] > 0
        # train always scans layers (remat loop); inference may inline
        assert r["n_while"] >= (1 if r["phase"] == "train" else 0)


def test_decode_rows_are_never_compute_bound():
    """The Calore-style contrast the cost model relies on: single-token
    decode sits far off the compute roofline on every architecture."""
    table = load_roofline()
    for arch in table.archs():
        assert table.get(arch, "decode")["bottleneck"] != "compute_s", arch
        assert table.beta(arch, "decode") <= 0.1, arch


def test_some_prefill_rows_are_meaningfully_compute_sensitive():
    """Real widths make large dense prefill clock-sensitive -- the zoo
    reduction must not collapse everything to the floor like make_smoke."""
    table = load_roofline()
    betas = [table.beta(a, "prefill") for a in table.archs()]
    assert max(betas) > 0.3
    assert sum(b > 0.2 for b in betas) >= 4


# ----------------------------------------------------------- golden pin
def test_golden_pin_gemma2():
    """The committed gemma2-2b rows match the golden copy bit-for-bit
    (timing fields excluded): same jax pin -> same artifact."""
    with open(GOLDEN) as f:
        golden = json.load(f)
    table = load_roofline()
    for grow in golden["rows"]:
        row = table.get(grow["arch"], grow["phase"])
        for k, v in grow.items():
            if k in TIMING_FIELDS:
                continue
            assert row[k] == v, f"{grow['phase']}.{k}: {row[k]} != {v}"


# ------------------------------------------------------- beta derivation
def test_beta_from_terms_worked_example():
    """The docs/ROOFLINE.md worked example, verbatim."""
    # memory-bound: compute 2 ms, memory 8 ms, collectives 1 ms
    assert beta_from_terms(0.002, 0.008, 0.001) == pytest.approx(0.25)
    # compute-bound step stretches linearly
    assert beta_from_terms(0.008, 0.002, 0.001) == 1.0
    # floor: a fully memory-bound step keeps residual clock sensitivity
    assert beta_from_terms(0.0001, 0.1, 0.0) == BETA_FLOOR
    assert beta_from_terms(0.0, 0.0, 0.0) == 1.0      # degenerate: no data


def test_beta_floor_is_configurable():
    assert beta_from_terms(0.0001, 0.1, 0.0, floor=0.2) == 0.2
    assert beta_from_terms(0.09, 0.1, 0.0, floor=0.2) == pytest.approx(0.9)


# ------------------------------------------------- roofline-fed profiles
def test_model_profiles_are_measured_not_hand_set():
    """Fresh checkout: no synthetic fallback. Decode flops stay anchored;
    betas and the prefill:decode ratio come from the table."""
    table = load_roofline()
    for name, prof in MODEL_PROFILES.items():
        hand = _HAND_SET_PROFILES[name]
        assert prof.arch == FAMILY_ARCHS[name]
        assert prof.decode_flops_per_token == DECODE_FLOPS_ANCHORS[name]
        assert prof.decode_beta == table.beta(prof.arch, "decode")
        assert prof.prefill_beta == table.beta(prof.arch, "prefill")
        assert prof.prefill_beta != hand.prefill_beta or \
            prof.decode_beta != hand.decode_beta
        ratio = (table.flops_per_token(prof.arch, "prefill")
                 / table.flops_per_token(prof.arch, "decode"))
        assert prof.prefill_flops_per_token == pytest.approx(
            prof.decode_flops_per_token * ratio)
    assert MODEL_PROFILES == profiles_from_roofline(table)


def test_profile_for_arch_every_zoo_member():
    table = load_roofline()
    for arch in table.archs():
        prof = profile_for_arch(arch, table)
        assert prof.name == prof.arch == arch
        assert prof.decode_flops_per_token in DECODE_FLOPS_ANCHORS.values()
        assert prof.decode_beta == table.beta(arch, "decode")
        assert prof.prefill_beta == table.beta(arch, "prefill")


def test_roofline_cost_model_kind_betas():
    table = load_roofline()
    cm = roofline_cost_model("gemma2-2b", table=table)
    assert cm.beta("TRAIN") == table.beta("gemma2-2b", "train")
    assert cm.beta("PREFILL") == table.beta("gemma2-2b", "prefill")
    assert cm.beta("DECODE") == table.beta("gemma2-2b", "decode")
    assert cm.beta("CLOCK") == 0.0


def test_table_unknown_cell_raises():
    table = load_roofline()
    with pytest.raises(KeyError, match="no roofline row"):
        table.get("not-a-model", "train")


def test_legacy_schema_rejected(tmp_path):
    legacy = tmp_path / "legacy.json"
    legacy.write_text(json.dumps([{"arch": "x", "mesh": "16x16"}]))
    with pytest.raises(ValueError, match="roofline/v2"):
        RooflineTable.load(str(legacy))


# ------------------------------------------- three-engine differential
@pytest.mark.parametrize("arch", ["gemma2-2b", "nemotron-4-340b",
                                  "mamba2-370m"])
def test_three_engines_agree_under_roofline_betas(arch):
    """Roofline-informed betas enter planning purely through `CostModel`
    -- every strategy's plan must agree bit-identically across
    simulate / simulate_reference / simulate_fleet."""
    profile = profile_for_arch(arch)
    cost = serving_cost_model(profile)
    assert cost.beta("PREFILL") == profile.prefill_beta
    assert cost.beta("DECODE") == profile.decode_beta
    trace = make_trace("diurnal", rate_rps=6.0, duration_s=6.0, seed=1)
    sg = build_serving_graph(trace, n_servers=2, step_period_s=0.25,
                             cost=cost, profile=profile)
    machine = serving_machine(make_server_proc(), 2)
    names = registered_strategies()
    cfg = StrategyConfig(plan_search_rounds=1, plan_search_lanes=16,
                         replan_every=8, slo_latency_s=sg.horizon_s + 2.0)
    ctx = PlanContext(sg.graph, machine, cost, cfg)
    plans = [get_strategy(n).plan(ctx) for n in names]
    refs = []
    for name, plan in zip(names, plans):
        ref = simulate_reference(sg.graph, machine, cost, plan)
        fast = simulate(sg.graph, machine, cost, plan)
        np.testing.assert_array_equal(fast.start, ref.start, err_msg=name)
        np.testing.assert_array_equal(fast.finish, ref.finish, err_msg=name)
        assert fast.total_energy_j() == pytest.approx(
            ref.total_energy_j(), rel=1e-9), name
        refs.append(ref)
    fleet = simulate_fleet(sg.graph, machine, cost, plans, cores_per_node=1)
    for i, (name, ref) in enumerate(zip(names, refs)):
        np.testing.assert_array_equal(fleet.start[i], ref.start,
                                      err_msg=name)
        np.testing.assert_array_equal(fleet.finish[i], ref.finish,
                                      err_msg=name)


def test_lower_beta_never_raises_strategy_energy():
    """Sanity direction: with the measured (lower) decode beta, downclocked
    decode finishes no later and costs no more energy than under the old
    hand-set beta -- on the same plan."""
    import dataclasses
    measured = MODEL_PROFILES["dense"]
    hand = _HAND_SET_PROFILES["dense"]
    # same flops (isolate the beta effect)
    hand = dataclasses.replace(
        hand, prefill_flops_per_token=measured.prefill_flops_per_token,
        decode_flops_per_token=measured.decode_flops_per_token)
    trace = make_trace("diurnal", rate_rps=6.0, duration_s=6.0, seed=1)
    machine = serving_machine(make_server_proc(), 2)
    results = {}
    for label, prof in (("measured", measured), ("hand", hand)):
        cost = serving_cost_model(prof)
        sg = build_serving_graph(trace, n_servers=2, step_period_s=0.25,
                                 cost=cost, profile=prof)
        ctx = PlanContext(sg.graph, machine, cost, StrategyConfig())
        plan = get_strategy("algorithmic").plan(ctx)
        results[label] = simulate(sg.graph, machine, cost, plan)
    assert results["measured"].total_energy_j() <= \
        results["hand"].total_energy_j() + 1e-9
    assert results["measured"].makespan <= results["hand"].makespan + 1e-9


# ------------------------------------------------------- regeneration
@pytest.mark.slow
def test_zoo_regenerates_one_arch_consistently(tmp_path):
    """`python -m repro.launch.zoo --arch gemma2-2b` in a fresh process
    reproduces the committed rows (the CI drift gate, one arch)."""
    out = tmp_path / "one.json"
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(REPO, "src"))
    subprocess.run(
        [sys.executable, "-m", "repro.launch.zoo", "--arch", "gemma2-2b",
         "--out", str(out)], check=True, env=env, cwd=REPO, timeout=600)
    fresh = {r["phase"]: r for r in json.load(out.open())["rows"]}
    table = load_roofline()
    for phase in PHASES:
        committed = table.get("gemma2-2b", phase)
        for k, v in committed.items():
            if k in TIMING_FIELDS:
                continue
            got = fresh[phase][k]
            if isinstance(v, float):
                assert math.isclose(got, v, rel_tol=0.05, abs_tol=1e-12), \
                    f"{phase}.{k}: {got} vs {v}"
            else:
                assert got == v, f"{phase}.{k}: {got} vs {v}"
