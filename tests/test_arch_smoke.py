"""Per-architecture smoke tests: reduced same-family configs, one
forward/train step + one prefill/decode round-trip on CPU.

Also checks the three param modes (init / abstract / axes) agree on tree
structure — the dry-run's ShapeDtypeStruct trees are exactly the arrays the
smoke test trains with.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, make_smoke
from repro.models import get_model
from repro.models.lm import VISION_PREFIX

ARCHS = list_archs()


def _batch(cfg, key, batch=2, seq=64):
    ks = jax.random.split(key, 3)
    out = {
        "tokens": jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab_size),
    }
    if cfg.frontend == "audio":
        out["audio_embeds"] = jax.random.normal(
            ks[2], (batch, cfg.frontend_len, cfg.d_model), jnp.float32)
    if cfg.frontend == "vision":
        n_pre = min(cfg.frontend_len or VISION_PREFIX, seq // 2)
        out["vision_embeds"] = jax.random.normal(
            ks[2], (batch, n_pre, cfg.d_model), jnp.float32)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_param_modes_agree(arch):
    cfg = make_smoke(get_config(arch))
    api = get_model(cfg)
    init = api.param_tree("init", jax.random.key(0))
    abstract = api.param_tree("abstract")
    axes = api.param_tree("axes")
    s_init = jax.tree.structure(init)
    s_abs = jax.tree.structure(abstract)
    assert s_init == s_abs
    # axes leaves are tuples -> compare with tuples treated as leaves
    s_axes = jax.tree.structure(axes, is_leaf=lambda x: isinstance(x, tuple))
    assert s_init == s_axes
    for a, b in zip(jax.tree.leaves(init), jax.tree.leaves(abstract)):
        assert a.shape == b.shape, (a.shape, b.shape)
        assert a.dtype == b.dtype
    for a, ax in zip(jax.tree.leaves(init),
                     jax.tree.leaves(axes, is_leaf=lambda x:
                                     isinstance(x, tuple))):
        assert a.ndim == len(ax), (a.shape, ax)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = make_smoke(get_config(arch))
    api = get_model(cfg)
    params = api.param_tree("init", jax.random.key(1))
    batch = _batch(cfg, jax.random.key(2))
    loss, grads = jax.value_and_grad(api.loss_fn)(params, batch)
    assert np.isfinite(float(loss)), loss
    # a healthy random-init CE is ~log(vocab)
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < \
        3.0 * np.log(cfg.vocab_size) + 10.0
    gnorm = jnp.sqrt(sum((g.astype(jnp.float32) ** 2).sum()
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = make_smoke(get_config(arch))
    api = get_model(cfg)
    params = api.param_tree("init", jax.random.key(3))
    b, s = 2, 32
    batch = _batch(cfg, jax.random.key(4), batch=b, seq=s)
    cache = api.init_cache(b, s + 8, "init")
    logits, cache = api.prefill(params, batch, cache)
    assert logits.shape == (b, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache = api.decode_step(params, tok, cache, jnp.int32(s))
    assert logits2.shape == (b, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_structure(arch):
    """Full (paper-scale) configs build abstract trees without allocation."""
    cfg = get_config(arch)
    api = get_model(cfg)
    abstract = api.param_tree("abstract")
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(abstract))
    assert n_params > 1e8 or cfg.name in ("whisper-small", "mamba2-370m")
    # declared param_count approximates the real tree (within 25%: the
    # analytic count skips small norms/bias terms)
    declared = cfg.param_count()
    assert 0.6 < n_params / declared < 1.67, (n_params, declared)
