"""Pins scripts/bench_compare.py's gating semantics, most importantly that
sections/metrics present in only one of the two JSONs are reported as
additions/drops and NEVER fail the gate -- each PR that adds a benchmark
section (PR 4: `heterogeneous`) relies on that to land its first
trajectory point.
"""

import importlib.util
import json
import os
import sys

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "bench_compare",
    os.path.join(os.path.dirname(__file__), "..", "scripts",
                 "bench_compare.py"))
bench_compare = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(bench_compare)


def _report(sections):
    return {"suite": "benchmarks.run", "sections": sections}


def _run(tmp_path, old_sections, new_sections, extra_args=()):
    old = tmp_path / "old.json"
    new = tmp_path / "new.json"
    old.write_text(json.dumps(_report(old_sections)))
    new.write_text(json.dumps(_report(new_sections)))
    argv = sys.argv
    sys.argv = ["bench_compare.py", str(old), str(new), *extra_args]
    try:
        return bench_compare.main()
    finally:
        sys.argv = argv


BASE = {"energy_savings": {"cholesky.tx.saved_pct": 16.0, "seconds": 1.0}}


def test_identical_reports_pass(tmp_path):
    assert _run(tmp_path, BASE, BASE) == 0


def test_new_only_metrics_are_additions_not_failures(tmp_path, capsys):
    """A section that exists only in NEW.json (a freshly landed benchmark)
    must be reported, never gated -- no KeyError, exit 0."""
    new = {**BASE,
           "heterogeneous": {"bl_1_1.tx.saved_pct": 7.3, "seconds": 0.2}}
    assert _run(tmp_path, BASE, new) == 0
    out = capsys.readouterr().out
    assert "additions" in out
    assert "heterogeneous.bl_1_1.tx.saved_pct" in out


def test_dropped_metrics_do_not_fail(tmp_path, capsys):
    old = {**BASE, "retired": {"gone.saved_pct": 5.0}}
    assert _run(tmp_path, old, BASE) == 0
    assert "dropped metrics" in capsys.readouterr().out


def test_malformed_section_skipped(tmp_path):
    """A non-dict section payload must not crash the comparison."""
    weird = {**BASE, "notes": "free-form string", "nullsec": None}
    assert _run(tmp_path, weird, weird) == 0


def test_saved_metric_regression_fails(tmp_path, capsys):
    new = {"energy_savings": {"cholesky.tx.saved_pct": 10.0}}
    assert _run(tmp_path, BASE, new) == 1
    assert "REGRESSIONS" in capsys.readouterr().out


def test_small_absolute_drops_denoised(tmp_path):
    """Near-zero baselines: a big relative drop under the absolute floor
    (default 0.25 points) must not flap the gate."""
    old = {"energy_savings": {"x.saved_pct": 0.30}}
    new = {"energy_savings": {"x.saved_pct": 0.10}}
    assert _run(tmp_path, old, new) == 0


def test_speedup_gated_by_hard_floor_only(tmp_path):
    old = {"sim_speed": {"tx.speedup": 9.0, "all_agree": True}}
    ok = {"sim_speed": {"tx.speedup": 5.5, "all_agree": True}}
    bad = {"sim_speed": {"tx.speedup": 4.0, "all_agree": True}}
    assert _run(tmp_path, old, ok) == 0     # noise, still above 5x target
    assert _run(tmp_path, old, bad) == 1    # below the hard floor


def test_engine_disagreement_fails(tmp_path):
    old = {"sim_speed": {"all_agree": True}}
    new = {"sim_speed": {"all_agree": False}}
    assert _run(tmp_path, old, new) == 1


def test_fleet_speedup_gated_by_hard_floor_only(tmp_path):
    """The batched-engine aggregate speedup has its own 50x hard floor:
    noisy drops that stay above it pass, anything below fails."""
    old = {"sim_speed": {"fleet_speedup": 120.0, "fleet_agree": True}}
    ok = {"sim_speed": {"fleet_speedup": 55.0, "fleet_agree": True}}
    bad = {"sim_speed": {"fleet_speedup": 40.0, "fleet_agree": True}}
    assert _run(tmp_path, old, ok) == 0     # noise, still above 50x target
    assert _run(tmp_path, old, bad) == 1    # below the hard floor
    # the floor is tunable for ad-hoc comparisons
    assert _run(tmp_path, old, bad, ("--fleet-floor", "30")) == 0


def test_fleet_disagreement_fails(tmp_path):
    """A fleet lane diverging from the oracle is a correctness failure."""
    old = {"sim_speed": {"fleet_agree": True}}
    new = {"sim_speed": {"fleet_agree": False}}
    assert _run(tmp_path, old, new) == 1


def test_search_ratio_gated_by_hard_floor_only(tmp_path):
    """The plan-search candidate-throughput ratio has its own 30x hard
    floor: noisy drops that stay above it pass, anything below fails."""
    old = {"sim_speed": {"search_throughput_ratio": 50.0,
                         "search_agree": True}}
    ok = {"sim_speed": {"search_throughput_ratio": 33.0,
                        "search_agree": True}}
    bad = {"sim_speed": {"search_throughput_ratio": 25.0,
                         "search_agree": True}}
    assert _run(tmp_path, old, ok) == 0     # noise, still above 30x target
    assert _run(tmp_path, old, bad) == 1    # below the hard floor
    # the floor is tunable for ad-hoc comparisons
    assert _run(tmp_path, old, bad, ("--search-floor", "20")) == 0


def test_serving_j_per_token_rise_fails(tmp_path, capsys):
    """serving *.j_per_token is lower-is-better: a >20% RISE fails, a
    drop (or a small rise) passes."""
    old = {"serving": {"diurnal.tx.j_per_token": 0.30}}
    better = {"serving": {"diurnal.tx.j_per_token": 0.25}}
    small = {"serving": {"diurnal.tx.j_per_token": 0.33}}
    bad = {"serving": {"diurnal.tx.j_per_token": 0.40}}
    assert _run(tmp_path, old, better) == 0
    assert _run(tmp_path, old, small) == 0
    assert _run(tmp_path, old, bad) == 1
    assert "J/token" in capsys.readouterr().out
    # the floor is tunable for ad-hoc comparisons
    assert _run(tmp_path, old, bad, ("--serving-floor", "0.5")) == 0


def test_serving_j_per_token_drop_never_fails(tmp_path):
    """A big J/token DROP is an improvement, not a >20%-drop regression
    (the generic saved-style rule must not apply to lower-is-better)."""
    old = {"serving": {"flat.tx.j_per_token": 0.40}}
    new = {"serving": {"flat.tx.j_per_token": 0.10}}
    assert _run(tmp_path, old, new) == 0


def test_serving_slo_flip_fails(tmp_path, capsys):
    """slo_ok flipping True -> False (p99 newly violating the SLO) fails;
    False -> True and new-only keys never gate."""
    old = {"serving": {"diurnal.tx.slo_ok": True,
                       "flat.tx.slo_ok": False}}
    flip = {"serving": {"diurnal.tx.slo_ok": False,
                        "flat.tx.slo_ok": False}}
    heal = {"serving": {"diurnal.tx.slo_ok": True,
                        "flat.tx.slo_ok": True,
                        "bursty.tx.slo_ok": False}}
    assert _run(tmp_path, old, flip) == 1
    assert "violates the SLO" in capsys.readouterr().out
    assert _run(tmp_path, old, heal) == 0


def test_serving_new_only_metrics_are_additions(tmp_path, capsys):
    """The whole serving section landing for the first time must be
    non-gating (the PR 8 first-landing path)."""
    new = {**BASE, "serving": {"diurnal.tx.j_per_token": 0.31,
                               "diurnal.tx.slo_ok": True}}
    assert _run(tmp_path, BASE, new) == 0
    assert "serving.diurnal.tx.j_per_token" in capsys.readouterr().out


def test_string_metrics_pass_through_ungated(tmp_path, capsys):
    """String-valued metrics (`lm_energy.roofline_source` attributes
    whether the run consumed measured:results/roofline.json or the
    synthetic fixture) must never gate -- not even when the value changes
    (a fixture->measured flip is the intended PR 9 transition)."""
    old = {**BASE, "lm_energy": {
        "roofline_source": "synthetic:benchmarks/data/roofline_fixture.json",
        "train.tx.saved_pct": 12.0}}
    new = {**BASE, "lm_energy": {
        "roofline_source": "measured:results/roofline.json",
        "train.tx.saved_pct": 12.5}}
    assert _run(tmp_path, old, new) == 0
    assert "REGRESSIONS" not in capsys.readouterr().out


def test_migrate_metrics_never_gate(tmp_path, capsys):
    """Migration metrics are trajectory-only: any key containing
    `migrate` -- the auto-emitted `*.tx_migrate.saved_pct` rows and the
    sweep's `*.migrate_saved_vs_tx_pct` cells alike -- is reported as
    drift but never fails the gate, even on a collapse that would trip
    the generic saved-style rule."""
    old = {**BASE, "heterogeneous": {
        "bl_1_1.tx_migrate.saved_pct": 20.0,
        "bl_1_1.bw5.migrate_saved_vs_tx_pct": 9.0,
        "bl_1_1.bw5.migrate_n_moved": 8}}
    new = {**BASE, "heterogeneous": {
        "bl_1_1.tx_migrate.saved_pct": 2.0,
        "bl_1_1.bw5.migrate_saved_vs_tx_pct": 0.0,
        "bl_1_1.bw5.migrate_n_moved": 0}}
    assert _run(tmp_path, old, new) == 0
    out = capsys.readouterr().out
    assert "REGRESSIONS" not in out
    assert "drift (informational): " \
           "heterogeneous.bl_1_1.tx_migrate.saved_pct" in out
    # a NON-migrate saved metric regressing alongside still fails
    new["energy_savings"] = {"cholesky.tx.saved_pct": 1.0}
    assert _run(tmp_path, old, new) == 1


def test_search_disagreement_fails(tmp_path):
    """A batched candidate diverging from the fast engine is a
    correctness failure, not a perf regression."""
    old = {"sim_speed": {"search_agree": True}}
    new = {"sim_speed": {"search_agree": False}}
    assert _run(tmp_path, old, new) == 1
