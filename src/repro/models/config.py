"""Model configuration shared by every assigned architecture."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | audio | hybrid | ssm | vlm
    n_layers: int
    d_model: int
    n_heads: int                     # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # --- attention features ---
    qkv_bias: bool = False           # qwen2.5
    attn_softcap: float | None = None     # gemma2
    final_softcap: float | None = None    # gemma2
    window: int | None = None        # sliding-window size for "local" layers
    rope_theta: float = 10_000.0
    # per-layer kinds, cycled: "global" | "local" | "recurrent" | "ssd"
    layer_pattern: tuple[str, ...] = ("global",)

    # --- MLP ---
    activation: str = "swiglu"       # swiglu | geglu | gelu | relu2
    mlp_bias: bool = False

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 4096       # tokens per dispatch group

    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4

    # --- RG-LRU (recurrentgemma) ---
    lru_width: int = 0

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    frontend: str | None = None      # "audio" | "vision" (stub embeddings)
    frontend_len: int = 0            # stub sequence length

    # --- norms / embedding ---
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    norm_eps: float = 1e-6
    post_norm: bool = False          # gemma2 sandwich norms
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma: x *= sqrt(d)

    # --- numerics / compile strategy ---
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True

    # ---------------------------------------------------------------- utils
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def pattern_period(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_groups(self) -> int:
        return self.n_layers // self.pattern_period

    @property
    def n_tail_layers(self) -> int:
        return self.n_layers % self.pattern_period

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def layer_kind(self, layer_idx: int) -> str:
        return self.layer_pattern[layer_idx % self.pattern_period]

    # parameter count (weights only), for 6ND model-flop accounting
    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            if kind in ("global", "local"):
                attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                    + self.n_heads * hd * d
            elif kind == "recurrent":
                w = self.lru_width or d
                attn = 2 * d * w + w * d + 3 * w   # in/out proj + gates
            elif kind == "ssd":
                inner = self.ssm_expand * d
                attn = d * (2 * inner + 2 * self.ssm_state) + inner * d
            else:
                attn = 0
            gated = self.activation in ("swiglu", "geglu")
            ff_mult = 3 if gated else 2
            if self.is_moe:
                mlp = self.n_experts * ff_mult * d * f + d * self.n_experts
            else:
                mlp = ff_mult * d * f
            if kind == "ssd":
                mlp = 0                    # mamba blocks replace the MLP
            total += attn + mlp
        if self.encoder_layers:
            # encoder stack: self-attn + mlp; decoder adds cross-attn
            enc = self.encoder_layers * (
                d * hd * (self.n_heads + 2 * self.n_kv_heads)
                + self.n_heads * hd * d + 2 * d * f)
            cross = self.n_layers * (
                d * hd * (self.n_heads + 2 * self.n_kv_heads)
                + self.n_heads * hd * d)
            total += enc + cross
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        gated = self.activation in ("swiglu", "geglu")
        ff_mult = 3 if gated else 2
        dense_total = self.param_count()
        moe_all = self.n_layers * self.n_experts * ff_mult * d * f
        moe_active = self.n_layers * self.top_k * ff_mult * d * f
        return int(dense_total - moe_all + moe_active)
