"""Parameter tree construction in three modes from a single definition.

    mode="init"      -> real arrays (smoke tests, the train example)
    mode="abstract"  -> jax.ShapeDtypeStruct (dry-run: a 340B model is
                        lowered without allocating a single weight byte)
    mode="axes"      -> logical-axis tuples, resolved to PartitionSpecs by
                        sharding.rules (one definition, no drift between
                        shapes and shardings)

Weight logical axes (distinct from activation axes on purpose -- FSDP
shards weight `wembed` over the data axis while activation `embed` stays
unsharded):
    wembed, wff, wheads, wkv, whead_dim, wvocab, wexperts, wstate, layers
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


class ParamFactory:
    def __init__(self, mode: str, key: jax.Array | None = None,
                 dtype=jnp.bfloat16):
        assert mode in ("init", "abstract", "axes")
        self.mode = mode
        self._key = key
        self.dtype = dtype

    def _split(self) -> jax.Array:
        assert self._key is not None, "init mode needs a PRNG key"
        self._key, sub = jax.random.split(self._key)
        return sub

    def param(self, shape: tuple[int, ...], axes: tuple[str | None, ...],
              init: str = "normal", scale: float | None = None, dtype=None):
        assert len(shape) == len(axes), (shape, axes)
        dt = dtype or self.dtype
        if self.mode == "axes":
            return axes
        if self.mode == "abstract":
            return jax.ShapeDtypeStruct(shape, dt)
        k = self._split()
        if init == "zeros":
            return jnp.zeros(shape, dt)
        if init == "ones":
            return jnp.ones(shape, dt)
        if init == "normal":
            if scale is None:
                # fan-in scaling over the contracting (first non-layer) dim
                fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
                scale = 1.0 / np.sqrt(max(fan_in, 1))
            return (jax.random.normal(k, shape, jnp.float32)
                    * scale).astype(dt)
        if init == "lru_a":
            # RG-LRU Lambda init: a in (0.9, 0.999) -> softplus-inverse space
            u = jax.random.uniform(k, shape, jnp.float32, 0.9, 0.999)
            c = 8.0
            # a = exp(-c * softplus(L)) => softplus(L) = -log(a)/c
            sp = -jnp.log(u) / c
            lam = jnp.log(jnp.expm1(sp))
            return lam.astype(dt)
        if init == "ssm_a":
            # mamba2 A init: A = -exp(a_log), a ~ U[1, 16]
            u = jax.random.uniform(k, shape, jnp.float32, 1.0, 16.0)
            return jnp.log(u).astype(dt)
        if init == "ssm_dt":
            # dt bias init so softplus(dt_bias) ~ U[1e-3, 1e-1]
            u = jax.random.uniform(k, shape, jnp.float32, 1e-3, 1e-1)
            return (u + jnp.log(-jnp.expm1(-u))).astype(dt)
        raise ValueError(init)
