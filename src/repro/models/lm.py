"""Decoder-only LM covering the dense / MoE / local-global / hybrid-RG-LRU /
SSD / VLM-prefix families, with scan-over-layer-groups compilation.

Layer heterogeneity (gemma2 local/global alternation, recurrentgemma 1:2
recurrent:attention) is expressed as a layer *pattern*: the model scans over
n_layers // period groups, each group applying the period's sub-blocks with
its own slice of the stacked parameters; pattern remainders run unrolled as
"tail" layers. This keeps HLO size O(period) instead of O(n_layers) -- a
96-layer nemotron lowers as fast as a 12-layer whisper.

Three param modes (see params.ParamFactory): init / abstract / axes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.sharding import constraint as cst

from . import layers as L
from .config import ModelConfig
from .params import ParamFactory

VISION_PREFIX = 256          # vlm stub: patch embeddings replace this prefix


# ------------------------------------------------------------------ params
def _block_params(pf: ParamFactory, cfg: ModelConfig, kind: str,
                  groups: tuple[int, ...]):
    p = {"norm1": L.norm_params(pf, cfg, groups)}
    if kind in ("global", "local"):
        p["attn"] = L.attention_params(pf, cfg, groups)
    elif kind == "recurrent":
        p["rec"] = L.rglru_params(pf, cfg, groups)
    elif kind == "ssd":
        p["ssd"] = L.ssd_params(pf, cfg, groups)
    else:
        raise ValueError(kind)
    if kind != "ssd":
        p["norm2"] = L.norm_params(pf, cfg, groups)
        p["mlp"] = (L.moe_params(pf, cfg, groups) if cfg.is_moe
                    else L.mlp_params(pf, cfg, groups))
    if cfg.post_norm:
        p["norm1_post"] = L.norm_params(pf, cfg, groups)
        if kind != "ssd":
            p["norm2_post"] = L.norm_params(pf, cfg, groups)
    return p


def param_tree(cfg: ModelConfig, mode: str, key=None):
    pf = ParamFactory(mode, key, dtype=jnp.dtype(cfg.dtype))
    v, d = cfg.vocab_size, cfg.d_model
    params = {"embed": pf.param((v, d), ("wvocab", "wembed"), scale=0.02)}
    if not cfg.tie_embeddings:
        params["unembed"] = pf.param((v, d), ("wvocab", "wembed"))
    g = cfg.n_groups
    params["blocks"] = {
        f"sub{i}": _block_params(pf, cfg, kind, (g,))
        for i, kind in enumerate(cfg.layer_pattern)
    }
    if cfg.n_tail_layers:
        params["tail"] = {
            f"tail{i}": _block_params(pf, cfg, cfg.layer_kind(
                cfg.n_groups * cfg.pattern_period + i), ())
            for i in range(cfg.n_tail_layers)
        }
    params["final_norm"] = L.norm_params(pf, cfg, ())
    return params


# ------------------------------------------------------------------ blocks
def _apply_block(bp, x, cfg: ModelConfig, kind: str, cache=None, pos=None):
    h = L.apply_norm(bp["norm1"], x, cfg)
    if kind in ("global", "local"):
        y, new_inner = L.attention_block(bp["attn"], h, cfg, kind=kind,
                                         cache=cache, pos=pos)
        aux = 0.0
    elif kind == "recurrent":
        y, new_inner = L.rglru_block(bp["rec"], h, cfg, cache=cache)
        aux = 0.0
    elif kind == "ssd":
        y, new_inner = L.ssd_block(bp["ssd"], h, cfg, cache=cache)
        aux = 0.0
    if cfg.post_norm:
        y = L.apply_norm(bp["norm1_post"], y, cfg)
    x = x + y
    if kind != "ssd":
        h = L.apply_norm(bp["norm2"], x, cfg)
        if cfg.is_moe:
            y, aux2 = L.moe_block(bp["mlp"], h, cfg)
            aux = aux + aux2
        else:
            y = L.mlp_block(bp["mlp"], h, cfg)
        if cfg.post_norm:
            y = L.apply_norm(bp["norm2_post"], y, cfg)
        x = x + y
    x = cst(x, ("batch", "res_seq", "embed"))
    return x, new_inner, aux


def _embed_tokens(params, tokens, cfg: ModelConfig):
    x = params["embed"][tokens]
    x = cst(x, ("batch", "res_seq", "embed"))
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _splice_vision(x, vision_embeds):
    if vision_embeds is None:
        return x
    pre = vision_embeds.astype(x.dtype)
    return jnp.concatenate([pre, x[:, pre.shape[1]:]], axis=1)


# ----------------------------------------------------------------- forward
def hidden_states(params, tokens, cfg: ModelConfig, vision_embeds=None):
    """Training/teacher-forcing forward; returns (hidden [B,S,D], aux)."""
    x = _splice_vision(_embed_tokens(params, tokens, cfg), vision_embeds)

    def group_body(x, gp):
        aux = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(cfg.layer_pattern):
            x, _, a = _apply_block(gp[f"sub{i}"], x, cfg, kind)
            aux = aux + a
        return x, aux

    body = jax.checkpoint(group_body) if cfg.remat else group_body
    if cfg.scan_layers and cfg.n_groups > 0:
        x, auxs = jax.lax.scan(body, x, params["blocks"])
        aux = auxs.sum()
    else:
        aux = jnp.zeros((), jnp.float32)
        for gi in range(cfg.n_groups):
            gp = jax.tree.map(lambda a: a[gi], params["blocks"])
            x, a = body(x, gp)
            aux = aux + a
    for i in range(cfg.n_tail_layers):
        kind = cfg.layer_kind(cfg.n_groups * cfg.pattern_period + i)
        x, _, a = _apply_block(params["tail"][f"tail{i}"], x, cfg, kind)
        aux = aux + a
    x = L.apply_norm(params["final_norm"], x, cfg)
    # gather the residual stream off the SP axis for the (chunked) loss
    return cst(x, ("batch", "seq", "embed")), aux


def _unembed_matrix(params):
    return params.get("unembed", params["embed"])


def logits_from_hidden(params, h, cfg: ModelConfig):
    w = _unembed_matrix(params)
    logits = jnp.einsum("bsd,vd->bsv", h, w)
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return cst(logits, ("batch", "seq", "act_vocab"))


def loss_fn(params, batch, cfg: ModelConfig, *, loss_chunk: int = 512,
            z_loss: float = 1e-4, aux_weight: float = 1e-2):
    """Chunked cross-entropy: logits are materialized loss_chunk tokens at a
    time (a 256k-vocab model never holds [B,S,V])."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    if cfg.frontend == "vision" and batch.get("vision_embeds") is not None:
        n_pre = batch["vision_embeds"].shape[1]
        mask = mask.at[:, :n_pre].set(0.0)
    h, aux = hidden_states(params, tokens, cfg,
                           vision_embeds=batch.get("vision_embeds"))
    w = _unembed_matrix(params)
    b, s, d = h.shape
    c = min(loss_chunk, s)
    assert s % c == 0
    hc = h.reshape(b, s // c, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, s // c, c).transpose(1, 0, 2)
    mc = mask.reshape(b, s // c, c).transpose(1, 0, 2)

    def chunk_loss(args):
        hx, lx, mx = args
        logits = jnp.einsum("bcd,vd->bcv", hx, w).astype(jnp.float32)
        if cfg.final_softcap is not None:
            logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
        logits = cst(logits, ("batch", "seq", "act_vocab"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lx[..., None], -1)[..., 0]
        nll = (lse - gold) * mx
        zl = z_loss * (lse ** 2) * mx
        return (nll + zl).sum(), mx.sum()

    sums, cnts = jax.lax.map(jax.checkpoint(chunk_loss), (hc, lc, mc))
    total = sums.sum() / jnp.maximum(cnts.sum(), 1.0)
    return total + aux_weight * aux


# -------------------------------------------------------------- serving
def _init_layer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                      pf_mode: str = "init"):
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)

    def mk(shape, dtype, axes):
        if pf_mode == "axes":
            return axes
        if pf_mode == "abstract":
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)

    kv_ax = ("batch", "kv_heads", "kv_seq", "head_dim")
    if kind == "global":
        return {"k": mk((batch, cfg.n_kv_heads, max_len, hd), dt, kv_ax),
                "v": mk((batch, cfg.n_kv_heads, max_len, hd), dt, kv_ax)}
    if kind == "local":
        w = min(cfg.window or max_len, max_len)
        return {"k": mk((batch, cfg.n_kv_heads, w, hd), dt, kv_ax),
                "v": mk((batch, cfg.n_kv_heads, w, hd), dt, kv_ax)}
    if kind == "recurrent":
        return {"h": mk((batch, cfg.lru_width), jnp.float32,
                        ("batch", "act_lru")),
                "conv": mk((batch, cfg.conv_width - 1, cfg.lru_width), dt,
                           ("batch", None, "act_lru"))}
    if kind == "ssd":
        inner = cfg.ssm_expand * cfg.d_model
        nh = inner // cfg.ssm_head_dim
        return {"state": mk((batch, nh, cfg.ssm_head_dim, cfg.ssm_state),
                            jnp.float32,
                            ("batch", "ssm_heads", None, None)),
                "conv": mk((batch, cfg.conv_width - 1,
                            inner + 2 * cfg.ssm_state), dt,
                           ("batch", None, None))}
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               mode: str = "init"):
    def stack(tree, g):
        if mode == "axes":
            return jax.tree.map(lambda ax: ("layers",) + ax, tree,
                                is_leaf=lambda x: isinstance(x, tuple))
        if mode == "abstract":
            return jax.tree.map(
                lambda sds: jax.ShapeDtypeStruct((g,) + sds.shape, sds.dtype),
                tree)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (g,) + a.shape),
                            tree)

    g = cfg.n_groups
    cache = {"blocks": {
        f"sub{i}": stack(_init_layer_cache(cfg, kind, batch, max_len, mode), g)
        for i, kind in enumerate(cfg.layer_pattern)}}
    if cfg.n_tail_layers:
        cache["tail"] = {
            f"tail{i}": _init_layer_cache(
                cfg, cfg.layer_kind(cfg.n_groups * cfg.pattern_period + i),
                batch, max_len, mode)
            for i in range(cfg.n_tail_layers)}
    return cache


def _scan_with_cache(params, cache, x, cfg: ModelConfig, pos):
    def group_body(x, xs):
        gp, gc = xs
        new_gc = {}
        for i, kind in enumerate(cfg.layer_pattern):
            x, nc, _ = _apply_block(gp[f"sub{i}"], x, cfg, kind,
                                    cache=gc[f"sub{i}"], pos=pos)
            new_gc[f"sub{i}"] = nc
        return x, new_gc

    body = jax.checkpoint(group_body) if cfg.remat else group_body
    x, new_cache_blocks = jax.lax.scan(
        body, x, (params["blocks"], cache["blocks"]))
    new_cache = {"blocks": new_cache_blocks}
    if cfg.n_tail_layers:
        new_tail = {}
        for i in range(cfg.n_tail_layers):
            kind = cfg.layer_kind(cfg.n_groups * cfg.pattern_period + i)
            x, nc, _ = _apply_block(params["tail"][f"tail{i}"], x, cfg, kind,
                                    cache=cache["tail"][f"tail{i}"], pos=pos)
            new_tail[f"tail{i}"] = nc
        new_cache["tail"] = new_tail
    return x, new_cache


def prefill(params, tokens, cfg: ModelConfig, cache, vision_embeds=None):
    """Fill the KV/state caches; returns (last-token logits [B,V], cache)."""
    x = _splice_vision(_embed_tokens(params, tokens, cfg), vision_embeds)
    x, new_cache = _scan_with_cache(params, cache, x, cfg, pos=None)
    x = L.apply_norm(params["final_norm"], x, cfg)
    last = x[:, -1:, :]
    logits = logits_from_hidden(params, last, cfg)[:, 0]
    return logits, new_cache


def decode_step(params, token, cache, pos, cfg: ModelConfig):
    """One token for the whole batch. token: [B, 1] int32; pos: scalar."""
    x = _embed_tokens(params, token, cfg)
    x, new_cache = _scan_with_cache(params, cache, x, cfg, pos=pos)
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = logits_from_hidden(params, x, cfg)[:, 0]
    return logits, new_cache
