"""Unified model API: family dispatch between the decoder-only LM and the
encoder-decoder (whisper) backbones."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

from . import encdec, lm
from .config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    param_tree: Callable          # (mode, key=None) -> params
    loss_fn: Callable             # (params, batch) -> scalar
    prefill: Callable             # (params, batch, cache) -> (logits, cache)
    decode_step: Callable         # (params, token, cache, pos) -> (logits, cache)
    init_cache: Callable          # (batch, max_len, mode) -> cache


def get_model(cfg: ModelConfig) -> ModelApi:
    if cfg.is_encdec:
        return ModelApi(
            cfg=cfg,
            param_tree=lambda mode, key=None: encdec.param_tree(cfg, mode, key),
            loss_fn=lambda params, batch: encdec.loss_fn(params, batch, cfg),
            prefill=lambda params, batch, cache: encdec.prefill(
                params, batch["tokens"], batch["audio_embeds"], cfg, cache),
            decode_step=lambda params, token, cache, pos: encdec.decode_step(
                params, token, cache, pos, cfg),
            init_cache=lambda batch, max_len, mode="init": encdec.init_cache(
                cfg, batch, max_len, mode),
        )
    return ModelApi(
        cfg=cfg,
        param_tree=lambda mode, key=None: lm.param_tree(cfg, mode, key),
        loss_fn=lambda params, batch: lm.loss_fn(params, batch, cfg),
        prefill=lambda params, batch, cache: lm.prefill(
            params, batch["tokens"], cfg, cache,
            vision_embeds=batch.get("vision_embeds")),
        decode_step=lambda params, token, cache, pos: lm.decode_step(
            params, token, cache, pos, cfg),
        init_cache=lambda batch, max_len, mode="init": lm.init_cache(
            cfg, batch, max_len, mode),
    )


__all__ = ["ModelApi", "ModelConfig", "get_model", "lm", "encdec"]
