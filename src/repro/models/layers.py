"""Layer primitives for every assigned architecture family.

Pure functions over explicit parameter pytrees (no module framework).
Activation/weight sharding is annotated with logical axes through
repro.sharding.constraint (no-ops outside a sharding context).

Conventions:
    x            [B, S, D] activations, compute dtype = params dtype
    numerics     softmax/norms/recurrences in float32
    caches       dicts of arrays; "global" attn: linear cache [B,Hkv,Smax,hd],
                 "local" attn: ring cache [B,Hkv,W,hd], recurrent/ssd: states
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.sharding import constraint as cst
from repro.sharding.rules import (column_parallel_ag, row_parallel_rs,
                                  rule_is_model, sp_gather_seq)

from .config import ModelConfig
from .params import ParamFactory

# =========================================================== small pieces

def rms_norm(x, w, eps):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, w, b, eps):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def apply_norm(p, x, cfg: ModelConfig):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


def norm_params(pf: ParamFactory, cfg: ModelConfig, groups: tuple[int, ...]):
    lead = tuple(groups)
    lax_ = ("layers",) * len(groups)
    p = {"scale": pf.param(lead + (cfg.d_model,), lax_ + (None,),
                           init="zeros" if cfg.norm == "rmsnorm" else "ones")}
    if cfg.norm == "layernorm":
        p["bias"] = pf.param(lead + (cfg.d_model,), lax_ + (None,),
                             init="zeros")
    return p


def rope(x, positions, theta):
    """x: [B, S, H, hd]; positions: [S] or [B, S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[None, :, None] * freqs[None, None]
        ang = ang[:, :, None, :]                       # [1, S, 1, half]
    else:
        ang = positions.astype(jnp.float32)[:, :, None] * freqs[None, None]
        ang = ang[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * cos - xf2 * sin,
                            xf2 * cos + xf1 * sin], -1).astype(x.dtype)


# ============================================================== attention

def attention_params(pf: ParamFactory, cfg: ModelConfig,
                     groups: tuple[int, ...]):
    d, hq, hkv, hd = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                      cfg.resolved_head_dim)
    g = tuple(groups)
    gl = ("layers",) * len(groups)
    p = {
        "wq": pf.param(g + (d, hq, hd), gl + ("wembed", "wheads", "whead_dim")),
        "wk": pf.param(g + (d, hkv, hd), gl + ("wembed", "wkv", "whead_dim")),
        "wv": pf.param(g + (d, hkv, hd), gl + ("wembed", "wkv", "whead_dim")),
        "wo": pf.param(g + (hq, hd, d), gl + ("wheads", "whead_dim", "wembed")),
    }
    if cfg.qkv_bias:
        p["bq"] = pf.param(g + (hq, hd), gl + ("wheads", "whead_dim"),
                           init="zeros")
        p["bk"] = pf.param(g + (hkv, hd), gl + ("wkv", "whead_dim"),
                           init="zeros")
        p["bv"] = pf.param(g + (hkv, hd), gl + ("wkv", "whead_dim"),
                           init="zeros")
    return p


def _qkv(p, x, cfg: ModelConfig, positions, *, use_rope=True):
    # SP: one seq all-gather feeds the projections inside a single
    # shard_map; the dgrad partials reduce-scatter through its transpose.
    # Projections whose head count doesn't TP-shard (GQA kv on a wide TP
    # axis) take the plain einsum against the gathered stream instead.
    if rule_is_model("heads") and rule_is_model("kv_heads"):
        q, k, v = column_parallel_ag(
            x, [p["wq"], p["wk"], p["wv"]], ["bsd,dhe->bshe"] * 3, "heads")
    elif rule_is_model("heads"):
        (q,) = column_parallel_ag(x, [p["wq"]], ["bsd,dhe->bshe"], "heads")
        xg = sp_gather_seq(x)
        k = jnp.einsum("bsd,dhe->bshe", xg, p["wk"])
        v = jnp.einsum("bsd,dhe->bshe", xg, p["wv"])
    else:
        xg = sp_gather_seq(x)
        q = jnp.einsum("bsd,dhe->bshe", xg, p["wq"])
        k = jnp.einsum("bsd,dhe->bshe", xg, p["wk"])
        v = jnp.einsum("bsd,dhe->bshe", xg, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = cst(q, ("batch", "seq", "heads", "head_dim"))
    k = cst(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = cst(v, ("batch", "seq", "kv_heads", "head_dim"))
    return q, k, v


def _out_proj(p, attn_out):
    # attn_out: [B, S, Hq, hd]; the head-contracted partial sums land
    # reduce-scattered (explicit shard_map psum_scatter, bf16) onto the
    # sequence-sharded residual stream when SP is on, else all-reduced.
    return row_parallel_rs(attn_out, p["wo"], "bshe,hed->bsd", "heads")


def attention_block(p, x, cfg: ModelConfig, *, kind: str, causal: bool = True,
                    cache=None, pos=None, positions=None, use_rope=True):
    """Full/local attention; returns (y, new_cache)."""
    b, s, _ = x.shape
    window = cfg.window if kind == "local" else None
    if positions is None:
        if pos is None:
            positions = jnp.arange(s)
        else:
            positions = pos + jnp.arange(s)              # decode: scalar pos
    q, k, v = _qkv(p, x, cfg, positions, use_rope=use_rope)
    qh = q.transpose(0, 2, 1, 3)                          # [B, H, S, hd]

    if cache is None:
        kh = k.transpose(0, 2, 1, 3)
        vh = v.transpose(0, 2, 1, 3)
        kh = cst(kh, ("batch", "kv_heads", "kv_seq", "head_dim"))
        vh = cst(vh, ("batch", "kv_heads", "kv_seq", "head_dim"))
        out = ops.flash_attention(qh, kh, vh, causal=causal, window=window,
                                  softcap=cfg.attn_softcap)
        return _out_proj(p, out.transpose(0, 2, 1, 3)), None

    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    if kind == "local":
        new_cache, out = _local_cached_attention(
            qh, kh, vh, cache, pos, s, cfg)
    else:
        new_cache, out = _global_cached_attention(
            qh, kh, vh, cache, pos, s, cfg, causal)
    return _out_proj(p, out.transpose(0, 2, 1, 3)), new_cache


def _global_cached_attention(qh, kh, vh, cache, pos, s, cfg, causal):
    """Linear cache [B, Hkv, Smax, hd]; prefill writes [0:s), decode at pos."""
    if pos is None:                                       # prefill
        kc = jax.lax.dynamic_update_slice(
            cache["k"], kh.astype(cache["k"].dtype), (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            cache["v"], vh.astype(cache["v"].dtype), (0, 0, 0, 0))
        valid = jnp.asarray(s, jnp.int32)
    else:                                                 # decode (s tokens)
        z = jnp.zeros((), jnp.int32)
        p32 = jnp.asarray(pos, jnp.int32)
        kc = jax.lax.dynamic_update_slice(
            cache["k"], kh.astype(cache["k"].dtype), (z, z, p32, z))
        vc = jax.lax.dynamic_update_slice(
            cache["v"], vh.astype(cache["v"].dtype), (z, z, p32, z))
        valid = pos + s
    kc = cst(kc, ("batch", "kv_heads", "kv_seq", "head_dim"))
    vc = cst(vc, ("batch", "kv_heads", "kv_seq", "head_dim"))
    out = decode_attend(qh, kc, vc, valid_len=valid, causal=causal,
                        softcap=cfg.attn_softcap)
    return {"k": kc, "v": vc}, out


def _local_cached_attention(qh, kh, vh, cache, pos, s, cfg):
    """Ring cache [B, Hkv, W, hd]: slot(p) = p mod W."""
    w = cache["k"].shape[2]
    if pos is None:                                       # prefill
        # write the last min(s, w) positions into their ring slots
        slots = jnp.arange(w)
        p_i = (s - 1) - ((s - 1 - slots) % w)             # abs pos per slot
        valid = p_i >= 0
        src = jnp.clip(p_i, 0, s - 1)
        kc = jnp.where(valid[None, None, :, None], kh[:, :, src, :], 0.0)
        vc = jnp.where(valid[None, None, :, None], vh[:, :, src, :], 0.0)
        kc = kc.astype(cache["k"].dtype)
        vc = vc.astype(cache["v"].dtype)
        # attention itself: full-seq local flash
        out = ops.flash_attention(qh, kh, vh, causal=True, window=cfg.window,
                                  softcap=cfg.attn_softcap)
        return {"k": kc, "v": vc}, out
    # decode: write token at slot pos % w
    slot = jnp.asarray(pos % w, jnp.int32)
    z = jnp.zeros((), jnp.int32)
    kc = jax.lax.dynamic_update_slice(cache["k"],
                                      kh.astype(cache["k"].dtype),
                                      (z, z, slot, z))
    vc = jax.lax.dynamic_update_slice(cache["v"],
                                      vh.astype(cache["v"].dtype),
                                      (z, z, slot, z))
    slots = jnp.arange(w)
    p_i = pos - ((pos - slots) % w)                       # abs pos per slot
    mask = (p_i >= 0) & (p_i <= pos) & (p_i > pos - cfg.window)
    out = _masked_single_attend(qh, kc, vc, mask, cfg.attn_softcap)
    return {"k": kc, "v": vc}, out


def decode_attend(qh, kc, vc, *, valid_len, causal=True, softcap=None):
    """Attention of [B,H,s,hd] queries against a length-masked cache.

    Queries sit at absolute positions valid_len-s .. valid_len-1.
    """
    s = qh.shape[2]
    skv = kc.shape[2]
    kpos = jnp.arange(skv)[None, :]
    qpos = (valid_len - s) + jnp.arange(s)[:, None]
    mask = kpos < valid_len
    if causal:
        mask = mask & (kpos <= qpos)
    else:
        mask = jnp.broadcast_to(mask, (s, skv))
    return _masked_attend(qh, kc, vc, mask, softcap)


def _masked_attend(qh, kc, vc, mask, softcap):
    """mask: [s, skv] (shared over batch/heads)."""
    b, hq, s, hd = qh.shape
    hkv = kc.shape[1]
    group = hq // hkv
    qg = qh.reshape(b, hkv, group, s, hd).astype(jnp.float32)
    logits = jnp.einsum("bhgsd,bhkd->bhgsk", qg,
                        kc.astype(jnp.float32)) * (hd ** -0.5)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgsk,bhkd->bhgsd", probs, vc.astype(jnp.float32))
    return out.reshape(b, hq, s, hd).astype(qh.dtype)


def _masked_single_attend(qh, kc, vc, mask_1d, softcap):
    return _masked_attend(qh, kc, vc, mask_1d[None, :], softcap)


# ==================================================================== MLP

def mlp_params(pf: ParamFactory, cfg: ModelConfig, groups: tuple[int, ...]):
    d, f = cfg.d_model, cfg.d_ff
    g = tuple(groups)
    gl = ("layers",) * len(groups)
    gated = cfg.activation in ("swiglu", "geglu")
    p = {"w1": pf.param(g + (d, f), gl + ("wembed", "wff")),
         "w2": pf.param(g + (f, d), gl + ("wff", "wembed"))}
    if gated:
        p["w3"] = pf.param(g + (d, f), gl + ("wembed", "wff"))
    if cfg.mlp_bias:
        p["b1"] = pf.param(g + (f,), gl + ("wff",), init="zeros")
        p["b2"] = pf.param(g + (d,), gl + (None,), init="zeros")
    return p


def _act(h, kind: str):
    if kind in ("swiglu",):
        return jax.nn.silu(h)
    if kind in ("geglu", "gelu"):
        return jax.nn.gelu(h)
    if kind == "relu2":
        r = jax.nn.relu(h)
        return r * r
    raise ValueError(kind)


def mlp_block(p, x, cfg: ModelConfig):
    # SP: one seq all-gather feeds w1 (and w3) inside a single shard_map
    # (Megatron-SP column side); w2 reduce-scatters back onto the
    # sequence-sharded residual stream (row side).
    gated = cfg.activation in ("swiglu", "geglu")
    ws = [p["w1"], p["w3"]] if gated else [p["w1"]]
    outs = column_parallel_ag(x, ws, ["bsd,df->bsf"] * len(ws), "act_ff")
    h = outs[0]
    if cfg.mlp_bias:
        h = h + p["b1"]
    h = cst(h, ("batch", "seq", "act_ff"))
    h = _act(h, cfg.activation)
    if gated:
        h = h * outs[1]
    y = row_parallel_rs(h, p["w2"], "bsf,fd->bsd", "act_ff")
    if cfg.mlp_bias:
        y = y + p["b2"]
    return cst(y, ("batch", "res_seq", "embed"))


# ==================================================================== MoE

def moe_params(pf: ParamFactory, cfg: ModelConfig, groups: tuple[int, ...]):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    g = tuple(groups)
    gl = ("layers",) * len(groups)
    p = {"router": pf.param(g + (d, e), gl + ("wembed", "wexperts")),
         "w1": pf.param(g + (e, d, f), gl + ("wexperts", "wembed",
                                             "wexpert_ff")),
         "w2": pf.param(g + (e, f, d), gl + ("wexperts", "wexpert_ff",
                                             "wembed"))}
    if cfg.activation in ("swiglu", "geglu"):
        p["w3"] = pf.param(g + (e, d, f), gl + ("wexperts", "wembed",
                                                "wexpert_ff"))
    return p


def moe_block(p, x, cfg: ModelConfig):
    """GShard-style capacity dispatch, scanned over token groups.

    Returns (y, aux_loss). Dispatch tensors live one group at a time
    ([gs, E, C] bf16), so memory stays flat however long the sequence is.
    """
    b, s, d = x.shape
    t = b * s
    gs = min(cfg.moe_group_size, t)
    assert t % gs == 0, (t, gs)
    n_groups = t // gs
    e, k = cfg.n_experts, cfg.top_k
    cap = max(int(math.ceil(gs * k / e * cfg.capacity_factor)), 1)

    xt = x.reshape(n_groups, gs, d)
    xt = cst(xt, ("moe_groups", None, "embed"))

    def one_group(xg):
        gates = jnp.einsum("td,de->te", xg.astype(jnp.float32),
                           p["router"].astype(jnp.float32))
        probs = jax.nn.softmax(gates, axis=-1)
        topw, topi = jax.lax.top_k(probs, k)
        topw = topw / jnp.clip(topw.sum(-1, keepdims=True), 1e-9)
        # aux loss stats
        me = probs.mean(axis=0)                                   # [E]
        ce = jnp.zeros((e,)).at[topi.reshape(-1)].add(1.0) / (gs * k)
        aux = e * jnp.sum(me * ce)

        dispatch = jnp.zeros((gs, e, cap), jnp.bfloat16)
        combine = jnp.zeros((gs, e, cap), jnp.float32)
        # fill per routing rank; capacity is claimed in token order
        used = jnp.zeros((gs, e), jnp.float32)
        for kk in range(k):
            oh = jax.nn.one_hot(topi[:, kk], e, dtype=jnp.float32)  # [gs,E]
            # slot index: tokens already queued for this expert (earlier
            # tokens this rank + all earlier ranks)
            prior = jnp.cumsum(oh, axis=0) - oh + used.sum(0)[None, :]
            slot = prior.astype(jnp.int32)
            keep = (oh > 0) & (slot < cap)
            slot_oh = jax.nn.one_hot(slot, cap, dtype=jnp.float32) \
                * keep[..., None]                                 # [gs,E,C]
            dispatch = dispatch + slot_oh.astype(jnp.bfloat16)
            combine = combine + slot_oh * topw[:, kk][:, None, None]
            used = used + oh * keep
        # expert compute. When experts TP-shard (EP), xe/out are forced to
        # the expert-sharded layout; when they don't (mixtral: 8 experts on
        # a 16-wide axis -> TP inside each expert's d_ff), leave xe/out
        # UNCONSTRAINED: the w2 contraction's partial sums then flow through
        # the (linear) combine einsum and are reduced once on the [gs, d]
        # output instead of on the ExCxd expert buffer -- E*C/gs ~ 2.5x
        # fewer bytes per reduction (S-Perf iteration mixtral/1).
        ep = rule_is_model("act_experts")
        xe = jnp.einsum("tec,td->ecd", dispatch, xg.astype(jnp.bfloat16))
        # non-EP: xe deliberately left UNCONSTRAINED -- pinning it
        # replicated (to suppress the partitioner's token-contraction
        # split) was tried and REFUTED: collective 90.9s -> 214.6s
        # (EXPERIMENTS.md S-Perf mixtral/iter-3).
        if ep:
            xe = cst(xe, ("act_experts", None, "embed"))
        h = jnp.einsum("ecd,edf->ecf", xe, p["w1"])
        h = cst(h, ("act_experts", None, "act_ff"))
        h = _act(h, cfg.activation)
        if "w3" in p:
            h = h * jnp.einsum("ecd,edf->ecf", xe, p["w3"])
        out = jnp.einsum("ecf,efd->ecd", h, p["w2"])
        if ep:
            out = cst(out, ("act_experts", None, "embed"))
        y = jnp.einsum("tec,ecd->td", combine.astype(jnp.bfloat16),
                       out.astype(jnp.bfloat16))
        return y.astype(x.dtype), aux

    if n_groups == 1:
        y, aux = one_group(xt[0])
        return y.reshape(b, s, d), aux
    # remat each dispatch group: the [gs, E, C] dispatch/combine tensors are
    # recomputed in backward instead of being stored for every group (the
    # config-wide remat policy applied at MoE granularity)
    body = jax.checkpoint(one_group) if cfg.remat else one_group
    ys, auxs = jax.lax.map(body, xt)
    return ys.reshape(b, s, d), auxs.mean()


# ================================================================= RG-LRU

def rglru_params(pf: ParamFactory, cfg: ModelConfig, groups: tuple[int, ...]):
    d, w = cfg.d_model, cfg.lru_width
    g = tuple(groups)
    gl = ("layers",) * len(groups)
    cw = cfg.conv_width
    return {
        "w_gate": pf.param(g + (d, w), gl + ("wembed", "wlru")),
        "w_in": pf.param(g + (d, w), gl + ("wembed", "wlru")),
        "w_out": pf.param(g + (w, d), gl + ("wlru", "wembed")),
        "conv": pf.param(g + (cw, w), gl + (None, "wlru"), scale=0.5),
        "w_r": pf.param(g + (w, w), gl + ("wlru", None)),
        "w_i": pf.param(g + (w, w), gl + ("wlru", None)),
        "b_r": pf.param(g + (w,), gl + ("wlru",), init="zeros"),
        "b_i": pf.param(g + (w,), gl + ("wlru",), init="zeros"),
        "lam": pf.param(g + (w,), gl + ("wlru",), init="lru_a",
                        dtype=jnp.float32),
    }


def _causal_conv(u, w_conv, cache):
    """Depthwise causal conv, width cw. cache: [B, cw-1, W] trailing inputs."""
    cw = w_conv.shape[0]
    if cache is None:
        pads = [jnp.pad(u, ((0, 0), (i, 0), (0, 0)))[:, :u.shape[1]]
                for i in range(cw)]
        out = sum(w_conv[cw - 1 - i] * pads[i] for i in range(cw))
        new_cache = None
    else:
        ext = jnp.concatenate([cache.astype(u.dtype), u], axis=1)
        out = sum(w_conv[cw - 1 - i] *
                  jax.lax.dynamic_slice_in_dim(
                      ext, ext.shape[1] - u.shape[1] - i, u.shape[1], 1)
                  for i in range(cw))
        new_cache = ext[:, -(cw - 1):].astype(cache.dtype)
    return out, new_cache


def rglru_block(p, x, cfg: ModelConfig, cache=None):
    """Griffin recurrent block: conv1d -> RG-LRU -> gated output.

    cache: {"h": [B, W] f32, "conv": [B, cw-1, W]} or None (training).
    """
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"]))
    u = jnp.einsum("bsd,dw->bsw", x, p["w_in"])
    u = cst(u, ("batch", "seq", "act_lru"))
    u, conv_cache = _causal_conv(u, p["conv"],
                                 None if cache is None else cache["conv"])
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", uf,
                                  p["w_r"].astype(jnp.float32)) + p["b_r"])
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", uf,
                                  p["w_i"].astype(jnp.float32)) + p["b_i"])
    c = 8.0
    log_a = -c * jax.nn.softplus(p["lam"]) * r            # [B, S, W] f32
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    gx = mult * i * uf

    if cache is None:
        def comb(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, b1 * a2 + b2
        _, h = jax.lax.associative_scan(comb, (a, gx), axis=1)
        new_cache = None
    else:
        h0 = cache["h"]                                    # [B, W] f32
        def step(hprev, xs):
            at, gt = xs
            hnew = at * hprev + gt
            return hnew, hnew
        hT, h = jax.lax.scan(step, h0, (a.transpose(1, 0, 2),
                                        gx.transpose(1, 0, 2)))
        h = h.transpose(1, 0, 2)
        new_cache = {"h": hT, "conv": conv_cache}
    y = jnp.einsum("bsw,wd->bsd", (h.astype(x.dtype) * gate), p["w_out"])
    return cst(y, ("batch", "res_seq", "embed")), new_cache


# ============================================================ Mamba-2 SSD

def ssd_params(pf: ParamFactory, cfg: ModelConfig, groups: tuple[int, ...]):
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    n = cfg.ssm_state
    h = inner // cfg.ssm_head_dim
    g = tuple(groups)
    gl = ("layers",) * len(groups)
    cw = cfg.conv_width
    return {
        "w_in": pf.param(g + (d, 2 * inner + 2 * n + h),
                         gl + ("wembed", "wlru")),
        "conv": pf.param(g + (cw, inner + 2 * n), gl + (None, None),
                         scale=0.5),
        "a_log": pf.param(g + (h,), gl + ("wssm_heads",), init="ssm_a",
                          dtype=jnp.float32),
        "dt_bias": pf.param(g + (h,), gl + ("wssm_heads",), init="ssm_dt",
                            dtype=jnp.float32),
        "d_skip": pf.param(g + (h,), gl + ("wssm_heads",), init="ones",
                           dtype=jnp.float32),
        "norm": pf.param(g + (inner,), gl + (None,), init="zeros"),
        "w_out": pf.param(g + (inner, d), gl + ("wlru", "wembed")),
    }


def _segsum(a):
    """a: [..., Q]; returns [..., Q, Q] with out[i,j] = sum_{j<k<=i} a_k."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, out, -jnp.inf)


def ssd_block(p, x, cfg: ModelConfig, cache=None, chunk: int = 128):
    """Mamba-2 SSD block (state-space duality, chunked scan).

    cache: {"state": [B, H, P, N] f32, "conv": [B, cw-1, inner+2N]} or None.
    """
    b, s, d = x.shape
    inner = cfg.ssm_expand * d
    n = cfg.ssm_state
    hd = cfg.ssm_head_dim
    nh = inner // hd

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z, xbc, dt = jnp.split(zxbcdt, [inner, 2 * inner + 2 * n], axis=-1)
    xbc, conv_cache = _causal_conv(
        xbc, p["conv"], None if cache is None else cache["conv"])
    xbc = jax.nn.silu(xbc)
    xin, bmat, cmat = jnp.split(xbc, [inner, inner + n], axis=-1)
    xin = cst(xin, ("batch", "seq", "act_lru"))

    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])    # [B,S,H]
    a = -jnp.exp(p["a_log"])                                        # [H]
    da = dtf * a                                                    # [B,S,H]
    xh = xin.reshape(b, s, nh, hd).astype(jnp.float32)
    bf = bmat.astype(jnp.float32)                                   # [B,S,N]
    cf = cmat.astype(jnp.float32)

    if cache is None:
        qn = min(chunk, s)
        assert s % qn == 0
        nc = s // qn
        xc = xh.reshape(b, nc, qn, nh, hd).transpose(1, 0, 2, 3, 4)
        bc = bf.reshape(b, nc, qn, n).transpose(1, 0, 2, 3)
        cc = cf.reshape(b, nc, qn, n).transpose(1, 0, 2, 3)
        dac = da.reshape(b, nc, qn, nh).transpose(1, 0, 2, 3)
        dtc = dtf.reshape(b, nc, qn, nh).transpose(1, 0, 2, 3)
        state0 = jnp.zeros((b, nh, hd, n), jnp.float32)

        def chunk_step(state, xs):
            xck, bck, cck, dack, dtck = xs              # [b,qn,...]
            acum = jnp.cumsum(dack, axis=1)             # [b,qn,h]
            l = jnp.exp(_segsum(dack.transpose(0, 2, 1)))   # [b,h,qn,qn]
            scores = jnp.einsum("bqn,bkn->bqk", cck, bck)
            y_intra = jnp.einsum("bhqk,bqk,bkh,bkhp->bqhp",
                                 l, scores, dtck, xck)
            decay_in = jnp.exp(acum)                    # [b,qn,h]
            y_inter = jnp.einsum("bqn,bqh,bhpn->bqhp", cck, decay_in, state)
            atot = acum[:, -1]                          # [b,h]
            decay_out = jnp.exp(atot[:, None, :] - acum)   # [b,qn,h]
            state_new = state * jnp.exp(atot)[:, :, None, None] + \
                jnp.einsum("bkn,bkh,bkh,bkhp->bhpn",
                           bck, decay_out, dtck, xck)
            return state_new, y_intra + y_inter

        _, ys = jax.lax.scan(chunk_step, state0, (xc, bc, cc, dac, dtc))
        y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, nh, hd)
        new_cache = None
    else:
        # sequential decode steps (s is small)
        state0 = cache["state"]

        def step(state, xs):
            xt, bt, ct, dat, dtt = xs                   # [b,...] single step
            state = state * jnp.exp(dat)[:, :, None, None] + \
                jnp.einsum("bn,bh,bhp->bhpn", bt, dtt, xt)
            yt = jnp.einsum("bn,bhpn->bhp", ct, state)
            return state, yt

        stateT, ys = jax.lax.scan(
            step, state0,
            (xh.transpose(1, 0, 2, 3), bf.transpose(1, 0, 2),
             cf.transpose(1, 0, 2), da.transpose(1, 0, 2),
             dtf.transpose(1, 0, 2)))
        y = ys.transpose(1, 0, 2, 3).reshape(b, s, nh, hd)
        new_cache = {"state": stateT, "conv": conv_cache}

    y = y + p["d_skip"][None, None, :, None] * xh
    y = y.reshape(b, s, inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return cst(out, ("batch", "res_seq", "embed")), new_cache
