"""Whisper-style encoder-decoder backbone (audio frontend is a stub: the
conv-downsampled frame embeddings arrive precomputed via input_specs, per
the assignment). LayerNorm + GELU MLPs + learned positions, bidirectional
encoder, causal decoder with cross-attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import constraint as cst

from . import layers as L
from .config import ModelConfig
from .params import ParamFactory


def _xattn_params(pf: ParamFactory, cfg: ModelConfig,
                  groups: tuple[int, ...]):
    """Cross-attention: q from decoder, k/v from encoder output."""
    return L.attention_params(pf, cfg, groups)


def param_tree(cfg: ModelConfig, mode: str, key=None):
    pf = ParamFactory(mode, key, dtype=jnp.dtype(cfg.dtype))
    v, d = cfg.vocab_size, cfg.d_model
    enc_g, dec_g = cfg.encoder_layers, cfg.n_layers
    params = {
        "embed": pf.param((v, d), ("wvocab", "wembed"), scale=0.02),
        "enc_pos": pf.param((cfg.frontend_len, d), (None, "wembed"),
                            scale=0.01),
        "enc": {
            "attn": L.attention_params(pf, cfg, (enc_g,)),
            "norm1": L.norm_params(pf, cfg, (enc_g,)),
            "mlp": L.mlp_params(pf, cfg, (enc_g,)),
            "norm2": L.norm_params(pf, cfg, (enc_g,)),
        },
        "enc_final_norm": L.norm_params(pf, cfg, ()),
        "dec": {
            "self_attn": L.attention_params(pf, cfg, (dec_g,)),
            "norm1": L.norm_params(pf, cfg, (dec_g,)),
            "cross_attn": _xattn_params(pf, cfg, (dec_g,)),
            "norm_x": L.norm_params(pf, cfg, (dec_g,)),
            "mlp": L.mlp_params(pf, cfg, (dec_g,)),
            "norm2": L.norm_params(pf, cfg, (dec_g,)),
        },
        "final_norm": L.norm_params(pf, cfg, ()),
    }
    return params


def encode(params, audio_embeds, cfg: ModelConfig):
    """audio_embeds: [B, frontend_len, D] (stub frontend output)."""
    x = audio_embeds.astype(jnp.dtype(cfg.dtype)) + params["enc_pos"]
    x = cst(x, ("batch", "seq", "embed"))

    def body(x, lp):
        h = L.apply_norm(lp["norm1"], x, cfg)
        y, _ = L.attention_block(lp["attn"], h, cfg, kind="global",
                                 causal=False, use_rope=False)
        x = x + y
        h = L.apply_norm(lp["norm2"], x, cfg)
        x = x + L.mlp_block(lp["mlp"], h, cfg)
        return x, None

    body_ = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_, x, params["enc"])
    return L.apply_norm(params["enc_final_norm"], x, cfg)


def _cross_attend(lp, x, enc_out, cfg, xkv=None):
    """Cross-attention; xkv: precomputed (k, v) from the encoder output."""
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhe->bshe", x, lp["wq"]).transpose(0, 2, 1, 3)
    if xkv is None:
        k = jnp.einsum("bsd,dhe->bshe", enc_out, lp["wk"]).transpose(0, 2, 1, 3)
        v = jnp.einsum("bsd,dhe->bshe", enc_out, lp["wv"]).transpose(0, 2, 1, 3)
    else:
        k, v = xkv
    out = L.decode_attend(q, k, v, valid_len=k.shape[2], causal=False)
    y = jnp.einsum("bshe,hed->bsd", out.transpose(0, 2, 1, 3), lp["wo"])
    return cst(y, ("batch", "seq", "embed")), (k, v)


def _decoder_block(lp, x, enc_out, cfg, cache=None, pos=None, xkv=None):
    h = L.apply_norm(lp["norm1"], x, cfg)
    y, new_kv = L.attention_block(lp["self_attn"], h, cfg, kind="global",
                                  cache=cache, pos=pos, use_rope=True)
    x = x + y
    h = L.apply_norm(lp["norm_x"], x, cfg)
    y, xkv_out = _cross_attend(lp["cross_attn"], h, enc_out, cfg, xkv=xkv)
    x = x + y
    h = L.apply_norm(lp["norm2"], x, cfg)
    x = x + L.mlp_block(lp["mlp"], h, cfg)
    return cst(x, ("batch", "seq", "embed")), new_kv, xkv_out


def hidden_states(params, tokens, audio_embeds, cfg: ModelConfig):
    """Teacher-forcing decoder hidden states (training)."""
    enc_out = encode(params, audio_embeds, cfg)
    x = params["embed"][tokens]
    x = cst(x, ("batch", "seq", "embed"))

    def body(x, lp):
        x, _, _ = _decoder_block(lp, x, enc_out, cfg)
        return x, None

    body_ = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_, x, params["dec"])
    return L.apply_norm(params["final_norm"], x, cfg), jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg: ModelConfig, *, loss_chunk: int = 512,
            z_loss: float = 1e-4):
    h, _ = hidden_states(params, batch["tokens"], batch["audio_embeds"], cfg)
    labels = batch["labels"]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    w = params["embed"]
    b, s, d = h.shape
    c = min(loss_chunk, s)
    hc = h.reshape(b, s // c, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, s // c, c).transpose(1, 0, 2)
    mc = mask.reshape(b, s // c, c).transpose(1, 0, 2)

    def chunk_loss(args):
        hx, lx, mx = args
        logits = jnp.einsum("bcd,vd->bcv", hx, w).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lx[..., None], -1)[..., 0]
        return ((lse - gold + z_loss * lse**2) * mx).sum(), mx.sum()

    sums, cnts = jax.lax.map(jax.checkpoint(chunk_loss), (hc, lc, mc))
    return sums.sum() / jnp.maximum(cnts.sum(), 1.0)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               mode: str = "init"):
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    gl = cfg.n_layers

    def mk(shape, dtype, axes):
        if mode == "axes":
            return axes
        if mode == "abstract":
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)

    self_ax = ("layers", "batch", "kv_heads", "kv_seq", "head_dim")
    cross_ax = ("layers", "batch", "kv_heads", None, "head_dim")
    return {
        "self": {"k": mk((gl, batch, cfg.n_kv_heads, max_len, hd), dt,
                         self_ax),
                 "v": mk((gl, batch, cfg.n_kv_heads, max_len, hd), dt,
                         self_ax)},
        "cross": {"k": mk((gl, batch, cfg.n_kv_heads, cfg.frontend_len, hd),
                          dt, cross_ax),
                  "v": mk((gl, batch, cfg.n_kv_heads, cfg.frontend_len, hd),
                          dt, cross_ax)},
    }


def prefill(params, tokens, audio_embeds, cfg: ModelConfig, cache):
    """Encoder + teacher-forced decoder prefill; fills self/cross caches."""
    enc_out = encode(params, audio_embeds, cfg)
    x = params["embed"][tokens]

    def body(x, xs):
        lp, sc = xs
        xn, new_kv, xkv = _decoder_block(lp, x, enc_out, cfg, cache=sc)
        return xn, (new_kv, {"k": xkv[0], "v": xkv[1]})

    x, (new_self, new_cross) = jax.lax.scan(
        body, x, (params["dec"], cache["self"]))
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = jnp.einsum("bd,vd->bv", x[:, -1], params["embed"])
    return logits, {"self": new_self, "cross": new_cross}


def decode_step(params, token, cache, pos, cfg: ModelConfig):
    x = params["embed"][token]                  # [B, 1, D]

    def body(x, xs):
        lp, sc, cc = xs
        xn, new_kv, _ = _decoder_block(lp, x, None, cfg, cache=sc, pos=pos,
                                       xkv=(cc["k"], cc["v"]))
        return xn, new_kv

    x, new_self = jax.lax.scan(body, x,
                               (params["dec"], cache["self"], cache["cross"]))
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = jnp.einsum("bd,vd->bv", x[:, 0], params["embed"])
    return logits, {"self": new_self, "cross": cache["cross"]}
