"""Pallas TPU POTRF: Cholesky of a single SPD tile (lower), in-VMEM.

The diagonal panel task. One grid step; the whole tile lives in VMEM and is
factored by b masked rank-1 column sweeps (right-looking unblocked
algorithm, identical to kernels.ref.potrf_unblocked_ref). Latency-bound by
construction -- the paper's DAG cost model rates POTRF at ~0.3 of peak,
which is exactly what a VPU-bound sweep over an MXU-sized tile gives.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import compat


def _potrf_kernel(a_ref, l_ref):
    a = a_ref[...].astype(jnp.float32)
    n = a.shape[0]
    rows = jax.lax.iota(jnp.int32, n)
    l0 = jnp.where(rows[:, None] >= rows[None, :], a, 0.0)   # tril

    def col(j, l):
        pivot = jnp.sqrt(l[j, j])
        colv = jnp.where(rows > j, l[:, j] / pivot, 0.0)
        colv = jnp.where(rows == j, pivot, colv)
        l = jnp.where(rows[None, :] == j, colv[:, None], l)
        mask = (rows[None, :] > j) & (rows[:, None] >= rows[None, :])
        return l - jnp.where(mask, colv[:, None] * colv[None, :], 0.0)

    l = jax.lax.fori_loop(0, n, col, l0)
    l_ref[...] = jnp.where(rows[:, None] >= rows[None, :], l,
                           0.0).astype(l_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def potrf_pallas(a: jax.Array, *, interpret: bool = False) -> jax.Array:
    n = a.shape[0]
    assert a.shape == (n, n)
    return pl.pallas_call(
        _potrf_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((n, n), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), a.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="repro_potrf",
    )(a)
