"""Dispatch layer: Pallas kernels on TPU, pure-jnp paths elsewhere.

Backend selection:
    "pallas"     real TPU lowering (Mosaic)
    "interpret"  Pallas interpret mode -- kernel body runs on CPU (tests)
    "jnp"        pure-jnp reference/chunked paths (CPU runs + dry-run
                 lowering, so compiled HLO contains real, analyzable FLOPs)
Default: "pallas" on TPU backends, "jnp" otherwise.
"""

from __future__ import annotations

import functools
from contextlib import contextmanager

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_pallas
from .gemm import gemm_pallas
from .potrf import potrf_pallas
from .syrk import syrk_pallas
from .trsm import trsm_pallas

_BACKEND: str | None = None          # None = auto


def set_backend(name: str | None) -> None:
    global _BACKEND
    assert name in (None, "pallas", "interpret", "jnp"), name
    _BACKEND = name


def backend() -> str:
    if _BACKEND is not None:
        return _BACKEND
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


@contextmanager
def use_backend(name: str):
    prev = _BACKEND
    set_backend(name)
    try:
        yield
    finally:
        set_backend(prev)


def _pallas_kwargs() -> dict:
    return {"interpret": backend() == "interpret"}


# ----------------------------------------------------------------- BLAS-3
def gemm(a, b, c=None, *, alpha: float = 1.0, beta: float = 1.0):
    if backend() == "jnp":
        return ref.gemm_ref(a, b, c, alpha, beta)
    return gemm_pallas(a, b, c, alpha=alpha, beta=beta, **_pallas_kwargs())


def syrk(a, c, *, alpha: float = -1.0, beta: float = 1.0):
    if backend() == "jnp":
        return ref.syrk_ref(a, c, alpha, beta)
    return syrk_pallas(a, c, alpha=alpha, beta=beta, **_pallas_kwargs())


def trsm(l, b, *, unit_diag: bool = False):
    """X @ L^T = B."""
    if backend() == "jnp":
        return ref.trsm_ref(l, b, unit_diag=unit_diag)
    return trsm_pallas(l, b, unit_diag=unit_diag, **_pallas_kwargs())


# --------------------------------------------------------------- panel ops
def potrf(a):
    if backend() == "jnp":
        return ref.potrf_ref(a)
    return potrf_pallas(a, **_pallas_kwargs())


def getrf(a):
    """Unblocked LU of the diagonal tile (jnp on all backends: latency-bound
    panel op; the Pallas win lives in the trailing update)."""
    return ref.getrf_nopiv_ref(a)


def geqrt(a):
    """Householder panel factorization (V, T, R); jnp on all backends
    (unrolled columns for small tiles, fori_loop for production widths)."""
    return ref.householder_qr(a)


def apply_reflector(v, t, c):
    """C := (I - V T V^T)^T C. Three GEMMs; routed through the GEMM kernel
    when shapes are MXU-tileable, else jnp."""
    if backend() == "jnp" or c.shape[1] % 128 != 0 or v.shape[0] % 128 != 0:
        return ref.apply_block_reflector_ref(v, t, c)
    w = gemm(v.T, c, alpha=1.0, beta=0.0)
    tw = ref.gemm_ref(t.T, w)                      # (b,b) tiny
    return gemm(v, tw, c, alpha=-1.0, beta=1.0)


# ------------------------------------------------------------- attention
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    softcap: float | None = None, scale: float | None = None,
                    q_chunk: int = 1024, k_chunk: int = 1024):
    """FlashAttention: Pallas kernel on TPU, chunked-scan jnp elsewhere."""
    if backend() == "jnp":
        return attention_chunked(q, k, v, causal=causal, window=window,
                                 softcap=softcap, scale=scale,
                                 q_chunk=q_chunk, k_chunk=k_chunk)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  softcap=softcap, scale=scale,
                                  **_pallas_kwargs())


def _dividing_chunk(s: int, c: int) -> int:
    """Largest chunk <= c that divides s (1500 with c=1024 -> 750)."""
    c = min(c, s)
    while s % c:
        c -= 1
    return c


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "q_chunk", "k_chunk"))
def attention_chunked(q, k, v, *, causal: bool = True,
                      window: int | None = None, softcap: float | None = None,
                      scale: float | None = None, q_chunk: int = 1024,
                      k_chunk: int = 1024):
    """Memory-bounded online-softmax attention in pure jnp (double scan).

    Numerically the same online-softmax recurrence as the Pallas kernel;
    never materializes more than (q_chunk x k_chunk) logits per (b, h). The
    kv-step is rematerialized on backward (flash-style training memory).
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale_ = scale if scale is not None else d ** -0.5
    q_chunk = _dividing_chunk(sq, q_chunk)
    k_chunk = _dividing_chunk(skv, k_chunk)
    nq, nk = sq // q_chunk, skv // k_chunk
    offset = skv - sq

    qg = q.reshape(b, hkv, group, nq, q_chunk, d).astype(jnp.float32)
    kc = k.reshape(b, hkv, nk, k_chunk, d).astype(jnp.float32)
    vc = v.reshape(b, hkv, nk, k_chunk, d).astype(jnp.float32)
    kc = jnp.moveaxis(kc, 2, 0)        # (nk, b, hkv, k_chunk, d)
    vc = jnp.moveaxis(vc, 2, 0)

    def q_block(qi, qblk):             # qblk: (b, hkv, group, q_chunk, d)
        qpos = offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, xs):
            m, l, acc = carry
            kb, vb, ki = xs
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qblk, kb) * scale_
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            kpos = ki * k_chunk + jnp.arange(k_chunk)
            mask = jnp.ones((q_chunk, k_chunk), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
            m_next = jnp.maximum(m, s.max(axis=-1))
            m_safe = jnp.where(jnp.isneginf(m_next), 0.0, m_next)
            alpha = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
            p = jnp.exp(s - m_safe[..., None])
            l_next = alpha * l + p.sum(axis=-1)
            acc_next = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vb)
            return (m_next, l_next, acc_next), None

        init = (jnp.full((b, hkv, group, q_chunk), -jnp.inf),
                jnp.zeros((b, hkv, group, q_chunk)),
                jnp.zeros((b, hkv, group, q_chunk, d)))
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step), init, (kc, vc, jnp.arange(nk)))
        denom = jnp.where(l == 0.0, 1.0, l)
        return acc / denom[..., None]

    qg = jnp.moveaxis(qg, 3, 0)        # (nq, b, hkv, group, q_chunk, d)
    out = jax.lax.map(lambda xs: q_block(*xs), (jnp.arange(nq), qg))
    out = jnp.moveaxis(out, 0, 3)      # (b, hkv, group, nq, q_chunk, d)
    return out.reshape(b, hq, sq, d).astype(q.dtype)
