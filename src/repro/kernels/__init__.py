"""Pallas TPU kernels for the compute hot-spots, with jnp oracles.

Layout (per repo convention):
    <name>.py  -- pl.pallas_call + BlockSpec kernels (gemm, syrk, trsm,
                  potrf, flash_attention)
    ops.py     -- jit'd dispatch wrappers (pallas | interpret | jnp)
    ref.py     -- pure-jnp oracles every kernel is validated against
    compat.py  -- pallas version shims (CompilerParams vs TPUCompilerParams)
"""

from . import compat, ops, ref
from .flash_attention import flash_attention_pallas
from .gemm import gemm_pallas
from .potrf import potrf_pallas
from .syrk import syrk_pallas
from .trsm import trsm_pallas

__all__ = ["compat", "ops", "ref", "gemm_pallas", "syrk_pallas",
           "trsm_pallas", "potrf_pallas", "flash_attention_pallas"]
