"""Version shims for jax.experimental.pallas across jax releases.

`pltpu.CompilerParams` was introduced as the public name for the Mosaic
compiler-parameter struct; older releases (e.g. jax 0.4.x) only expose it
as `pltpu.TPUCompilerParams`. Both accept `dimension_semantics=...`, which
is all the kernels here use. Resolve whichever exists once, at import time,
so every kernel module can say `compat.CompilerParams(...)`.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

if hasattr(pltpu, "CompilerParams"):
    CompilerParams = pltpu.CompilerParams
else:                                       # jax <= 0.4.x
    CompilerParams = pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
