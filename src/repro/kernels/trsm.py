"""Pallas TPU TRSM: solve X @ L^T = B for X, with L lower-triangular b x b.

The Cholesky panel update (TRSM(i,k) tasks). B is (m x b) with m a multiple
of the row block; L stays VMEM-resident across the whole solve while row
blocks of B stream through. The triangular solve itself is formulated as b
masked rank-1 sweeps (column substitution) -- VPU-bound but tiny next to
the trailing GEMM, exactly as the paper's task cost model assumes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import compat


def _trsm_kernel(l_ref, b_ref, x_ref, *, unit_diag: bool):
    l = l_ref[...].astype(jnp.float32)
    bmat = b_ref[...].astype(jnp.float32)
    nb = l.shape[0]
    cols = jax.lax.iota(jnp.int32, nb)

    def body(j, x):
        # X[:, j] = (B[:, j] - X[:, :j] @ L[j, :j]) / L[j, j]
        lrow = jnp.where(cols < j, l[j, :], 0.0)
        resid = bmat[:, j] - x @ lrow
        denom = 1.0 if unit_diag else l[j, j]
        xj = resid / denom
        return jnp.where(cols[None, :] == j, xj[:, None], x)

    x = jax.lax.fori_loop(0, nb, body, jnp.zeros_like(bmat))
    x_ref[...] = x.astype(x_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "unit_diag", "interpret"))
def trsm_pallas(l: jax.Array, b: jax.Array, *, bm: int = 256,
                unit_diag: bool = False, interpret: bool = False) -> jax.Array:
    """X such that X @ L^T = B; L: (nb, nb) lower, B: (m, nb)."""
    nb = l.shape[0]
    m = b.shape[0]
    assert l.shape == (nb, nb) and b.shape[1] == nb
    bm = min(bm, m)
    assert m % bm == 0
    kernel = functools.partial(_trsm_kernel, unit_diag=unit_diag)
    return pl.pallas_call(
        kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((nb, nb), lambda i: (0, 0)),   # L resident
            pl.BlockSpec((bm, nb), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, nb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, nb), b.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
        name="repro_trsm",
    )(l, b)
