"""Pallas TPU GEMM: C := alpha * A @ B + beta * C.

The trailing-matrix-update workhorse (Cholesky GEMM, LU GEMM, QR SSRFB are
all this shape) and the LM matmul hot-spot.

Blocking: 3-D grid (M/bm, N/bn, K/bk) with a float32 VMEM accumulator.
The K axis is the innermost ("arbitrary") grid dimension so each (i, j)
output tile stays resident in the accumulator across K steps; A/B tiles
stream HBM->VMEM. Default 256x256x256 bf16 blocks: 3 x 256KiB in-flight
blocks + 256KiB accumulator, comfortably inside the ~16 MiB v5e VMEM with
double buffering, and all dims multiples of the 128x128 MXU tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import compat


def _gemm_kernel(a_ref, b_ref, c_ref, o_ref, acc_ref, *,
                 alpha: float, beta: float, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        out = alpha * acc_ref[...]
        if beta != 0.0:
            out = out + beta * c_ref[...].astype(jnp.float32)
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "bm", "bn",
                                             "bk", "interpret"))
def gemm_pallas(a: jax.Array, b: jax.Array, c: jax.Array | None = None,
                *, alpha: float = 1.0, beta: float = 1.0,
                bm: int = 256, bn: int = 256, bk: int = 256,
                interpret: bool = False) -> jax.Array:
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        f"shapes ({m},{n},{k}) must tile by ({bm},{bn},{bk})"
    if c is None:
        c = jnp.zeros((m, n), a.dtype)
        beta = 0.0
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    kernel = functools.partial(_gemm_kernel, alpha=alpha, beta=beta,
                               k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="repro_gemm",
    )(a, b, c)
