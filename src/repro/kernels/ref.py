"""Pure-jnp reference oracles for every Pallas kernel and tile op.

These are the ground truth the Pallas kernels are validated against
(tests run the kernels in interpret mode and assert_allclose vs these),
and the CPU execution path of the whole framework.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular


# ----------------------------------------------------------------- BLAS-3
def gemm_ref(a: jax.Array, b: jax.Array, c: jax.Array | None = None,
             alpha: float = 1.0, beta: float = 1.0) -> jax.Array:
    """C := alpha * A @ B + beta * C."""
    out = alpha * (a @ b)
    if c is not None:
        out = out + beta * c
    return out


def syrk_ref(a: jax.Array, c: jax.Array, alpha: float = -1.0,
             beta: float = 1.0) -> jax.Array:
    """Symmetric rank-k update (lower): C := alpha * A @ A^T + beta * C.

    Only the lower triangle is meaningful; we compute the full product and
    let the caller use the lower part (cheap and MXU-friendly).
    """
    return alpha * (a @ a.T) + beta * c


def trsm_ref(l: jax.Array, b: jax.Array, *, side: str = "right",
             trans: bool = True, unit_diag: bool = False) -> jax.Array:
    """Triangular solve with a LOWER-triangular L.

    side="right", trans=True : X solves X @ L^T = B   (Cholesky panel)
    side="left",  trans=False: X solves L @ X = B     (LU row update)
    """
    if side == "right":
        # X L^T = B  <=>  L X^T = B^T
        xt = solve_triangular(l, b.T, lower=True,
                              trans="T" if not trans else "N",
                              unit_diagonal=unit_diag)
        return xt.T
    return solve_triangular(l, b, lower=True,
                            trans="T" if trans else "N",
                            unit_diagonal=unit_diag)


def trsm_upper_right_ref(u: jax.Array, b: jax.Array) -> jax.Array:
    """X solves X @ U = B with U upper triangular (LU column update)."""
    xt = solve_triangular(u.T, b.T, lower=True)
    return xt.T


# --------------------------------------------------------------- panel ops
def potrf_ref(a: jax.Array) -> jax.Array:
    """Cholesky of an SPD tile (lower)."""
    return jnp.linalg.cholesky(a)


def potrf_unblocked_ref(a: jax.Array) -> jax.Array:
    """Column-by-column unblocked Cholesky -- mirrors the Pallas kernel's
    algorithm exactly (used to pin down its numerics)."""
    n = a.shape[0]
    l = jnp.tril(a)

    def col(j, l):
        pivot = jnp.sqrt(l[j, j])
        colv = l[:, j] / pivot
        colv = jnp.where(jnp.arange(n) >= j, colv, 0.0).at[j].set(pivot)
        l = l.at[:, j].set(colv)
        # trailing update: l[:, j+1:] -= colv * colv[j+1:]^T (lower part)
        mask = (jnp.arange(n)[None, :] > j) & \
               (jnp.arange(n)[:, None] >= jnp.arange(n)[None, :])
        upd = jnp.outer(colv, colv)
        return l - jnp.where(mask, upd, 0.0)

    l = jax.lax.fori_loop(0, n, col, l, unroll=False)
    return jnp.tril(l)


def getrf_nopiv_ref(a: jax.Array) -> jax.Array:
    """Unblocked LU without pivoting; returns packed LU (unit-lower L)."""
    n = a.shape[0]

    def col(k, m):
        pivot = m[k, k]
        lcol = m[:, k] / pivot
        lcol = jnp.where(jnp.arange(n) > k, lcol, m[:, k])
        m = m.at[:, k].set(lcol)
        mask = (jnp.arange(n)[:, None] > k) & (jnp.arange(n)[None, :] > k)
        upd = jnp.outer(lcol, m[k, :])
        return m - jnp.where(mask, upd, 0.0)

    return jax.lax.fori_loop(0, n, col, a)


def householder_qr_ref(a: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Compact-WY Householder QR of an m x n tile (m >= n).

    Returns (V, T, R): Q = I - V @ T @ V^T (T upper triangular),
    V unit-lower-trapezoidal, R upper triangular n x n.
    """
    m, n = a.shape
    dt = a.dtype
    V = jnp.zeros((m, n), dt)
    T = jnp.zeros((n, n), dt)
    R = a
    rows = jnp.arange(m)
    for j in range(n):                       # static tile width
        x = jnp.where(rows >= j, R[:, j], 0.0)
        normx = jnp.linalg.norm(x)
        sign_xj = jnp.where(x[j] >= 0, 1.0, -1.0)
        alpha = -sign_xj * normx
        # guard the zero column edge case
        alpha = jnp.where(normx == 0, -1.0, alpha)
        v = x.at[j].add(-alpha)
        vnorm = jnp.linalg.norm(v)
        v = jnp.where(vnorm > 0, v / vnorm, v)
        beta = 2.0
        # R := (I - beta v v^T) R
        R = R - beta * jnp.outer(v, v @ R)
        # accumulate compact WY: T[:j, j] = -beta * T[:j,:j] @ (V[:, :j]^T v)
        tcol = -beta * (T[:, :] @ (V.T @ v))
        tcol = jnp.where(jnp.arange(n) < j, tcol, 0.0).at[j].set(beta)
        T = T.at[:, j].set(tcol)
        V = V.at[:, j].set(v)
    return V, T, jnp.triu(R[:n, :])


def householder_qr_loop(a: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """fori_loop compact-WY Householder QR (same math as householder_qr_ref,
    one HLO while-loop instead of n unrolled columns -- the path the
    distributed QR uses for production tile widths, where unrolling b
    columns would explode the module)."""
    m, n = a.shape
    dt = a.dtype
    rows = jnp.arange(m)

    def col(j, carry):
        V, T, R = carry
        x = jnp.where(rows >= j, R[:, j], 0.0)
        normx = jnp.linalg.norm(x)
        xj = jnp.take(x, j)
        sign_xj = jnp.where(xj >= 0, 1.0, -1.0)
        alpha = jnp.where(normx == 0, -1.0, -sign_xj * normx)
        v = x.at[j].add(-alpha)
        vnorm = jnp.linalg.norm(v)
        v = jnp.where(vnorm > 0, v / vnorm, v)
        beta = jnp.asarray(2.0, dt)
        R = R - beta * jnp.outer(v, v @ R)
        tcol = -beta * (T @ (V.T @ v))
        tcol = jnp.where(jnp.arange(n) < j, tcol, 0.0).at[j].set(beta)
        T = T.at[:, j].set(tcol)
        V = V.at[:, j].set(v)
        return V, T, R

    # carries derive from `a` (not fresh zeros) so their varying-manual-axes
    # type matches the body's outputs under shard_map (scan-vma rule)
    V0 = a * jnp.asarray(0.0, dt)
    T0 = a[:n, :] * jnp.asarray(0.0, dt)
    V, T, R = jax.lax.fori_loop(0, n, col, (V0, T0, a))
    return V, T, jnp.triu(R[:n, :])


def householder_qr(a: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Dispatch: unrolled columns for test-size tiles, while-loop above."""
    if a.shape[1] <= 64:
        return householder_qr_ref(a)
    return householder_qr_loop(a)


def cholqr2(a: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """CholeskyQR2 panel factorization with Yamamoto's compact-WY
    reconstruction -- the TPU-native panel QR (EXPERIMENTS.md S-Perf/qr).

    The column-by-column Householder panel streams the m x b panel b times
    (hopelessly HBM-bound at production tile sizes); CholeskyQR2 touches it
    ~4 times, all through MXU-shaped b x b matmuls:

        [Q, R] = cholqr(cholqr(A));   A = Q (R2 R1)
        W = Q - E1,  T~ = (I - Q_top)^-T   =>   Q_full = I - W T~ W^T

    Returns (W, T~, R) with the SAME contract as householder_qr: applying
    C - W T~^T (W^T C) realizes Q_full^T C, so the distributed trailing
    update is unchanged. Caveat: I - Q_top must be nonsingular (fails only
    when the panel is already upper-triangular with positive diagonal --
    see tests); production fallback is householder_qr.
    """
    m, b = a.shape

    def _cholqr(s):
        g = s.T @ s
        r = jnp.linalg.cholesky(g).T                   # upper
        q = trsm_upper_right_ref(r, s)                 # Q = S R^-1
        return q, r

    q1, r1 = _cholqr(a)
    q, r2 = _cholqr(q1)
    r = r2 @ r1
    w = q.at[:b].add(-jnp.eye(b, dtype=a.dtype))
    t_til = jnp.linalg.inv(jnp.eye(b, dtype=a.dtype) - q[:b]).T
    return w, t_til, r


def apply_block_reflector_ref(v: jax.Array, t: jax.Array,
                              c: jax.Array) -> jax.Array:
    """C := (I - V T V^T)^T C = C - V T^T V^T C   (applies Q^T)."""
    w = v.T @ c
    return c - v @ (t.T @ w)


# ------------------------------------------------------------- attention
def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int | None = None,
                  softcap: float | None = None,
                  scale: float | None = None) -> jax.Array:
    """Naive full-materialization attention oracle.

    q: [B, Hq, Sq, D], k/v: [B, Hkv, Skv, D] (GQA: Hq % Hkv == 0).
    `window`: sliding-window size (local attention); None = full.
    `softcap`: Gemma-2 logit soft-capping: cap * tanh(logits / cap).
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    scale = scale if scale is not None else d ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kx) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    skv = k.shape[2]
    qpos = jnp.arange(sq)[:, None] + (skv - sq)   # align ends (decode-friendly)
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(mask[None, None], probs, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, vx)
