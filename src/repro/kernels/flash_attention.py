"""Pallas TPU FlashAttention-2 (forward) with GQA / causal / sliding-window /
logit-softcap support.

Grid (B*Hq, Sq/bq, Skv/bk); the KV axis is innermost ("arbitrary") so the
running max / denominator / output accumulator stay VMEM-resident per query
block (online softmax). GQA is handled in the K/V BlockSpec index maps
(query head -> kv head), so no repeated-KV materialization ever happens.
Fully-masked KV blocks are skipped under `pl.when` (causal: upper-right
blocks; sliding window: lower-left blocks), which is where the FLOP savings
of local attention come from.

Default blocks 512(q) x 512(kv) x head_dim: q/k/v blocks + fp32 accumulator
fit VMEM for head_dim <= 256 with double buffering; MXU dims 128-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import compat

_LANES = 128          # TPU vector lane count for 2-D scratch


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               scale: float, causal: bool, window: int | None,
               softcap: float | None, k_steps: int, bq: int, bk: int,
               offset: int):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * bq + offset          # absolute position of first query
    k_start = ki * bk
    live = jnp.bool_(True)
    if causal:
        live &= k_start <= q_start + bq - 1
    if window is not None:
        live &= k_start + bk - 1 > q_start - window

    @pl.when(live)
    def _block():
        q = q_ref[0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0].astype(jnp.float32)            # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.iota(jnp.int32, bq)[:, None]
        kpos = k_start + jax.lax.iota(jnp.int32, bk)[None, :]
        mask = jnp.bool_(jnp.ones((bq, bk), jnp.bool_))
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, -jnp.inf)

        m_prev = m_ref[:, 0]                        # (bq,)
        l_prev = l_ref[:, 0]
        m_next = jnp.maximum(m_prev, s.max(axis=-1))
        m_safe = jnp.where(jnp.isneginf(m_next), 0.0, m_next)
        alpha = jnp.where(jnp.isneginf(m_prev), 0.0,
                          jnp.exp(m_prev - m_safe))
        p = jnp.exp(s - m_safe[:, None])            # masked entries -> 0
        l_next = alpha * l_prev + p.sum(axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot(p.astype(v.dtype), v,
                                      preferred_element_type=jnp.float32))
        m_ref[...] = jnp.broadcast_to(m_next[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_next[:, None], l_ref.shape)

    @pl.when(ki == k_steps - 1)
    def _done():
        l = l_ref[:, 0]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "bq", "bk", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int | None = None,
                           softcap: float | None = None,
                           scale: float | None = None,
                           bq: int = 512, bk: int = 512,
                           interpret: bool = False) -> jax.Array:
    """q: [B, Hq, Sq, D]; k, v: [B, Hkv, Skv, D]; returns [B, Hq, Sq, D]."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0

    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, d)
    k_steps = skv // bk

    def kv_row(bh):
        return (bh // hq) * hkv + (bh % hq) // group

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, k_steps=k_steps, bq=bq, bk=bk, offset=skv - sq)

    of = pl.pallas_call(
        kernel,
        grid=(b * hq, sq // bq, k_steps),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (kv_row(bh), ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (kv_row(bh), ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, _LANES), jnp.float32),
            pltpu.VMEM((bq, _LANES), jnp.float32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="repro_flash_attention",
    )(qf, kf, vf)
    return of.reshape(b, hq, sq, d)
