"""Pallas TPU SYRK: C := beta * C + alpha * A @ A^T (lower-symmetric).

Cholesky's trailing update. Grid (M/bm, M/bn, K/bk); output tiles strictly
above the block diagonal are passed through untouched (symmetry makes them
dead), halving MXU work versus a plain GEMM of the same shape.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import compat


def _syrk_kernel(a_ref, at_ref, c_ref, o_ref, acc_ref, *,
                 alpha: float, beta: float, k_steps: int):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(i >= j)          # lower (block) triangle only
    def _mac():
        acc_ref[...] += jnp.dot(a_ref[...], at_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        live = alpha * acc_ref[...] + beta * c_ref[...].astype(jnp.float32)
        o_ref[...] = jnp.where(i >= j, live,
                               c_ref[...].astype(jnp.float32)
                               ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("alpha", "beta", "bm", "bk",
                                             "interpret"))
def syrk_pallas(a: jax.Array, c: jax.Array, *, alpha: float = -1.0,
                beta: float = 1.0, bm: int = 256, bk: int = 256,
                interpret: bool = False) -> jax.Array:
    m, k = a.shape
    assert c.shape == (m, m)
    bm, bk = min(bm, m), min(bk, k)
    assert m % bm == 0 and k % bk == 0
    k_steps = k // bk
    grid = (m // bm, m // bm, k_steps)
    kernel = functools.partial(_syrk_kernel, alpha=alpha, beta=beta,
                               k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            # A^T streamed as row-blocks of A transposed inside the kernel
            # via a second view of A with swapped index map
            pl.BlockSpec((bk, bm), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bm), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bm), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, m), c.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bm), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="repro_syrk",
    )(a, a.T, c)
