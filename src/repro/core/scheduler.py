"""Distributed schedule simulator: task graph + frequency plans -> timelines,
power traces, and nodal energy.

Execution semantics mirror the SPMD factorization codes the paper measures:
each rank executes *its own tasks in program order* (owner computes); a task
starts when (a) the rank is free and (b) every dependency's output has
arrived (cross-rank edges pay tile_bytes/bandwidth + latency). This is an
event-driven list schedule; with per-rank program order fixed, it is
deterministic.

Gear mechanics simulated:
  * per-task frequency plans (list of (gear, seconds) segments),
  * gear-switch stalls: switching costs `switch_latency_s`; a stall delays
    the rank unless the switch was issued during a wait (`hidden` policy --
    possible only when the schedule is known in advance, i.e. the paper's
    algorithmic strategy, or proactively predicted, i.e. CP-aware),
  * idle gears: what a rank runs at while waiting (race-to-halt & friends
    drop to f_min; `original` stays at the top gear),
  * per-task runtime overhead (CP-detection / completion-monitoring cost).

Energy = sum over per-rank piecewise-constant power segments
       + gear-switch energies
       + nodal constant power * makespan * n_nodes.

Two engines compute the same schedule:

  * `simulate`           -- event-driven: a ready-heap keyed on earliest
                            feasible start plus per-task remaining-dependency
                            counters decremented on completion events.
                            O((n + e) log n) dispatch instead of scanning
                            every rank's head task per pick.
  * `simulate_reference` -- the original O(n_tasks x n_ranks x deps)
                            pick-loop, kept verbatim as a slow, obviously-
                            correct oracle for the differential test suite
                            (`tests/test_scheduler_differential.py`).

Because a task's timing depends only on its rank's previous task and its
dependencies' finish times, dispatch order between ranks cannot change the
result; both engines produce bit-identical timelines and switch counts (the
switch-energy sum may differ by accumulation order, within 1e-9).

Heterogeneous machines: both engines accept a `MachineModel` (per-rank
ProcessorModels -- asymmetric clusters) wherever a `ProcessorModel` is
taken; gear indices in a plan's segments are then interpreted against the
*owning rank's* gear table, and switch latency/energy, idle gears, and
power curves are all per-rank. `MachineModel.homogeneous(proc)` is a
provable no-op (every per-rank lookup returns the same object), so the
homogeneous path stays bit-identical to the legacy single-processor code.
Per the PR 1 policy, the per-rank generalization was applied to BOTH
engines in lockstep and the differential suite gained mixed-machine cases.
"""

from __future__ import annotations

import dataclasses
import functools
import heapq
from typing import Sequence

import numpy as np

from .dag import KIND_EFFICIENCY, TaskGraph
from .dvfs import Segment
from .energy_model import (Gear, LinkModel, MachineModel, ProcessorModel,
                           as_machine)


@dataclasses.dataclass
class CostModel:
    """Analytic task/communication cost model (rank == core)."""

    flops_per_cycle: float = 4.0            # fp64 FMA pipes per core
    kind_efficiency: dict[str, float] = dataclasses.field(
        default_factory=lambda: dict(KIND_EFFICIENCY))
    # frequency sensitivity per kind (beta); default: compute-bound
    freq_sensitivity: dict[str, float] = dataclasses.field(default_factory=dict)
    comm_bandwidth_gbs: float = 5.0         # 40 Gb/s InfiniBand
    comm_latency_s: float = 5e-6
    # per-rank-pair link overrides; the trivial default keeps the legacy
    # scalar comm path (bit-identical, see LinkModel)
    link: LinkModel = dataclasses.field(default_factory=LinkModel)

    def beta(self, kind: str) -> float:
        """Frequency sensitivity of a task kind (1.0 = compute-bound)."""
        return self.freq_sensitivity.get(kind, 1.0)

    def duration_top(self, flops: float, kind: str, proc: ProcessorModel) -> float:
        """Duration at the *owning rank's* top gear; pass that rank's
        ProcessorModel (`MachineModel.proc_for_rank`) on mixed machines."""
        rate = (proc.f_max * 1e9 * self.flops_per_cycle
                * self.kind_efficiency.get(kind, 0.8))
        return flops / rate

    def durations_top(self, graph: TaskGraph,
                      proc: ProcessorModel | MachineModel) -> np.ndarray:
        """Vectorized `duration_top` over every task in the graph.

        With a `MachineModel`, each task's duration is referenced to its
        owner rank's own top gear (fast ranks finish sooner), which is
        what keeps downstream slack/TDS classification correct when fast
        and slow ranks coexist.
        """
        eff = np.asarray([self.kind_efficiency.get(t.kind, 0.8)
                          for t in graph.tasks])
        flops = np.asarray([t.flops for t in graph.tasks])
        machine = as_machine(proc)
        if machine.is_homogeneous:
            f_max = machine.procs[0].f_max
        else:
            procs = machine.rank_procs(graph.n_ranks)
            f_max = np.asarray([procs[t.owner].f_max for t in graph.tasks])
        return flops / (f_max * 1e9 * self.flops_per_cycle * eff)

    def comm_time(self, graph: TaskGraph) -> float:
        """Cross-rank transfer time of one tile: bytes/bandwidth + latency."""
        return graph.tile_bytes / (self.comm_bandwidth_gbs * 1e9) \
            + self.comm_latency_s

    def comm_cost(self, graph: TaskGraph) -> "float | np.ndarray":
        """Per-edge transfer pricing: the legacy scalar or a link matrix.

        With the trivial default `link`, returns the scalar
        `comm_time(graph)` -- the engines and analyses then take their
        original uniform-comm code paths, bit-identical to the pre-link
        implementation. A non-trivial `LinkModel` yields the (R, R)
        per-rank-pair transfer-time matrix (zero diagonal) instead; every
        consumer (`simulate`, `simulate_reference`, `simulate_fleet`,
        `cp_analysis`, `schedule_slack`, `analyze_tds`, the residual
        analyses, and `CandidateEvaluator`) accepts both forms.
        """
        if self.link.is_trivial:
            return self.comm_time(graph)
        return self.link.time_matrix(graph.n_ranks, graph.tile_bytes,
                                     self.comm_bandwidth_gbs,
                                     self.comm_latency_s)

    def comm_energy_matrix(self, graph: TaskGraph) -> "np.ndarray | None":
        """(R, R) wire energy per transferred tile, or None when trivial.

        None (the trivial-link default) means every transfer is free --
        the engines then skip comm-energy accounting entirely, keeping
        totals bit-identical to the pre-link implementation.
        """
        if self.link.is_trivial:
            return None
        return self.link.energy_matrix(graph.n_ranks, graph.tile_bytes)


@dataclasses.dataclass
class RankSegment:
    """One piecewise-constant span of a rank's timeline."""

    t0: float
    t1: float
    gear: Gear
    active: bool          # computing vs idle/waiting


# Per-rank timeline as flat columns: (t0, t1, gear_index, active). Cheap for
# the engines to emit and for energy/power queries to vectorize over.
SegColumns = tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]


@dataclasses.dataclass
class Schedule:
    """A simulated execution: per-task times, per-rank timelines, energy."""

    graph: TaskGraph
    proc: ProcessorModel | MachineModel
    start: np.ndarray
    finish: np.ndarray
    seg_columns: list[SegColumns]
    switch_count: int
    switch_energy_j: float
    cores_per_node: int = 16
    comm_energy_j: float = 0.0     # wire energy of cross-rank transfers

    @classmethod
    def from_rank_segments(cls, graph: TaskGraph,
                           proc: ProcessorModel | MachineModel,
                           start: np.ndarray, finish: np.ndarray,
                           rank_segments: list[list[RankSegment]],
                           switch_count: int, switch_energy_j: float,
                           cores_per_node: int = 16,
                           comm_energy_j: float = 0.0) -> "Schedule":
        """Build from the classic list-of-RankSegment representation."""
        cols: list[SegColumns] = [
            (np.asarray([s.t0 for s in segs]),
             np.asarray([s.t1 for s in segs]),
             np.asarray([s.gear.index for s in segs], dtype=np.int64),
             np.asarray([s.active for s in segs], dtype=bool))
            for segs in rank_segments
        ]
        return cls(graph, proc, start, finish, cols, switch_count,
                   switch_energy_j, cores_per_node, comm_energy_j)

    @functools.cached_property
    def machine(self) -> MachineModel:
        """The (possibly homogeneous-wrapped) per-rank machine model."""
        return as_machine(self.proc)

    @functools.cached_property
    def rank_segments(self) -> list[list[RankSegment]]:
        """Materialized per-rank RankSegment lists (cached). Gear indices
        resolve against each rank's own gear table."""
        procs = self.machine.rank_procs(self.graph.n_ranks)
        return [
            [RankSegment(float(a), float(b), procs[r].gears[g], bool(ac))
             for a, b, g, ac in zip(*cols)]
            for r, cols in enumerate(self.seg_columns)
        ]

    @property
    def makespan(self) -> float:
        """End-to-end wall time: the latest task finish."""
        return float(self.finish.max()) if len(self.finish) else 0.0

    @property
    def n_nodes(self) -> int:
        """Node count at `cores_per_node` ranks per node (min 1).

        Ceil division: a partially filled last node still burns its full
        constant power (board, fans, NICs), so 24 ranks at 16 cores/node
        occupy 2 nodes, not 1 -- floor division silently dropped ranks
        16..23 from the nodal accounting and every power/energy query.
        """
        return max(1, -(-self.graph.n_ranks // self.cores_per_node))

    @staticmethod
    def _power_table(proc: ProcessorModel) -> np.ndarray:
        """power_w[gear_index, active_as_int]."""
        return np.array([[proc.core_power_w(g, False),
                          proc.core_power_w(g, True)]
                         for g in proc.gears])

    def _rank_power_tables(self) -> list[np.ndarray]:
        """One power table per rank, computed once per distinct processor."""
        cache: dict[int, np.ndarray] = {}
        tables = []
        for p in self.machine.rank_procs(self.graph.n_ranks):
            t = cache.get(id(p))
            if t is None:
                t = cache[id(p)] = self._power_table(p)
            tables.append(t)
        return tables

    def _node_ranks(self, nd: int) -> range:
        return range(nd * self.cores_per_node,
                     min((nd + 1) * self.cores_per_node, self.graph.n_ranks))

    def nodal_const_power_w(self, nodes: Sequence[int] | None = None) -> float:
        """Total non-CPU constant power of the given nodes (default: all).

        Homogeneous machines use the legacy n_nodes * P_const expression
        verbatim; on a mixed machine each node charges the mean P_const of
        its ranks' processor models (mixed nodes share boards/fans).
        """
        return machine_nodal_const_power_w(self.machine, self.graph.n_ranks,
                                           self.cores_per_node, nodes)

    def core_energy_j(self) -> float:
        """CPU-core energy: per-rank power curves integrated over segments."""
        pw_tables = self._rank_power_tables()
        e = 0.0
        for pw, (t0, t1, gi, act) in zip(pw_tables, self.seg_columns):
            if len(t0):
                e += float(pw[gi, act.astype(np.int64)] @ (t1 - t0))
        return e

    def total_energy_j(self) -> float:
        """Core energy + gear-switch energy + nodal constant * makespan,
        plus the link transfer energy (exactly 0.0 under a trivial
        `LinkModel`, so the legacy total is preserved bitwise)."""
        return (self.core_energy_j() + self.switch_energy_j
                + self.nodal_const_power_w() * self.makespan
                + self.comm_energy_j)

    def power_trace(self, times: np.ndarray,
                    nodes: Sequence[int] | None = None) -> np.ndarray:
        """Total power (W) of the given nodes sampled at `times`."""
        if nodes is None:
            nodes = range(self.n_nodes)
        nodes = list(nodes)
        ranks: list[int] = []
        for nd in nodes:
            ranks.extend(self._node_ranks(nd))
        pw_tables = self._rank_power_tables()
        watts = np.full(times.shape, self.nodal_const_power_w(nodes))
        for r in ranks:
            t0, t1, gi, act = self.seg_columns[r]
            if not len(t0):
                continue
            pw = pw_tables[r]
            idx = np.searchsorted(t0, times, side="right") - 1
            idx = np.clip(idx, 0, len(t0) - 1)
            p = pw[gi, act.astype(np.int64)]
            inside = (times >= t0[0]) & (times <= t1[-1])
            # outside the rank's timeline it idles at its starting (top)
            # gear before the first segment -- both engines boot every rank
            # at gear index 0 -- and at its final gear after the last one
            outside = np.where(times < t0[0], pw[0, 0], pw[gi[-1], 0])
            watts = watts + np.where(inside, p[idx], outside)
        return watts


def machine_nodal_const_power_w(machine: ProcessorModel | MachineModel,
                                n_ranks: int, cores_per_node: int = 16,
                                nodes: Sequence[int] | None = None) -> float:
    """Total non-CPU constant power of the given nodes (default: all).

    The single source of truth for nodal constant-power accounting, shared
    by `Schedule.nodal_const_power_w` and the batched fleet engine
    (`repro.core.fleet`). Node count is the *ceiling* of
    `n_ranks / cores_per_node`: a partially filled last node still burns
    its full board/fan power, and its ranks still count.

    Parameters
    ----------
    machine : ProcessorModel or MachineModel
        Power model; a bare processor means a homogeneous machine.
    n_ranks : int
        Ranks of the job whose nodes are being charged.
    cores_per_node : int, optional
        Ranks packed per node (default 16).
    nodes : sequence of int, optional
        Node indices to charge; default all occupied nodes.

    Returns
    -------
    float
        Watts of constant power. Homogeneous machines charge
        `len(nodes) * P_const` verbatim; on a mixed machine each node
        charges the mean P_const of its ranks' processor models (mixed
        nodes share boards/fans).
    """
    machine = as_machine(machine)
    n_nodes = max(1, -(-n_ranks // cores_per_node))
    if nodes is None:
        nodes = range(n_nodes)
    nodes = list(nodes)
    if machine.is_homogeneous:
        return float(len(nodes)) * machine.procs[0].p_const_watts
    procs = machine.rank_procs(n_ranks)
    total = 0.0
    for nd in nodes:
        ranks = range(nd * cores_per_node,
                      min((nd + 1) * cores_per_node, n_ranks))
        if len(ranks):
            total += sum(procs[r].p_const_watts for r in ranks) / len(ranks)
        else:
            total += machine.proc_for_rank(nd * cores_per_node).p_const_watts
    return total


@dataclasses.dataclass
class StrategyPlan:
    """Everything a strategy decides; consumed by `simulate`.

    On a heterogeneous machine every gear in `task_segments[tid]` must
    belong to the *owning rank's* gear table (the engines index power and
    switch tables by `gear.index` against that rank's processor), and
    `rank_idle_gears` supplies the per-rank idle gear -- `idle_gear` alone
    cannot name "each rank's lowest gear" when ladders differ. Leaving
    `rank_idle_gears` as None (the homogeneous case) keeps the plan
    byte-for-byte what the legacy single-processor planner emitted.

    `task_owners` is the migration axis: a per-task rank override that
    re-maps tasks away from `graph.tasks[tid].owner` (the frozen
    block-cyclic layout). All three engines honor it in lockstep --
    per-rank program order becomes tid order within each *effective*
    rank, cross-rank comm is priced between effective owners, and every
    segment gear must come from the effective owner's ladder. None (the
    default) keeps the graph's own mapping and is byte-for-byte the
    pre-migration plan.
    """

    name: str
    task_segments: list[list[Segment]]       # per task: [(gear, seconds)]
    idle_gear: Gear                           # gear while waiting
    per_task_overhead: np.ndarray             # seconds of runtime overhead
    hide_switch_in_wait: bool                 # pre-armed switches (offline plan)
    min_halt_window_s: float = 0.0            # don't downshift for tiny gaps
    rank_idle_gears: Sequence[Gear] | None = None   # per-rank idle override
    task_owners: Sequence[int] | None = None  # migration: per-task rank

    def idle_gear_for(self, rank: int) -> Gear:
        """The gear rank `rank` waits at (per-rank override or global)."""
        if self.rank_idle_gears is not None:
            return self.rank_idle_gears[rank]
        return self.idle_gear


def _effective_owners(graph: TaskGraph,
                      plan: StrategyPlan) -> list[int] | None:
    """The plan's validated per-task rank mapping, or None for the graph's
    own (no-migration) layout. Shared by all three engines."""
    if plan.task_owners is None:
        return None
    owners = [int(o) for o in np.asarray(plan.task_owners).tolist()]
    if len(owners) != len(graph.tasks):
        raise ValueError(f"task_owners has {len(owners)} entries for "
                         f"{len(graph.tasks)} tasks")
    n_ranks = graph.n_ranks
    for o in owners:
        if not 0 <= o < n_ranks:
            raise ValueError(f"task_owners rank {o} outside [0, {n_ranks})")
    return owners


def _owner_program_order(graph: TaskGraph,
                         owners: Sequence[int]) -> list[list[int]]:
    """Per-rank program order under a migration mapping: tid order within
    each effective rank (tids are emitted in SPMD loop order, so this is
    exactly how `TaskGraph.tasks_by_rank` orders the frozen layout)."""
    per = [[] for _ in range(graph.n_ranks)]
    for t in graph.tasks:
        per[owners[t.tid]].append(t.tid)
    return per


def plan_comm_energy_j(graph: TaskGraph, cost: CostModel,
                       owners: Sequence[int] | None = None) -> float:
    """Total wire energy of one execution of `graph` under `cost.link`.

    Sums the link's per-transfer energy over every dependency edge whose
    endpoints live on different (effective) ranks; `owners` supplies a
    migration mapping (default: the graph's own layout). Exactly 0.0
    with the trivial default `LinkModel` -- the engines add this into
    `Schedule.total_energy_j` without perturbing the legacy total.
    """
    em = cost.comm_energy_matrix(graph)
    if em is None:
        return 0.0
    src, dst, _ = graph.dep_edge_arrays()
    if not len(src):
        return 0.0
    if owners is None:
        own = np.asarray([t.owner for t in graph.tasks], dtype=np.int64)
    else:
        own = np.asarray(owners, dtype=np.int64)
    # the matrix diagonal is zero, so owner-local edges charge nothing
    return float(em[own[src], own[dst]].sum())


def simulate(graph: TaskGraph, proc: ProcessorModel | MachineModel,
             cost: CostModel, plan: StrategyPlan) -> Schedule:
    """Event-driven engine: ready-heap + remaining-dependency counters.

    A task enters the heap the moment it becomes schedulable -- it is the
    head of its rank's program order AND its last outstanding dependency
    has finished -- keyed on its earliest feasible start. Executing a task
    can only unlock (never re-time) other tasks, so each task is pushed
    exactly once and popped with its final start time. Produces timelines
    bit-identical to `simulate_reference` (the differential suite asserts
    this across randomized DAGs, grids, gear tables, strategies, and
    mixed per-rank machines).

    Parameters
    ----------
    graph : TaskGraph
        The task DAG with its block-cyclic ownership (owner computes).
    proc : ProcessorModel or MachineModel
        Power/gear model; a `MachineModel` assigns one per rank.
    cost : CostModel
        Supplies the cross-rank communication time.
    plan : StrategyPlan
        Per-task frequency segments plus the idle-gear / switch policy.

    Returns
    -------
    Schedule
        Per-task start/finish, per-rank segment columns, switch counts
        and energy -- everything the energy model integrates over.
    """
    n = len(graph.tasks)
    n_ranks = graph.n_ranks
    comm_val = cost.comm_cost(graph)
    if isinstance(comm_val, np.ndarray):
        comm, cm = 0.0, comm_val.tolist()    # per-pair path (nested lists:
    else:                                    # scalar access is the hot loop)
        comm, cm = comm_val, None            # legacy uniform path, verbatim
    machine = as_machine(proc)
    procs = machine.rank_procs(n_ranks)

    owners_ovr = _effective_owners(graph, plan)
    per_rank = graph.tasks_by_rank() if owners_ovr is None \
        else _owner_program_order(graph, owners_ovr)
    ptr = [0] * n_ranks
    rank_free = [0.0] * n_ranks
    rank_gear = [0] * n_ranks                  # gear indices; 0 = top gear
    # per-rank segment columns, emitted flat (no per-segment objects)
    seg_t0: list[list[float]] = [[] for _ in range(n_ranks)]
    seg_t1: list[list[float]] = [[] for _ in range(n_ranks)]
    seg_gi: list[list[int]] = [[] for _ in range(n_ranks)]
    seg_act: list[list[bool]] = [[] for _ in range(n_ranks)]
    switch_count = 0
    switch_energy = 0.0
    # per-rank DVFS mechanics: switch latency, halt window, idle gear, and
    # memoized per-transition energies (identical floats to switch_energy_j;
    # one table per distinct processor, shared across its ranks)
    t_sw = [p.switch_latency_s for p in procs]
    halt_win = [max(plan.min_halt_window_s, 2.0 * t) for t in t_sw]
    idle_idx = [plan.idle_gear_for(r).index for r in range(n_ranks)]
    _sw_cache: dict[int, list[list[float]]] = {}
    sw_e = []
    for p in procs:
        tab = _sw_cache.get(id(p))
        if tab is None:
            tab = _sw_cache[id(p)] = [[p.switch_energy_j(a, b)
                                       for b in p.gears] for a in p.gears]
        sw_e.append(tab)

    # flat per-task state in plain Python lists: scalar access is the hot
    # path and list indexing is markedly faster than ndarray item access
    tasks = graph.tasks
    owner = [t.owner for t in tasks] if owners_ovr is None else owners_ovr
    deps = [t.deps for t in tasks]
    succ = graph.successors()
    n_wait = [len(d) for d in deps]        # remaining-dependency counters
    start = [0.0] * n
    fin = [0.0] * n
    queued = [False] * n
    task_segments = plan.task_segments
    overhead = plan.per_task_overhead.tolist()
    hide = plan.hide_switch_in_wait
    heappush, heappop = heapq.heappush, heapq.heappop

    heap: list[tuple[float, int]] = []
    for r in range(n_ranks):
        if per_rank[r]:
            tid = per_rank[r][0]
            if not n_wait[tid]:
                queued[tid] = True
                heappush(heap, (0.0, tid))   # roots: rank free at t=0, no deps

    remaining = n
    while heap:
        best_start, tid = heappop(heap)
        r = owner[tid]
        segs = task_segments[tid]
        gear_now = rank_gear[r]
        first_gear = segs[0][0].index if segs else gear_now
        t_now = rank_free[r]
        wait = best_start - t_now
        et0, et1, egi, eact = seg_t0[r], seg_t1[r], seg_gi[r], seg_act[r]

        # ---- waiting period handling (idle gear + switches) -------------
        if wait > 1e-15:
            if idle_idx[r] != gear_now and wait >= halt_win[r]:
                # downshift for the wait
                switch_count += 1
                switch_energy += sw_e[r][gear_now][idle_idx[r]]
                gear_now = idle_idx[r]
            et0.append(t_now)
            et1.append(best_start)
            egi.append(gear_now)
            eact.append(False)

        # ---- gear switch into the task's first segment ------------------
        t_exec = best_start
        if first_gear != gear_now:
            switch_count += 1
            switch_energy += sw_e[r][gear_now][first_gear]
            if not (hide and wait >= t_sw[r]):
                et0.append(t_exec)
                et1.append(t_exec + t_sw[r])
                egi.append(first_gear)
                eact.append(False)
                t_exec += t_sw[r]
            gear_now = first_gear

        # ---- runtime overhead (detection / monitoring) -------------------
        ovh = overhead[tid]
        if ovh > 0.0:
            et0.append(t_exec)
            et1.append(t_exec + ovh)
            egi.append(gear_now)
            eact.append(True)
            t_exec += ovh

        # ---- execute the task's frequency segments -----------------------
        start[tid] = t_exec
        for gear, dt in segs:
            gi = gear.index
            if gi != gear_now:
                switch_count += 1
                switch_energy += sw_e[r][gear_now][gi]
                # mid-task switches are always planned -> no stall modeled
                gear_now = gi
            et0.append(t_exec)
            et1.append(t_exec + dt)
            egi.append(gi)
            eact.append(True)
            t_exec += dt
        fin[tid] = t_exec
        rank_free[r] = t_exec
        rank_gear[r] = gear_now
        p = ptr[r] + 1
        ptr[r] = p
        remaining -= 1

        # completion event: unlock successors, then re-arm this rank's head
        successors = succ[tid]
        for s in successors:
            n_wait[s] -= 1
        rank_tasks = per_rank[r]
        if p < len(rank_tasks):
            h = rank_tasks[p]
            if not n_wait[h] and not queued[h]:
                ready = t_exec               # == rank_free[r]
                for d in deps[h]:
                    arr = fin[d] + ((comm if owner[d] != r else 0.0)
                                    if cm is None else cm[owner[d]][r])
                    if arr > ready:
                        ready = arr
                queued[h] = True
                heappush(heap, (ready, h))
        for s in successors:
            if not n_wait[s] and not queued[s]:
                rs = owner[s]
                if per_rank[rs][ptr[rs]] == s:
                    ready = rank_free[rs]
                    for d in deps[s]:
                        arr = fin[d] + ((comm if owner[d] != rs else 0.0)
                                        if cm is None else cm[owner[d]][rs])
                        if arr > ready:
                            ready = arr
                    queued[s] = True
                    heappush(heap, (ready, s))

    if remaining:   # cannot happen on a valid program order
        raise RuntimeError("deadlock in schedule simulation")

    start_a = np.asarray(start)
    finish_a = np.asarray(fin)

    # trailing idle until global makespan (ranks that finish early)
    makespan = float(finish_a.max()) if n else 0.0
    for r in range(n_ranks):
        if rank_free[r] < makespan - 1e-15:
            if idle_idx[r] != rank_gear[r]:
                switch_count += 1
                switch_energy += sw_e[r][rank_gear[r]][idle_idx[r]]
            seg_t0[r].append(rank_free[r])
            seg_t1[r].append(makespan)
            seg_gi[r].append(idle_idx[r])
            seg_act[r].append(False)

    cols: list[SegColumns] = [
        (np.asarray(seg_t0[r]), np.asarray(seg_t1[r]),
         np.asarray(seg_gi[r], dtype=np.int64),
         np.asarray(seg_act[r], dtype=bool))
        for r in range(n_ranks)
    ]
    return Schedule(graph, proc, start_a, finish_a, cols,
                    switch_count, switch_energy,
                    comm_energy_j=plan_comm_energy_j(graph, cost,
                                                     owners_ovr))


def simulate_reference(graph: TaskGraph, proc: ProcessorModel | MachineModel,
                       cost: CostModel, plan: StrategyPlan) -> Schedule:
    """The original O(tasks x ranks x deps) pick-loop, kept structurally
    verbatim (per-rank processor lookups are the only generalization,
    applied in lockstep with `simulate` per the PR 1 policy).

    Slow but obviously correct: every pick scans all ranks' head tasks and
    re-derives feasibility from first principles. The differential suite
    runs this oracle against `simulate` and asserts agreement to 1e-9.

    Parameters
    ----------
    graph, proc, cost, plan
        Exactly as for `simulate`; the two engines are drop-in
        interchangeable by contract.

    Returns
    -------
    Schedule
        The same schedule `simulate` produces (bit-identical timelines
        and switch counts; switch-energy sums agree to 1e-9).
    """
    n = len(graph.tasks)
    comm_val = cost.comm_cost(graph)
    if isinstance(comm_val, np.ndarray):
        comm, cm = 0.0, comm_val.tolist()    # per-pair link path
    else:
        comm, cm = comm_val, None            # legacy uniform path, verbatim
    machine = as_machine(proc)
    procs = machine.rank_procs(graph.n_ranks)
    start = np.zeros(n)
    finish = np.zeros(n)
    done = np.zeros(n, dtype=bool)

    owners_ovr = _effective_owners(graph, plan)
    per_rank = graph.tasks_by_rank() if owners_ovr is None \
        else _owner_program_order(graph, owners_ovr)
    own = [t.owner for t in graph.tasks] if owners_ovr is None \
        else owners_ovr
    ptr = [0] * graph.n_ranks
    rank_free = [0.0] * graph.n_ranks
    rank_gear: list[Gear] = [p.gears[0] for p in procs]
    segments: list[list[RankSegment]] = [[] for _ in range(graph.n_ranks)]
    switch_count = 0
    switch_energy = 0.0

    remaining = n
    while remaining:
        # pick the feasible rank whose next task can start earliest
        best_rank, best_start = -1, np.inf
        for r in range(graph.n_ranks):
            if ptr[r] >= len(per_rank[r]):
                continue
            tid = per_rank[r][ptr[r]]
            t = graph.tasks[tid]
            ready = rank_free[r]
            feasible = True
            for d in t.deps:
                if not done[d]:
                    feasible = False
                    break
                arr = finish[d] + ((comm if own[d] != r else 0.0)
                                   if cm is None else cm[own[d]][r])
                ready = max(ready, arr)
            if feasible and ready < best_start:
                best_rank, best_start = r, ready
        if best_rank < 0:   # cannot happen on a valid program order
            raise RuntimeError("deadlock in schedule simulation")

        r = best_rank
        proc_r = procs[r]
        t_sw = proc_r.switch_latency_s
        halt_win = max(plan.min_halt_window_s, 2.0 * t_sw)
        idle_gear = plan.idle_gear_for(r)
        tid = per_rank[r][ptr[r]]
        segs = plan.task_segments[tid]
        first_gear = segs[0][0] if segs else rank_gear[r]
        t_now = rank_free[r]
        wait = best_start - t_now

        # ---- waiting period handling (idle gear + switches) -------------
        if wait > 1e-15:
            if (idle_gear.index != rank_gear[r].index
                    and wait >= halt_win):
                # downshift for the wait
                switch_count += 1
                switch_energy += proc_r.switch_energy_j(rank_gear[r],
                                                        idle_gear)
                segments[r].append(RankSegment(t_now, best_start,
                                               idle_gear, False))
                rank_gear[r] = idle_gear
            else:
                segments[r].append(RankSegment(t_now, best_start,
                                               rank_gear[r], False))

        # ---- gear switch into the task's first segment ------------------
        t_exec = best_start
        if first_gear.index != rank_gear[r].index:
            switch_count += 1
            switch_energy += proc_r.switch_energy_j(rank_gear[r], first_gear)
            hidden = plan.hide_switch_in_wait and wait >= t_sw
            if not hidden:
                segments[r].append(RankSegment(t_exec, t_exec + t_sw,
                                               first_gear, False))
                t_exec += t_sw
            rank_gear[r] = first_gear

        # ---- runtime overhead (detection / monitoring) -------------------
        ovh = float(plan.per_task_overhead[tid])
        if ovh > 0.0:
            segments[r].append(RankSegment(t_exec, t_exec + ovh,
                                           rank_gear[r], True))
            t_exec += ovh

        # ---- execute the task's frequency segments -----------------------
        start[tid] = t_exec
        for gear, dt in segs:
            if gear.index != rank_gear[r].index:
                switch_count += 1
                switch_energy += proc_r.switch_energy_j(rank_gear[r], gear)
                # mid-task switches are always planned -> no stall modeled
                rank_gear[r] = gear
            segments[r].append(RankSegment(t_exec, t_exec + dt, gear, True))
            t_exec += dt
        finish[tid] = t_exec
        rank_free[r] = t_exec
        done[tid] = True
        ptr[r] += 1
        remaining -= 1

    # trailing idle until global makespan (ranks that finish early)
    makespan = float(finish.max()) if n else 0.0
    for r in range(graph.n_ranks):
        if rank_free[r] < makespan - 1e-15:
            gear = plan.idle_gear_for(r)
            if gear.index != rank_gear[r].index:
                switch_count += 1
                switch_energy += procs[r].switch_energy_j(rank_gear[r], gear)
            segments[r].append(RankSegment(rank_free[r], makespan, gear, False))

    return Schedule.from_rank_segments(
        graph, proc, start, finish, segments, switch_count, switch_energy,
        comm_energy_j=plan_comm_energy_j(graph, cost, owners_ovr))
