"""Distributed schedule simulator: task graph + frequency plans -> timelines,
power traces, and nodal energy.

Execution semantics mirror the SPMD factorization codes the paper measures:
each rank executes *its own tasks in program order* (owner computes); a task
starts when (a) the rank is free and (b) every dependency's output has
arrived (cross-rank edges pay tile_bytes/bandwidth + latency). This is an
event-driven list schedule; with per-rank program order fixed, it is
deterministic.

Gear mechanics simulated:
  * per-task frequency plans (list of (gear, seconds) segments),
  * gear-switch stalls: switching costs `switch_latency_s`; a stall delays
    the rank unless the switch was issued during a wait (`hidden` policy --
    possible only when the schedule is known in advance, i.e. the paper's
    algorithmic strategy, or proactively predicted, i.e. CP-aware),
  * idle gears: what a rank runs at while waiting (race-to-halt & friends
    drop to f_min; `original` stays at the top gear),
  * per-task runtime overhead (CP-detection / completion-monitoring cost).

Energy = sum over per-rank piecewise-constant power segments
       + gear-switch energies
       + nodal constant power * makespan * n_nodes.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .dag import KIND_EFFICIENCY, TaskGraph
from .dvfs import Segment
from .energy_model import Gear, ProcessorModel


@dataclasses.dataclass
class CostModel:
    """Analytic task/communication cost model (rank == core)."""

    flops_per_cycle: float = 4.0            # fp64 FMA pipes per core
    kind_efficiency: dict[str, float] = dataclasses.field(
        default_factory=lambda: dict(KIND_EFFICIENCY))
    # frequency sensitivity per kind (beta); default: compute-bound
    freq_sensitivity: dict[str, float] = dataclasses.field(default_factory=dict)
    comm_bandwidth_gbs: float = 5.0         # 40 Gb/s InfiniBand
    comm_latency_s: float = 5e-6

    def beta(self, kind: str) -> float:
        return self.freq_sensitivity.get(kind, 1.0)

    def duration_top(self, flops: float, kind: str, proc: ProcessorModel) -> float:
        rate = (proc.f_max * 1e9 * self.flops_per_cycle
                * self.kind_efficiency.get(kind, 0.8))
        return flops / rate

    def comm_time(self, graph: TaskGraph) -> float:
        return graph.tile_bytes / (self.comm_bandwidth_gbs * 1e9) \
            + self.comm_latency_s


@dataclasses.dataclass
class RankSegment:
    t0: float
    t1: float
    gear: Gear
    active: bool          # computing vs idle/waiting


@dataclasses.dataclass
class Schedule:
    graph: TaskGraph
    proc: ProcessorModel
    start: np.ndarray
    finish: np.ndarray
    rank_segments: list[list[RankSegment]]
    switch_count: int
    switch_energy_j: float
    cores_per_node: int = 16

    @property
    def makespan(self) -> float:
        return float(self.finish.max()) if len(self.finish) else 0.0

    @property
    def n_nodes(self) -> int:
        return max(1, self.graph.n_ranks // self.cores_per_node)

    def core_energy_j(self) -> float:
        e = 0.0
        for segs in self.rank_segments:
            for s in segs:
                e += self.proc.core_power_w(s.gear, s.active) * (s.t1 - s.t0)
        return e

    def total_energy_j(self) -> float:
        return (self.core_energy_j() + self.switch_energy_j
                + self.n_nodes * self.proc.p_const_watts * self.makespan)

    def power_trace(self, times: np.ndarray,
                    nodes: Sequence[int] | None = None) -> np.ndarray:
        """Total power (W) of the given nodes sampled at `times`."""
        if nodes is None:
            nodes = range(self.n_nodes)
        ranks: list[int] = []
        for nd in nodes:
            ranks.extend(range(nd * self.cores_per_node,
                               min((nd + 1) * self.cores_per_node,
                                   self.graph.n_ranks)))
        watts = np.full(times.shape, float(len(list(nodes))) *
                        self.proc.p_const_watts)
        for r in ranks:
            segs = self.rank_segments[r]
            if not segs:
                continue
            t0s = np.array([s.t0 for s in segs])
            idx = np.searchsorted(t0s, times, side="right") - 1
            idx = np.clip(idx, 0, len(segs) - 1)
            p = np.array([self.proc.core_power_w(s.gear, s.active)
                          for s in segs])
            inside = (times >= segs[0].t0) & (times <= segs[-1].t1)
            watts = watts + np.where(inside, p[idx], p[-1] * 0 +
                                     self.proc.core_power_w(
                                         segs[-1].gear, False))
        return watts


@dataclasses.dataclass
class StrategyPlan:
    """Everything a strategy decides; consumed by `simulate`."""

    name: str
    task_segments: list[list[Segment]]       # per task: [(gear, seconds)]
    idle_gear: Gear                           # gear while waiting
    per_task_overhead: np.ndarray             # seconds of runtime overhead
    hide_switch_in_wait: bool                 # pre-armed switches (offline plan)
    min_halt_window_s: float = 0.0            # don't downshift for tiny gaps


def simulate(graph: TaskGraph, proc: ProcessorModel, cost: CostModel,
             plan: StrategyPlan) -> Schedule:
    n = len(graph.tasks)
    comm = cost.comm_time(graph)
    start = np.zeros(n)
    finish = np.zeros(n)
    done = np.zeros(n, dtype=bool)

    per_rank = graph.tasks_by_rank()
    ptr = [0] * graph.n_ranks
    rank_free = [0.0] * graph.n_ranks
    rank_gear: list[Gear] = [proc.gears[0]] * graph.n_ranks
    segments: list[list[RankSegment]] = [[] for _ in range(graph.n_ranks)]
    switch_count = 0
    switch_energy = 0.0
    t_sw = proc.switch_latency_s
    halt_win = max(plan.min_halt_window_s, 2.0 * t_sw)

    remaining = n
    while remaining:
        # pick the feasible rank whose next task can start earliest
        best_rank, best_start = -1, np.inf
        for r in range(graph.n_ranks):
            if ptr[r] >= len(per_rank[r]):
                continue
            tid = per_rank[r][ptr[r]]
            t = graph.tasks[tid]
            ready = rank_free[r]
            feasible = True
            for d in t.deps:
                if not done[d]:
                    feasible = False
                    break
                arr = finish[d] + (comm if graph.tasks[d].owner != r else 0.0)
                ready = max(ready, arr)
            if feasible and ready < best_start:
                best_rank, best_start = r, ready
        if best_rank < 0:   # cannot happen on a valid program order
            raise RuntimeError("deadlock in schedule simulation")

        r = best_rank
        tid = per_rank[r][ptr[r]]
        segs = plan.task_segments[tid]
        first_gear = segs[0][0] if segs else rank_gear[r]
        t_now = rank_free[r]
        wait = best_start - t_now

        # ---- waiting period handling (idle gear + switches) -------------
        if wait > 1e-15:
            if (plan.idle_gear.index != rank_gear[r].index
                    and wait >= halt_win):
                # downshift for the wait
                switch_count += 1
                switch_energy += proc.switch_energy_j(rank_gear[r],
                                                      plan.idle_gear)
                segments[r].append(RankSegment(t_now, best_start,
                                               plan.idle_gear, False))
                rank_gear[r] = plan.idle_gear
            else:
                segments[r].append(RankSegment(t_now, best_start,
                                               rank_gear[r], False))

        # ---- gear switch into the task's first segment ------------------
        t_exec = best_start
        if first_gear.index != rank_gear[r].index:
            switch_count += 1
            switch_energy += proc.switch_energy_j(rank_gear[r], first_gear)
            hidden = plan.hide_switch_in_wait and wait >= t_sw
            if not hidden:
                segments[r].append(RankSegment(t_exec, t_exec + t_sw,
                                               first_gear, False))
                t_exec += t_sw
            rank_gear[r] = first_gear

        # ---- runtime overhead (detection / monitoring) -------------------
        ovh = float(plan.per_task_overhead[tid])
        if ovh > 0.0:
            segments[r].append(RankSegment(t_exec, t_exec + ovh,
                                           rank_gear[r], True))
            t_exec += ovh

        # ---- execute the task's frequency segments -----------------------
        start[tid] = t_exec
        for gear, dt in segs:
            if gear.index != rank_gear[r].index:
                switch_count += 1
                switch_energy += proc.switch_energy_j(rank_gear[r], gear)
                # mid-task switches are always planned -> no stall modeled
                rank_gear[r] = gear
            segments[r].append(RankSegment(t_exec, t_exec + dt, gear, True))
            t_exec += dt
        finish[tid] = t_exec
        rank_free[r] = t_exec
        done[tid] = True
        ptr[r] += 1
        remaining -= 1

    # trailing idle until global makespan (ranks that finish early)
    makespan = float(finish.max()) if n else 0.0
    for r in range(graph.n_ranks):
        if rank_free[r] < makespan - 1e-15:
            gear = plan.idle_gear
            if gear.index != rank_gear[r].index:
                switch_count += 1
                switch_energy += proc.switch_energy_j(rank_gear[r], gear)
            segments[r].append(RankSegment(rank_free[r], makespan, gear, False))

    return Schedule(graph, proc, start, finish, segments,
                    switch_count, switch_energy)
