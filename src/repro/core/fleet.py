"""Batched structure-of-arrays schedule engine: B plan lanes in one pass.

`simulate` and `simulate_reference` evaluate one `(machine, plan)` pair at a
time, which is exactly the wrong shape for the repo's expensive analyses --
`single_freq_opt`'s per-depth candidate sweep, the noise x seed x cadence
grids in `benchmarks/strategy_gap.py`, and `core/optimize.py`'s plan search
all evaluate *many variants of the same task graph*. `simulate_fleet` runs
B such lanes in a single pass: a Python loop over dependency *waves* (not
individual tasks), with every per-lane quantity (rank clocks, gear
indices, energy and switch accumulators) held in NumPy arrays whose
trailing axis is the lane.

Why the wave sweep is a valid schedule: both serial engines rely on the
invariant that a task's timing depends only on its rank's previous task
and its dependencies' finish times, so dispatch order between ranks
cannot change the result. Task ids are emitted topologically sorted AND
in per-rank program order, so any order that respects dependencies and
per-rank tid order is admissible. `_wave_structure` groups tasks by
longest-path depth over the dependency DAG *augmented with each rank's
tid chain*: within a wave no two tasks share a rank and every
dependency/rank-predecessor sits in an earlier wave, so a whole wave is
one block of vectorized array operations (tasks x lanes at once) and the
engine still computes the same unique fixed point the pick-loop oracle
does, just for B lanes -- and k tasks -- at a time.

Exactness contract (the *three-engine* differential policy):

  * per-lane `start`/`finish` timelines and switch **counts** are
    bit-identical to `simulate`/`simulate_reference` -- every timeline
    float is produced by the same sequence of IEEE operations (the
    per-segment fold `t += dt` is replicated via zero-padded segment
    slots, exact because `x + 0.0 == x` for finite x);
  * energy sums (`core_energy_j`, `switch_energy_j`, `total_energy_j`)
    agree to 1e-9 relative -- accumulation *order* differs across lanes,
    the same documented tolerance the two serial engines already carry.

Any engine-visible semantic change must now land in all THREE engines in
lockstep, and `tests/test_scheduler_differential.py` runs fleet lanes over
randomized DAGs, strategies, and mixed `MachineModel`s to hold the line.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .dag import TaskGraph
from .energy_model import MachineModel, ProcessorModel, as_machine
from .scheduler import (CostModel, Schedule, StrategyPlan,
                        _effective_owners, machine_nodal_const_power_w,
                        plan_comm_energy_j, simulate)

__all__ = ["FleetSchedule", "simulate_fleet"]


@dataclasses.dataclass
class FleetSchedule:
    """B simulated lanes of one task graph, stored as stacked arrays.

    The batched counterpart of `Schedule`: per-lane task times and energy
    accumulators without per-lane `Schedule` (or per-rank segment) objects.
    Row i of every array is lane i, i.e. the schedule of
    `(machines[i], plans[i])` on the shared graph/cost model.
    """

    graph: TaskGraph
    machines: list[MachineModel]
    cost: CostModel
    plans: list[StrategyPlan]
    start: np.ndarray            # (B, n_tasks) task start times
    finish: np.ndarray           # (B, n_tasks) task finish times
    switch_count: np.ndarray     # (B,) int64 DVFS transitions per lane
    switch_energy_j: np.ndarray  # (B,) switch energy per lane
    core_energy_j: np.ndarray    # (B,) integrated core power per lane
    nodal_const_w: np.ndarray    # (B,) constant nodal power per lane
    cores_per_node: int = 16
    # (B,) wire energy per lane, or None under a trivial LinkModel (the
    # legacy zero-comm-energy path, kept bit-identical by skipping the add)
    comm_energy_j: np.ndarray | None = None

    @property
    def n_lanes(self) -> int:
        """Number of schedule lanes B in this fleet."""
        return len(self.plans)

    @property
    def makespan(self) -> np.ndarray:
        """(B,) end-to-end wall time per lane (latest task finish)."""
        if self.finish.shape[1]:
            return self.finish.max(axis=1)
        return np.zeros(self.finish.shape[0])

    def total_energy_j(self) -> np.ndarray:
        """(B,) core energy + switch energy + nodal constant * makespan,
        plus per-lane link transfer energy under a non-trivial `LinkModel`.

        Lane-for-lane this is `Schedule.total_energy_j()` to 1e-9 relative
        (the documented cross-engine energy tolerance).
        """
        total = (self.core_energy_j + self.switch_energy_j
                 + self.nodal_const_w * self.makespan)
        if self.comm_energy_j is not None:
            total = total + self.comm_energy_j
        return total

    def lane(self, i: int) -> Schedule:
        """Materialize lane `i` as a full `Schedule` (debugging escape hatch).

        Re-runs the event-driven engine for that lane's `(machine, plan)`
        pair -- exact by the differential contract -- so the result carries
        the per-rank segment timelines the fleet pass never builds.
        """
        sched = simulate(self.graph, self.machines[i], self.cost,
                         self.plans[i])
        if sched.cores_per_node != self.cores_per_node:
            sched = dataclasses.replace(sched,
                                        cores_per_node=self.cores_per_node)
        return sched


def _proc_tables(procs: list[ProcessorModel]):
    """Padded per-processor lookup tables (active/idle power, switch energy,
    switch latency), indexed by a compact processor code."""
    g_max = max(len(p.gears) for p in procs)
    n_proc = len(procs)
    pw_act = np.zeros((n_proc, g_max))
    pw_idle = np.zeros((n_proc, g_max))
    sw_e = np.zeros((n_proc, g_max, g_max))
    t_sw = np.zeros(n_proc)
    for c, p in enumerate(procs):
        t_sw[c] = p.switch_latency_s
        for a, ga in enumerate(p.gears):
            pw_act[c, a] = p.core_power_w(ga, True)
            pw_idle[c, a] = p.core_power_w(ga, False)
            for b, gb in enumerate(p.gears):
                sw_e[c, a, b] = p.switch_energy_j(ga, gb)
    return pw_act, pw_idle, sw_e, t_sw


def _segment_slots(plans: Sequence[StrategyPlan], n: int):
    """Zero-padded per-slot segment arrays across all lanes.

    Returns `(counts2d, gears, dts)` where `counts2d[t, l]` is lane l's
    segment count for task t and `gears`/`dts` are `(P, n, B)` arrays
    (P = max segment count) with gear index 0 / duration 0.0 padding.
    The 0.0 padding is what keeps the batched time fold bit-identical to
    the serial engines: adding 0.0 never perturbs a finite float.
    """
    b = len(plans)
    counts2d = np.zeros((n, b), dtype=np.int64)
    for l, plan in enumerate(plans):
        counts2d[:, l] = np.fromiter(map(len, plan.task_segments),
                                     np.int64, n)
    p_max = int(counts2d.max()) if counts2d.size else 0
    gears = np.zeros((p_max, n, b), dtype=np.int64)
    dts = np.zeros((p_max, n, b))
    task_ids = np.arange(n)
    for l, plan in enumerate(plans):
        cl = counts2d[:, l]
        total = int(cl.sum())
        if not total:
            continue
        flat = [pair for segs in plan.task_segments for pair in segs]
        g_l = np.fromiter((pair[0].index for pair in flat), np.int64, total)
        d_l = np.fromiter((pair[1] for pair in flat), np.float64, total)
        task_rep = np.repeat(task_ids, cl)
        pos = np.arange(total) - np.repeat(np.cumsum(cl) - cl, cl)
        gears[pos, task_rep, l] = g_l
        dts[pos, task_rep, l] = d_l
    return counts2d, gears, dts


def _wave_structure(n: int, n_ranks: int, owner, dep_info):
    """Group tasks into dependency-and-rank-order waves for the lane pass.

    A task's wave index is its longest-path depth over the dependency DAG
    *augmented with each rank's tid-order chain*: `wave(t) = 1 + max(wave
    of every dependency, wave of the rank's previous task)`. Within one
    wave no two tasks share a rank and every dependency (and every rank
    predecessor) sits in a strictly earlier wave, so the whole wave is
    computable from earlier-wave state in one block of vectorized array
    operations -- and processing waves in order replays every per-rank
    state write in tid order, i.e. the pass reaches the same unique fixed
    point as a task-by-task tid-order sweep, bit for bit.

    Returns a list of `(tids, tid_list, ranks, dep_idx, comm)` tuples:
    `tids`/`ranks` are (k,) index arrays (`tid_list` the plain-list twin
    for cheap Python-side lookups), `dep_idx` is a (k, D) dependency-tid
    array right-padded with `n` -- the finish buffer's extra pad row,
    pinned at 0.0 and therefore never above a rank clock, so padding can
    never win the readiness max -- or None when the wave has no
    dependencies at all, and `comm` is the matching (k, D, 1) per-edge
    communication adder (0.0 on the padding and on same-rank edges, an
    exact no-op under IEEE addition for the nonnegative finish times).
    """
    wave = [0] * n
    last = [-1] * n_ranks
    for t in range(n):
        w = 0
        for d, _ in dep_info[t]:
            wd = wave[d] + 1
            if wd > w:
                w = wd
        r = owner[t]
        p = last[r]
        if p >= 0 and wave[p] + 1 > w:
            w = wave[p] + 1
        wave[t] = w
        last[r] = t
    groups: list[list[int]] = [[] for _ in range(max(wave) + 1)] if n else []
    for t in range(n):
        groups[wave[t]].append(t)
    waves = []
    for g in groups:
        k = len(g)
        dmax = max(len(dep_info[t]) for t in g)
        if dmax:
            dep_idx = np.full((k, dmax), n, dtype=np.int64)
            comm = np.zeros((k, dmax, 1))
            for i, t in enumerate(g):
                for j, (d, cm) in enumerate(dep_info[t]):
                    dep_idx[i, j] = d
                    comm[i, j, 0] = cm
        else:
            dep_idx = comm = None
        waves.append((np.asarray(g, dtype=np.int64), g,
                      np.asarray([owner[t] for t in g], dtype=np.int64),
                      dep_idx, comm))
    return waves


def _fleet_lane_pass(n: int, n_ranks: int, owner, dep_info, code,
                     pw_act, pw_idle, sw_tab, tsw, halt_win, hide, idle,
                     overhead, ovh_any, seg_gear, seg_dt, valid, max_slots,
                     start2d, fin2d, rank_free, rank_gear, core_e, sw_e,
                     sw_cnt, waves=None) -> np.ndarray:
    """One vectorized wave-order sweep over all lanes, mutating the state
    buffers in place and returning the (B,) makespan.

    The single hot loop shared by `simulate_fleet` (which allocates fresh
    buffers per call) and `core/optimize.py`'s candidate evaluator (which
    zeroes and reuses preallocated buffers across search rounds, passes
    `(n_ranks, 1)`-shaped machine columns that broadcast over the lane
    axis, and supplies its precomputed `waves`). `fin2d` must carry one
    extra all-zero pad row (shape `(n + 1, B)`) that dependency gathers
    aim padding at. Every expression here is the engine's
    exactness-critical core -- see the module docstring for the
    bit-identical timeline contract it upholds and `_wave_structure` for
    why the wave order computes the tid-order fixed point exactly.

    Active-segment energy (power at the planned gear x planned duration)
    depends only on the plan, never on the realized timeline, and padded
    slots carry dt == 0.0 -- so it is summed in ONE vectorized block
    before the wave loop. Like the per-wave `.sum(axis=0)` reductions,
    that is a pure summation reorder relative to accumulating it in tid
    order: timelines are untouched and the energy totals stay well
    inside the engine's documented 1e-9 relative contract.
    """
    if n:
        own = np.asarray(owner)
        core_e += np.einsum("snl,snl->l", pw_act[code[own][None], seg_gear],
                            seg_dt)
    if waves is None:
        waves = _wave_structure(n, n_ranks, owner, dep_info)
    maximum, where = np.maximum, np.where
    for tids, tlist, ranks, dep_idx, comm in waves:
        free = rank_free[ranks]                                # (k, L)
        ready = (free if dep_idx is None
                 else maximum(free, (fin2d[dep_idx] + comm).max(axis=1)))
        code_w = code[ranks]                                   # (k, W)
        gear_now = rank_gear[ranks]                            # (k, L)
        # serial engines resolve each task's first gear BEFORE the wait
        # downshift: a no-segment lane targets the pre-wait gear, so a
        # downshifted rank switches back (with a stall) to run it
        gear_pre = gear_now
        wait = ready - free

        # ---- waiting period handling (idle gear + switches) -------------
        waiting = wait > 1e-15
        if waiting.any():
            idle_w = idle[ranks]
            down = waiting & (idle_w != gear_now) & (wait >= halt_win[ranks])
            g_wait = where(down, idle_w, gear_now)
            sw_e += sw_tab[code_w, gear_now, g_wait].sum(axis=0)  # diag 0.0
            sw_cnt += down.sum(axis=0)
            core_e += where(waiting, pw_idle[code_w, g_wait] * wait,
                            0.0).sum(axis=0)
            gear_now = g_wait

        # ---- gear switch into each task's first segment -----------------
        ms_w = max(max_slots[t] for t in tlist)
        first = (where(valid[0, tids], seg_gear[0, tids], gear_pre)
                 if ms_w else gear_pre)
        shifted = first != gear_now
        if shifted.any():
            sw_e += sw_tab[code_w, gear_now, first].sum(axis=0)
            sw_cnt += shifted.sum(axis=0)
            stall = where(shifted & ~(hide & (wait >= tsw[ranks])),
                          tsw[ranks], 0.0)
            core_e += (pw_idle[code_w, first] * stall).sum(axis=0)
            t_exec = ready + stall
        else:
            t_exec = ready
        gear_now = first

        # ---- runtime overhead (detection / monitoring) ------------------
        if any(ovh_any[t] for t in tlist):
            ovh = overhead[tids]
            core_e += (pw_act[code_w, gear_now] * ovh).sum(axis=0)
            t_exec = t_exec + ovh
        start2d[tids] = t_exec

        # ---- execute the frequency segments -----------------------------
        # slot 0 never switches (gear_now == first already); later slots
        # replicate the serial engines' planned mid-task switches. Tasks
        # shorter than the wave's deepest slot ride along on dt == 0.0
        # padding. The active energy itself was summed before the loop.
        for s in range(ms_w):
            if s:
                gs = where(valid[s, tids], seg_gear[s, tids], gear_now)
                sw_e += sw_tab[code_w, gear_now, gs].sum(axis=0)
                sw_cnt += (gs != gear_now).sum(axis=0)
                gear_now = gs
            t_exec = t_exec + seg_dt[s, tids]
        fin2d[tids] = t_exec
        rank_free[ranks] = t_exec
        rank_gear[ranks] = gear_now

    # ---- trailing idle until global makespan (ranks finishing early) ----
    makespan = fin2d[:n].max(axis=0) if n else np.zeros(fin2d.shape[1])
    gap = rank_free < makespan - 1e-15
    if gap.any():
        g_tail = where(gap & (idle != rank_gear), idle, rank_gear)
        sw_e += sw_tab[code, rank_gear, g_tail].sum(axis=0)
        sw_cnt += (g_tail != rank_gear).sum(axis=0)
        core_e += where(gap, pw_idle[code, g_tail]
                        * (makespan - rank_free), 0.0).sum(axis=0)
    return makespan


def _empty_fleet(graph: TaskGraph, cost: CostModel,
                 cores_per_node: int) -> FleetSchedule:
    """The zero-lane fleet (B == 0): all arrays empty along the lane axis."""
    n = len(graph.tasks)
    zb = np.zeros(0)
    return FleetSchedule(graph, [], cost, [], np.zeros((0, n)),
                         np.zeros((0, n)), np.zeros(0, np.int64), zb,
                         zb.copy(), zb.copy(), cores_per_node)


def simulate_fleet(graph: TaskGraph,
                   machines: (ProcessorModel | MachineModel
                              | Sequence[ProcessorModel | MachineModel]),
                   cost: CostModel, plans: Sequence[StrategyPlan],
                   cores_per_node: int = 16) -> FleetSchedule:
    """Simulate B `(machine, plan)` lanes of one graph in a single pass.

    One vectorized NumPy sweep over tasks in tid order; every lane's
    timeline is bit-identical to what `simulate`/`simulate_reference`
    produce for that lane alone, and energies agree to 1e-9 relative (see
    the module docstring for why, and for the three-engine differential
    obligation this engine is held to).

    Parameters
    ----------
    graph : TaskGraph
        The shared task DAG. Task ids must be topologically sorted (every
        dependency's tid below its consumer's), which every `build_dag`
        graph and the differential suite's random DAGs satisfy; a
        `ValueError` is raised otherwise.
    machines : ProcessorModel, MachineModel, or sequence thereof
        Power/gear model per lane. A single (machine) model is broadcast
        to all lanes; a sequence supplies one per lane and may mix
        heterogeneous `MachineModel`s freely.
    cost : CostModel
        Supplies the cross-rank communication time (shared by all lanes).
    plans : sequence of StrategyPlan
        One frequency plan per lane; B = len(plans). May be empty.
    cores_per_node : int, optional
        Ranks per node for the nodal constant-power charge (default 16).

    Returns
    -------
    FleetSchedule
        Per-lane start/finish arrays, switch counts/energies, core
        energies, and nodal constant power -- everything `total_energy_j`
        and `makespan` need, without per-lane `Schedule` objects.
    """
    plans = list(plans)
    b = len(plans)
    if isinstance(machines, (ProcessorModel, MachineModel)):
        lane_machines = [as_machine(machines)] * b
    else:
        lane_machines = [as_machine(m) for m in machines]
        if len(lane_machines) != b:
            raise ValueError(
                f"{len(lane_machines)} machines for {b} plans; pass one "
                "machine per lane or a single model to broadcast")
    if b == 0:
        return _empty_fleet(graph, cost, cores_per_node)

    n = len(graph.tasks)
    n_ranks = graph.n_ranks
    src, dst, _ = graph.dep_edge_arrays()
    if src.size and not (src < dst).all():
        raise ValueError("simulate_fleet requires topologically sorted "
                         "task ids (dep tids below consumer tids)")

    # -- migration mappings: one wave structure per distinct task->rank map.
    # The common case (no plan overrides its owners) stays a single pass;
    # mixed-mapping batches are partitioned by mapping, each group runs one
    # pass, and the lane rows are stitched back in the original order.
    keys = [None if (o := _effective_owners(graph, p)) is None else tuple(o)
            for p in plans]
    if len(set(keys)) > 1:
        groups: dict[object, list[int]] = {}
        for i, k in enumerate(keys):
            groups.setdefault(k, []).append(i)
        start2 = np.zeros((b, n))
        finish2 = np.zeros((b, n))
        sw_cnt2 = np.zeros(b, dtype=np.int64)
        sw_e2 = np.zeros(b)
        core_e2 = np.zeros(b)
        nodal2 = np.zeros(b)
        comm_e2 = np.zeros(b)
        for lanes in groups.values():
            sub = simulate_fleet(graph, [lane_machines[i] for i in lanes],
                                 cost, [plans[i] for i in lanes],
                                 cores_per_node)
            idx = np.asarray(lanes, dtype=np.int64)
            start2[idx] = sub.start
            finish2[idx] = sub.finish
            sw_cnt2[idx] = sub.switch_count
            sw_e2[idx] = sub.switch_energy_j
            core_e2[idx] = sub.core_energy_j
            nodal2[idx] = sub.nodal_const_w
            if sub.comm_energy_j is not None:
                comm_e2[idx] = sub.comm_energy_j
        return FleetSchedule(graph, lane_machines, cost, plans, start2,
                             finish2, sw_cnt2, sw_e2, core_e2, nodal2,
                             cores_per_node,
                             None if cost.link.is_trivial else comm_e2)
    owners_ovr = None if keys[0] is None else list(keys[0])
    comm_val = cost.comm_cost(graph)

    # -- compact processor codes + padded power/switch lookup tables ------
    proc_code: dict[int, int] = {}
    procs: list[ProcessorModel] = []
    code = np.empty((n_ranks, b), dtype=np.int64)
    for l, m in enumerate(lane_machines):
        for r, p in enumerate(m.rank_procs(n_ranks)):
            c = proc_code.get(id(p))
            if c is None:
                c = proc_code[id(p)] = len(procs)
                procs.append(p)
            code[r, l] = c
    pw_act, pw_idle, sw_tab, t_sw_tab = _proc_tables(procs)

    # -- per-(rank, lane) DVFS mechanics ----------------------------------
    tsw = t_sw_tab[code]                                   # (n_ranks, B)
    mhw = np.fromiter((p.min_halt_window_s for p in plans), np.float64, b)
    halt_win = np.maximum(mhw[None, :], 2.0 * tsw)         # (n_ranks, B)
    hide = np.fromiter((p.hide_switch_in_wait for p in plans), bool, b)
    idle = np.empty((n_ranks, b), dtype=np.int64)
    for l, plan in enumerate(plans):
        for r in range(n_ranks):
            idle[r, l] = plan.idle_gear_for(r).index

    # -- per-(slot, task, lane) plan arrays -------------------------------
    overhead = (np.stack([np.asarray(p.per_task_overhead, np.float64)
                          for p in plans], axis=1)
                if n else np.zeros((0, b)))                # (n, B)
    ovh_any = (overhead > 0.0).any(axis=1).tolist()
    counts2d, seg_gear, seg_dt = _segment_slots(plans, n)
    valid = counts2d[None, :, :] > np.arange(
        seg_gear.shape[0])[:, None, None]                  # (P, n, B)
    max_slots = counts2d.max(axis=1).tolist() if n else []

    tasks = graph.tasks
    owner = [t.owner for t in tasks] if owners_ovr is None else owners_ovr
    if isinstance(comm_val, np.ndarray):
        dep_info = [[(d, float(comm_val[owner[d], owner[t.tid]]))
                     for d in t.deps] for t in tasks]
    else:
        dep_info = [[(d, comm_val if owner[d] != owner[t.tid] else 0.0)
                     for d in t.deps] for t in tasks]

    # -- lane state + accumulators ----------------------------------------
    # fin2d's extra row is the all-zero pad target for dependency gathers
    start2d = np.zeros((n, b))
    fin2d = np.zeros((n + 1, b))
    rank_free = np.zeros((n_ranks, b))
    rank_gear = np.zeros((n_ranks, b), dtype=np.int64)     # 0 = top gear
    core_e = np.zeros(b)
    sw_e = np.zeros(b)
    sw_cnt = np.zeros(b, dtype=np.int64)

    _fleet_lane_pass(n, n_ranks, owner, dep_info, code, pw_act, pw_idle,
                     sw_tab, tsw, halt_win, hide, idle, overhead, ovh_any,
                     seg_gear, seg_dt, valid, max_slots, start2d, fin2d,
                     rank_free, rank_gear, core_e, sw_e, sw_cnt)

    nodal = np.array([machine_nodal_const_power_w(m, n_ranks, cores_per_node)
                      for m in lane_machines])
    if cost.link.is_trivial:
        comm_e = None         # legacy zero-comm-energy path, bit-identical
    else:
        comm_e = np.full(b, plan_comm_energy_j(graph, cost, owners_ovr))
    return FleetSchedule(graph, lane_machines, cost, plans,
                         np.ascontiguousarray(start2d.T),
                         np.ascontiguousarray(fin2d[:n].T),
                         sw_cnt, sw_e, core_e, nodal, cores_per_node,
                         comm_e)
