"""The paper's technique applied to LM training/serving steps.

The factorization paper's thesis is that a *statically known* execution DAG
lets the DVFS/energy plan be derived offline, with zero runtime detection
cost. An XLA-compiled training step has exactly that property: the HLO
schedule is fixed at compile time, so per-step busy intervals of each
hardware lane (MXU compute, HBM DMA, ICI collectives) are known before the
first step runs. This module transposes the paper's analysis:

    CPU core            ->  chip "lane" (mxu / hbm / ici)
    task slack          ->  lane slack = step_time - lane_busy_time
                            (the dry-run's three roofline terms ARE the
                            per-lane busy times; the dominant lane has
                            zero slack -- it is the critical path)
    race-to-halt        ->  lane idles at idle-power outside its busy time
    CP-aware reclaim    ->  lane stretched to run at f = busy/step of peak
    algorithmic (paper) ->  the same stretch plan, but computed offline
                            from the compiled step (no detection overhead,
                            pre-armed transitions) -- possible *because*
                            the XLA schedule is static, exactly the
                            paper's argument for factorization DAGs
    tx (TDS-driven)     ->  at step granularity the lane profile IS the
                            Task Dependency Set (critical lane: zero
                            slack; other lanes: barrier-bound imbalance
                            slack), so TX coincides with the offline
                            stretch plan here

Two device power models are evaluated (DESIGN.md S3.2):
  * `tpu_like`  -- no DVFS ladder: stretching is impossible; only
    race-to-halt (clock/power-gating idle lanes) exists. This is how real
    TPUs behave.
  * `dvfs_ladder` -- a hypothetical accelerator exposing the paper-era CPU
    gear ladders (scaled): lets us reproduce the paper's E(S2)-E(S1)
    comparison on an LM step and show the gap narrowing as V(f) flattens.
"""

from __future__ import annotations

import dataclasses
import math

from .energy_model import GEAR_TABLES

# Per-chip lane power split (TPU-v5e-class estimates; peak_w sums with
# p_const to ~250 W active, idles to ~65 W -- consistent with the
# make_tpu_like() nodal model in energy_model.py).
LANES = ("mxu", "hbm", "ici")


@dataclasses.dataclass(frozen=True)
class LanePower:
    peak_w: float
    idle_w: float


DEFAULT_LANES: dict[str, LanePower] = {
    "mxu": LanePower(peak_w=120.0, idle_w=12.0),
    "hbm": LanePower(peak_w=55.0, idle_w=22.0),   # refresh floor
    "ici": LanePower(peak_w=20.0, idle_w=4.0),
}
P_CONST_W = 55.0          # board, host link, fans -- unaffected by scaling


@dataclasses.dataclass(frozen=True)
class StepProfile:
    """Per-lane busy seconds of one compiled step (= roofline terms)."""
    arch: str
    shape: str
    mxu_s: float
    hbm_s: float
    ici_s: float
    overlap: float = 1.0   # 1.0 = lanes fully overlap (XLA async);
                           # 0.0 = fully serialized phases

    @property
    def lane_busy(self) -> dict[str, float]:
        """Busy seconds per lane (mxu / hbm / ici)."""
        return {"mxu": self.mxu_s, "hbm": self.hbm_s, "ici": self.ici_s}

    @property
    def step_s(self) -> float:
        """Step wall time under the profile's overlap assumption."""
        busy = self.lane_busy
        lo = max(busy.values())                   # perfect overlap
        hi = sum(busy.values())                   # fully serial
        return hi + (lo - hi) * self.overlap

    @property
    def critical_lane(self) -> str:
        """The zero-slack lane bounding the step (its critical path)."""
        return max(self.lane_busy, key=lambda k: self.lane_busy[k])

    def slack(self) -> dict[str, float]:
        """Per-lane idle seconds: step time minus the lane's busy time."""
        t = self.step_s
        return {k: t - v for k, v in self.lane_busy.items()}


def profile_from_dryrun(rec: dict, overlap: float = 1.0) -> StepProfile:
    """Build a StepProfile from one dryrun.json record."""
    return StepProfile(arch=rec["arch"], shape=rec["shape"],
                       mxu_s=rec["compute_s"], hbm_s=rec["memory_s"],
                       ici_s=rec["collective_s"], overlap=overlap)


# ------------------------------------------------------------ gear physics

def _norm_gear_ladder(table_name: str) -> list[tuple[float, float]]:
    """(f/f_max, V/V_max) ladder from a published CPU gear table."""
    gears = GEAR_TABLES[table_name]
    f0, v0 = gears[0]
    return [(f / f0, v / v0) for f, v in gears]


def voltage_at(freq_ratio: float, ladder: list[tuple[float, float]]) -> float:
    """V/V_max at f/f_max, interpolating adjacent published gears."""
    r = min(max(freq_ratio, ladder[-1][0]), 1.0)
    for (fh, vh), (fl, vl) in zip(ladder[:-1], ladder[1:]):
        if fl <= r <= fh:
            w = 0.0 if fh == fl else (r - fl) / (fh - fl)
            return vl + w * (vh - vl)
    return ladder[0][1]


def dynamic_power_ratio(freq_ratio: float,
                        ladder: list[tuple[float, float]] | None) -> float:
    """P_dyn(f)/P_dyn(f_max) = (f/f_max) * (V/V_max)^2.

    ladder=None models a voltage-flat device (modern CMOS limit / TPU):
    dynamic power is linear in f, so stretching a task saves *nothing*
    over race-to-halt on dynamic energy -- the paper's core observation.
    """
    if ladder is None:
        return freq_ratio
    return freq_ratio * voltage_at(freq_ratio, ladder) ** 2


# ------------------------------------------------------------- strategies
#
# Lane strategies mirror core/strategies.py's registry at step granularity:
# a lane strategy consumes (profile, lanes, ladder, step seconds) and emits
# per-lane energies. Register new policies with @register_lane_strategy; any
# registered name works in step_energy/evaluate_step and the lm_energy
# benchmark picks it up automatically.

@dataclasses.dataclass
class LaneEnergy:
    strategy: str
    step_s: float
    energy_j: float
    per_lane_j: dict[str, float]
    avg_power_w: float
    saved_vs_original_pct: float


# Runtime overhead fractions (same roles as core/strategies.py)
CP_DETECT_OVERHEAD = 0.005     # online profiling/plan computation per step
MONITOR_OVERHEAD = 0.001       # completion monitoring (race-to-halt)

# name -> (per-step overhead fraction, per-lane energy fn)
_LANE_REGISTRY: dict[str, tuple[float, object]] = {}


def register_lane_strategy(name: str, overhead: float = 0.0):
    """Register fn(profile, lanes, ladder, step_s) -> {lane: joules}."""
    def deco(fn):
        _LANE_REGISTRY[name] = (overhead, fn)
        return fn
    return deco


def registered_lane_strategies() -> tuple[str, ...]:
    """All registered lane-strategy names, in registration order."""
    return tuple(_LANE_REGISTRY)


@register_lane_strategy("original")
def _lane_original(profile, lanes, ladder, step):
    return {k: lp.peak_w * step for k, lp in lanes.items()}


@register_lane_strategy("race_to_halt", overhead=MONITOR_OVERHEAD)
def _lane_race_to_halt(profile, lanes, ladder, step):
    busy = profile.lane_busy
    return {
        k: lanes[k].peak_w * busy[k] + lanes[k].idle_w * (step - busy[k])
        for k in lanes
    }


def _lane_stretch(profile, lanes, ladder, step):
    """Stretch every non-critical lane into its slack (two-phase at floor)."""
    busy = profile.lane_busy
    per_lane = {}
    for k, lp in lanes.items():
        if busy[k] <= 0.0:
            per_lane[k] = lp.idle_w * step
            continue
        r = min(busy[k] / step, 1.0)           # stretch into all slack
        # floor: ladders bottom out (f_min/f_max); below it, run at the
        # floor gear then halt for the remainder (two-phase plan)
        r_floor = ladder[-1][0] if ladder else 0.10
        r_eff = max(r, r_floor)
        run_s = busy[k] / r_eff                # time at the low gear
        dyn_peak = lp.peak_w - lp.idle_w
        p_run = lp.idle_w + dyn_peak * dynamic_power_ratio(r_eff, ladder)
        per_lane[k] = p_run * run_s + lp.idle_w * max(step - run_s, 0.0)
    return per_lane


register_lane_strategy("cp_aware", overhead=CP_DETECT_OVERHEAD)(_lane_stretch)
register_lane_strategy("algorithmic")(_lane_stretch)
# TX at step granularity: the compiled step's lane profile IS the TDS -- the
# critical lane has zero slack, every other lane's slack is bounded by the
# step barrier (pure load imbalance, no panel class at this granularity),
# so the TDS-driven plan collapses to the offline stretch with pre-armed
# transitions and zero detection overhead.
register_lane_strategy("tx")(_lane_stretch)


def step_energy(profile: StepProfile,
                strategy: str,
                lanes: dict[str, LanePower] | None = None,
                ladder_name: str | None = None) -> LaneEnergy:
    """Energy of one step under a registered lane strategy.

    ladder_name: None -> voltage-flat device (tpu_like); else a
    GEAR_TABLES key -> hypothetical DVFS accelerator with that V(f) curve.
    """
    lanes = lanes or DEFAULT_LANES
    ladder = None if ladder_name is None else _norm_gear_ladder(ladder_name)
    try:
        overhead, fn = _LANE_REGISTRY[strategy]
    except KeyError:
        raise ValueError(f"unknown lane strategy {strategy!r}; choose from "
                         f"{registered_lane_strategies()}") from None
    step = profile.step_s * (1.0 + overhead)
    per_lane = fn(profile, lanes, ladder, step)
    e = sum(per_lane.values()) + P_CONST_W * step
    return LaneEnergy(strategy, step, e, per_lane, e / step, 0.0)


# The four strategies the paper evaluates; registered_lane_strategies()
# additionally includes `tx` and anything downstream code registers.
STRATEGIES = ("original", "race_to_halt", "cp_aware", "algorithmic")


def evaluate_step(profile: StepProfile,
                  device: str = "tpu_like") -> dict[str, LaneEnergy]:
    """Every registered lane strategy on one step profile.

    device: "tpu_like" (no ladder) or a GEAR_TABLES key. Savings are
    always vs `original`, whatever the registration order.
    """
    ladder_name = None if device == "tpu_like" else device
    ref = step_energy(profile, "original", ladder_name=ladder_name)
    out: dict[str, LaneEnergy] = {}
    for s in registered_lane_strategies():
        r = ref if s == "original" else \
            step_energy(profile, s, ladder_name=ladder_name)
        r.saved_vs_original_pct = 100.0 * (1.0 - r.energy_j / ref.energy_j)
        out[s] = r
    return out


def strategy_gap_pct(profile: StepProfile, device: str = "tpu_like") -> float:
    """(E_race_to_halt - E_algorithmic) / E_original * 100 -- the residual
    advantage of slack reclamation over halting. The paper predicts this
    shrinks toward ~0 as V(f) flattens; on a voltage-flat device it is
    <= 0 (race-to-halt wins outright once overheads are counted)."""
    r = evaluate_step(profile, device)
    return (r["race_to_halt"].energy_j - r["algorithmic"].energy_j) \
        / r["original"].energy_j * 100.0


# -------------------------------------------------- per-step phase timeline

def phase_timeline(profile: StepProfile, n_phases: int,
                   strategy: str = "race_to_halt",
                   lanes: dict[str, LanePower] | None = None,
                   samples_per_phase: int = 8):
    """Fig-2-style power trace of one step under a strategy.

    The step is split into n_phases equal compute phases (layer groups)
    with the lane busy times spread uniformly; between phases the
    non-critical lanes idle/stretch per the strategy. Returns
    (times, watts) arrays for plotting/CSV.
    """
    import numpy as np

    lanes = lanes or DEFAULT_LANES
    t = profile.step_s
    busy = profile.lane_busy
    res = step_energy(profile, strategy)
    times = np.linspace(0.0, res.step_s, n_phases * samples_per_phase)
    watts = np.full_like(times, P_CONST_W)
    for k, lp in lanes.items():
        duty = min(busy[k] / t, 1.0)
        if strategy == "original":
            watts += lp.peak_w
            continue
        # each phase: lane active for `duty` of the phase, then idles
        phase_pos = (times / res.step_s * n_phases) % 1.0
        active = phase_pos < duty
        if strategy == "race_to_halt":
            watts += np.where(active, lp.peak_w, lp.idle_w)
        else:  # stretched: constant reduced power all phase
            e = res.per_lane_j[k]
            watts += e / res.step_s
    return times, watts
