"""Core: algorithmic energy saving for parallel Cholesky/LU/QR (the paper).

Public API:
    build_dag, TaskGraph                    -- factorization task graphs
    cp_analysis, schedule_slack             -- critical path + slack
    make_processor, GEAR_TABLES             -- CMOS power model + gears
    two_gear_split                          -- Ishihara-Yasuura frequency split
    make_plan, evaluate_strategies          -- the four strategies
    simulate, CostModel, Schedule           -- schedule simulator (fast,
                                               event-driven engine)
    simulate_reference                      -- slow pick-loop oracle for
                                               differential testing
"""

from .critical_path import CpResult, cp_analysis, schedule_slack
from .dag import (DAG_BUILDERS, TaskGraph, Task, block_cyclic_owner,
                  build_cholesky_dag, build_dag, build_lu_dag, build_qr_dag,
                  factorization_flops)
from .dvfs import duration_at, plan_energy_j, two_gear_split
from .energy_model import (GEAR_TABLES, Gear, ProcessorModel, make_processor,
                           make_tpu_like, max_slack_ratio, strategy_gap_terms,
                           verify_worked_example)
from .scheduler import (CostModel, RankSegment, Schedule, StrategyPlan,
                        simulate, simulate_reference)
from .strategies import (STRATEGIES, StrategyConfig, StrategyResult,
                         evaluate_strategies, make_plan)

__all__ = [
    "CpResult", "cp_analysis", "schedule_slack",
    "DAG_BUILDERS", "TaskGraph", "Task", "block_cyclic_owner",
    "build_cholesky_dag", "build_dag", "build_lu_dag", "build_qr_dag",
    "factorization_flops",
    "duration_at", "plan_energy_j", "two_gear_split",
    "GEAR_TABLES", "Gear", "ProcessorModel", "make_processor",
    "make_tpu_like", "max_slack_ratio", "strategy_gap_terms",
    "verify_worked_example",
    "CostModel", "RankSegment", "Schedule", "StrategyPlan", "simulate",
    "simulate_reference",
    "STRATEGIES", "StrategyConfig", "StrategyResult",
    "evaluate_strategies", "make_plan",
]
