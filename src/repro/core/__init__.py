"""Core: algorithmic energy saving for parallel Cholesky/LU/QR (the paper).

Public API:
    build_dag, TaskGraph                    -- factorization task graphs
    cp_analysis, schedule_slack             -- critical path + slack
    analyze_tds, compute_tds, TdsResult     -- Task Dependency Set analysis
                                               (per-task wait/slack classes)
    make_processor, GEAR_TABLES             -- CMOS power model + gears
    MachineModel, as_machine                -- per-rank processor assignment
    make_big_little, make_tpu_mixed         -- canned asymmetric machines
    scale_processor                         -- derated/overclocked siblings
    LinkModel, comm_low_power_w             -- per-rank-pair link bandwidth /
                                               transfer energy (trivial default
                                               reproduces uniform free comm)
    plan_comm_energy_j                      -- wire energy of a mapping
    migration_mappings, TxMigrateStrategy   -- task migration off LITTLE
                                               ranks (the tx_migrate strategy)
    two_gear_split, two_gear_split_batch    -- Ishihara-Yasuura frequency split
    register_strategy, Strategy             -- pluggable strategy registry
    PlanContext, registered_strategies      -- shared planning inputs + listing
    make_plan, evaluate_strategies          -- plan/evaluate registered strategies
    simulate, CostModel, Schedule           -- schedule simulator (fast,
                                               event-driven engine)
    simulate_reference                      -- slow pick-loop oracle for
                                               differential testing
    simulate_fleet, FleetSchedule           -- batched engine: B plan lanes
                                               in one vectorized pass
    replan_tx, ReplanOutcome, WaveRecord    -- closed-loop re-planning
                                               (the tx_replan strategy)
    residual_schedule_times, residual_schedule_slack,
    analyze_residual_tds                    -- residual-graph analyses
    search_plan, CandidateEvaluator         -- batched plan search (the
                                               plan_search strategy)
    make_trace, build_serving_graph         -- LM serving traffic compiler
    serving_machine, serving_cost_model     -- serving cluster + cost model
    request_latencies, p99_latency_s,
    slo_violation_rate                      -- per-request SLO accounting
    load_roofline, RooflineTable            -- committed measured-roofline
                                               artifact (results/roofline.json)
    beta_from_terms, roofline_cost_model    -- measured per-kind frequency
                                               sensitivity (docs/ROOFLINE.md)
    profiles_from_roofline, profile_for_arch -- roofline-derived serving
                                               profiles

See README.md for the user-facing tour and docs/ARCHITECTURE.md for the
layer map, the three-engine differential-testing policy, and the
heterogeneous-machine design.
"""

from .critical_path import (CpResult, cp_analysis, residual_schedule_slack,
                            residual_schedule_times, schedule_slack,
                            validate_frozen_closure)
from .dag import (DAG_BUILDERS, PANEL_KINDS, TaskGraph, Task,
                  block_cyclic_owner, build_cholesky_dag, build_dag,
                  build_lu_dag, build_qr_dag, factorization_flops)
from .dvfs import (duration_at, plan_energy_j, two_gear_split,
                   two_gear_split_batch, two_gear_split_batch_by_table)
from .energy_model import (GEAR_TABLES, Gear, LinkModel, MachineModel,
                           ProcessorModel, as_machine, comm_low_power_w,
                           make_big_little, make_processor,
                           make_tpu_like, make_tpu_mixed, max_slack_ratio,
                           scale_processor, strategy_gap_terms,
                           verify_worked_example)
from .fleet import FleetSchedule, simulate_fleet
from .scheduler import (CostModel, RankSegment, Schedule, StrategyPlan,
                        machine_nodal_const_power_w, plan_comm_energy_j,
                        simulate, simulate_reference)
from .strategies import (STRATEGIES, PlanContext, ResidualPlanContext,
                         Strategy, StrategyConfig, StrategyResult,
                         TxMigrateStrategy, evaluate_strategies, get_strategy,
                         make_plan, migration_mappings, migration_plans,
                         register_strategy, registered_strategies)
from .roofline_model import (BETA_FLOOR, RooflineTable, beta_from_terms,
                             load_roofline, roofline_cost_model)
from .serving import (DECODE_FLOPS_ANCHORS, FAMILY_ARCHS, MODEL_PROFILES,
                      TRAFFIC_SHAPES, ServingGraph, ServingModelProfile,
                      ServingTrace, build_serving_graph, make_clock_proc,
                      make_server_proc, make_trace, p99_latency_s,
                      profile_for_arch, profiles_from_roofline,
                      request_latencies, serving_cost_model, serving_machine,
                      slo_violation_rate, traffic_rate_curve)
from .tds import (GEAR_CLASS_NAMES, GEAR_CLASS_PANEL, GEAR_CLASS_SOLVE,
                  GEAR_CLASS_UPDATE, SOLVE_KINDS, WAIT_CLASS_NAMES,
                  WAIT_COMM, WAIT_IMBALANCE, WAIT_NONE, WAIT_PANEL,
                  TdsResult, analyze_residual_tds, analyze_tds, compute_tds,
                  task_gear_classes)
# imported last: these register tx_replan and plan_search (both depend on
# .strategies' registry; optimize additionally seeds its search from every
# previously registered strategy)
from .replan import (ReplanOutcome, TxReplanStrategy, WaveRecord,
                     iteration_waves, replan_tx)
from .optimize import CandidateEvaluator, PlanSearchStrategy, search_plan

__all__ = [
    "CpResult", "cp_analysis", "schedule_slack",
    "residual_schedule_slack", "residual_schedule_times",
    "validate_frozen_closure",
    "ReplanOutcome", "TxReplanStrategy", "WaveRecord", "iteration_waves",
    "replan_tx", "ResidualPlanContext", "analyze_residual_tds",
    "CandidateEvaluator", "PlanSearchStrategy", "search_plan",
    "DAG_BUILDERS", "PANEL_KINDS", "TaskGraph", "Task", "block_cyclic_owner",
    "build_cholesky_dag", "build_dag", "build_lu_dag", "build_qr_dag",
    "factorization_flops",
    "duration_at", "plan_energy_j", "two_gear_split", "two_gear_split_batch",
    "two_gear_split_batch_by_table",
    "GEAR_TABLES", "Gear", "LinkModel", "MachineModel", "ProcessorModel",
    "as_machine", "comm_low_power_w",
    "make_big_little", "make_processor", "make_tpu_like", "make_tpu_mixed",
    "max_slack_ratio", "scale_processor", "strategy_gap_terms",
    "verify_worked_example",
    "CostModel", "FleetSchedule", "RankSegment", "Schedule", "StrategyPlan",
    "machine_nodal_const_power_w", "plan_comm_energy_j", "simulate",
    "simulate_fleet", "simulate_reference",
    "STRATEGIES", "PlanContext", "Strategy", "StrategyConfig",
    "StrategyResult", "TxMigrateStrategy", "evaluate_strategies",
    "get_strategy", "make_plan", "migration_mappings", "migration_plans",
    "register_strategy", "registered_strategies",
    "BETA_FLOOR", "RooflineTable", "beta_from_terms", "load_roofline",
    "roofline_cost_model",
    "DECODE_FLOPS_ANCHORS", "FAMILY_ARCHS", "MODEL_PROFILES",
    "TRAFFIC_SHAPES", "ServingGraph",
    "ServingModelProfile", "ServingTrace", "build_serving_graph",
    "make_clock_proc", "make_server_proc", "make_trace", "p99_latency_s",
    "profile_for_arch", "profiles_from_roofline", "request_latencies",
    "serving_cost_model", "serving_machine", "slo_violation_rate",
    "traffic_rate_curve",
    "GEAR_CLASS_NAMES", "GEAR_CLASS_PANEL", "GEAR_CLASS_SOLVE",
    "GEAR_CLASS_UPDATE", "SOLVE_KINDS",
    "WAIT_CLASS_NAMES", "WAIT_COMM", "WAIT_IMBALANCE", "WAIT_NONE",
    "WAIT_PANEL", "TdsResult", "analyze_tds", "compute_tds",
    "task_gear_classes",
]
