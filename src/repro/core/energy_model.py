"""CMOS power/energy model with published DVFS gear tables.

Model (the one the paper's group uses across its 2014 papers):

    P_node = n_cores * (A * C * f * V^2 * act + I_sub * V) + P_const

        A      -- fraction of gates switching (activity); lower when idle
        C      -- total capacitive load of the chip (effective, per core here)
        f, V   -- operating point from the processor's DVFS gear table
        I_sub  -- subthreshold leakage current (treated constant, see
                  Taur et al. 2004: converges past a threshold voltage)
        P_const-- non-CPU nodal power (RAM, NIC, board, fans) -- unaffected
                  by CPU DVFS.

Energy of a schedule = sum over timeline segments of P(gear, state) * dt.

Gear tables are published operating points (companion paper, Table 2) plus
the ARC cluster's Opteron 6128 gear set used in the paper's own experiments
(voltages for the 6128 are not published; values below are estimated from
the 2380's V/f slope and flagged as such).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

# --------------------------------------------------------------------------
# Gear tables: list of (frequency GHz, voltage V), highest gear first.
# --------------------------------------------------------------------------

GEAR_TABLES: dict[str, tuple[tuple[float, float], ...]] = {
    # AMD Opteron 2380 (gears 0..3)
    "amd_opteron_2380": ((2.5, 1.300), (1.8, 1.200), (1.3, 1.100), (0.8, 1.025)),
    # AMD Opteron 846 / Athlon64 3200+
    "amd_opteron_846": ((2.0, 1.500), (1.8, 1.400), (1.6, 1.300), (0.8, 0.900)),
    # AMD Opteron 2218 -- the worked EXAMPLE processor in the companion text
    "amd_opteron_2218": ((2.4, 1.250), (2.2, 1.200), (1.8, 1.150), (1.0, 1.100)),
    # Intel Pentium M
    "intel_pentium_m": ((1.4, 1.484), (1.2, 1.436), (1.0, 1.308), (0.8, 1.180)),
    # Intel Pentium 4 HT 530 (only two published points)
    "intel_pentium4_ht530": ((3.0, 1.430), (2.1, 1.250)),
    # Intel Xeon E5-2687W (only two published points)
    "intel_xeon_e5_2687w": ((3.1, 1.200), (1.2, 0.840)),
    # Intel Core i7-2760QM
    "intel_core_i7_2760qm": ((2.4, 1.060), (2.0, 0.970), (1.6, 0.890), (0.8, 0.760)),
    # ARC cluster: 2x 8-core AMD Opteron 6128 per node; freq set published in
    # the paper ({0.8,1.0,1.2,1.5,2.0} GHz), voltages ESTIMATED (see module doc).
    "arc_opteron_6128": (
        (2.0, 1.3000),
        (1.5, 1.2000),
        (1.2, 1.1625),
        (1.0, 1.1250),
        (0.8, 1.0875),
    ),
}


@dataclasses.dataclass(frozen=True)
class Gear:
    index: int
    freq_ghz: float
    voltage: float


def bracketing_gears_in(gears: Sequence[Gear],
                        freq_ghz: float) -> tuple[Gear, Gear]:
    """Adjacent gears of a descending table with g_lo.f <= freq <= g_hi.f.

    Clamps to the table's ends. Shared by `ProcessorModel.bracketing_gears`
    (full ladder) and the dvfs split functions (asymmetric subtables), so
    the first-match rule cannot diverge between the two paths.
    """
    if freq_ghz >= gears[0].freq_ghz:
        return gears[0], gears[0]
    if freq_ghz <= gears[-1].freq_ghz:
        return gears[-1], gears[-1]
    for hi, lo in zip(gears[:-1], gears[1:]):
        if lo.freq_ghz <= freq_ghz <= hi.freq_ghz:
            return hi, lo
    return gears[0], gears[-1]


@dataclasses.dataclass(frozen=True)
class ProcessorModel:
    """Per-node power model with a discrete DVFS gear table."""

    name: str
    gears: tuple[Gear, ...]               # highest frequency first
    n_cores: int = 16                     # ARC: 2 sockets x 8 cores
    # Calibrated so that a 3-node ARC group reproduces the paper's trace
    # levels (~950 W peak / ~850 W mid / ~700 W comm-low for 3 nodes).
    eff_cap_nf: float = 2.87              # A*C lumped, nF per core (active)
    idle_activity: float = 0.30           # A_idle / A_active
    i_sub_amps: float = 0.50              # subthreshold leakage per core
    p_const_watts: float = 150.0          # non-CPU nodal power (P_c)
    # DVFS transition cost: the core stalls for switch_latency_s and burns
    # the *higher* gear's active power during the switch.
    switch_latency_s: float = 100e-6

    # -- gear helpers ------------------------------------------------------
    @property
    def f_max(self) -> float:
        return self.gears[0].freq_ghz

    @property
    def f_min(self) -> float:
        return self.gears[-1].freq_ghz

    def gear_for_freq(self, freq_ghz: float) -> Gear:
        """Lowest-power gear with frequency >= freq_ghz (clamped)."""
        for g in reversed(self.gears):           # lowest first
            if g.freq_ghz >= freq_ghz - 1e-12:
                return g
        return self.gears[0]

    def gear_subtable(self, indices: Sequence[int]) -> tuple[Gear, ...]:
        """An asymmetric (per-task-type) table: the gears at `indices`.

        Indices must be strictly increasing positions into `self.gears`
        (which is descending in frequency), so the subtable is itself a
        valid descending ladder whose Gear objects keep their original
        indices -- the simulator's power/switch lookups stay valid.
        """
        idx = tuple(indices)
        if not idx:
            raise ValueError("a gear subtable needs at least one gear")
        if any(i < 0 or i >= len(self.gears) for i in idx):
            raise ValueError(f"gear index out of range [0, {len(self.gears)})")
        if any(a >= b for a, b in zip(idx, idx[1:])):
            raise ValueError("gear indices must be strictly increasing")
        return tuple(self.gears[i] for i in idx)

    def gear_prefix(self, depth: float) -> tuple[Gear, ...]:
        """The top portion of the ladder, by fractional depth.

        depth 0.0 -> top gear only (latency-critical task types stay on the
        'big' operating points); depth 1.0 -> the full table. Intermediate
        depths round to the nearest ladder position.
        """
        if not 0.0 <= depth <= 1.0:
            raise ValueError(f"depth must be in [0, 1], got {depth}")
        k = 1 + int(round(depth * (len(self.gears) - 1)))
        return self.gears[:k]

    def bracketing_gears(self, freq_ghz: float) -> tuple[Gear, Gear]:
        """Adjacent gears (g_hi, g_lo) with g_lo.f <= freq <= g_hi.f."""
        return bracketing_gears_in(self.gears, freq_ghz)

    # -- power -------------------------------------------------------------
    def core_dynamic_w(self, gear: Gear, active: bool) -> float:
        act = 1.0 if active else self.idle_activity
        # eff_cap in nF * f in GHz -> nF*1e-9 * GHz*1e9 = F*Hz; watts = C f V^2
        return self.eff_cap_nf * gear.freq_ghz * gear.voltage**2 * act

    def core_power_w(self, gear: Gear, active: bool) -> float:
        """Per-core power: dynamic + subthreshold leakage (no nodal const)."""
        return self.core_dynamic_w(gear, active) + self.i_sub_amps * gear.voltage

    def node_power_w(self, gear: Gear, active: bool) -> float:
        return self.n_cores * self.core_power_w(gear, active) + self.p_const_watts

    def switch_energy_j(self, from_gear: Gear, to_gear: Gear) -> float:
        """Per-core energy of one DVFS transition (core stalls at the higher
        gear's active power for switch_latency_s)."""
        if from_gear.index == to_gear.index:
            return 0.0
        hi = from_gear if from_gear.freq_ghz >= to_gear.freq_ghz else to_gear
        return self.core_power_w(hi, active=True) * self.switch_latency_s


def make_processor(name: str, **overrides) -> ProcessorModel:
    table = GEAR_TABLES[name]
    gears = tuple(Gear(i, f, v) for i, (f, v) in enumerate(table))
    return ProcessorModel(name=name, gears=gears, **overrides)


# A "TPU-like" device: no software DVFS ladder -- only active vs idle power
# states (race-to-halt is the only hardware-supported strategy). Used by the
# hardware-adaptation experiments (DESIGN.md S3.2).
def make_tpu_like(name: str = "tpu_v5e_like") -> ProcessorModel:
    # Model a v5e-ish chip: ~200 W active, ~60 W idle, one "gear".
    gears = (Gear(0, 0.94, 0.75),)  # nominal core clock / core voltage
    return ProcessorModel(
        name=name,
        gears=gears,
        n_cores=1,
        eff_cap_nf=265.0,    # calibrated: ~200 W active
        idle_activity=0.20,  # ~88 W idle incl. HBM refresh
        i_sub_amps=8.0,
        p_const_watts=52.0,
        switch_latency_s=10e-6,
    )


# --------------------------------------------------------------------------
# Analytical strategy-gap terms (Eqns 7-9 of the companion analysis).
# These power the `strategy_gap` benchmark: Delta E_d and Delta E_l between
# CP-aware slack reclamation (S2) and race-to-halt (S1), per unit A*C*T and
# I_sub*T respectively.
# --------------------------------------------------------------------------

def strategy_gap_terms(proc: ProcessorModel, n: float) -> tuple[float, float]:
    """Return (dEd_coeff, dEl_coeff) for slack ratio n (T' = (n-1) T).

    E(S2) - E(S1) = dEd_coeff * (A C T) + dEl_coeff * (I_sub T).
    Negative => CP-aware (S2) saves more energy than race-to-halt (S1).
    """
    if n < 1.0:
        raise ValueError(f"n must be >= 1, got {n}")
    f_h, v_h = proc.gears[0].freq_ghz, proc.gears[0].voltage
    f_l, v_l = proc.gears[-1].freq_ghz, proc.gears[-1].voltage
    f_m = f_h / n
    # voltage at f_m: the gear actually used to realize f_m (paper assumes
    # f_m available; with a discrete table we take the bracketing-high gear's
    # voltage, the conservative choice).
    g_hi, g_lo = proc.bracketing_gears(f_m)
    if g_hi.index == g_lo.index:
        v_m = g_hi.voltage
    else:  # linear interpolation between adjacent gears
        w = (f_m - g_lo.freq_ghz) / (g_hi.freq_ghz - g_lo.freq_ghz)
        v_m = g_lo.voltage + w * (g_hi.voltage - g_lo.voltage)
    d_ed = f_h * (v_m**2 - v_h**2) - (n - 1.0) * f_l * v_l**2
    d_el = n * v_m - v_h - (n - 1.0) * v_l
    return d_ed, d_el


def max_slack_ratio(proc: ProcessorModel) -> float:
    """Upper bound on n: f_h / f_l."""
    return proc.f_max / proc.f_min


def verify_worked_example() -> dict[str, float]:
    """The companion text's worked example (AMD Opteron 2218, n = 1.25).

    Expected: dEd = -0.8785 * ACT, dEl = -0.0875 * I_sub T.
    (The text picks 1.8 GHz as the realized gear for f_m = 1.92 GHz, i.e. it
    rounds DOWN to the published gear; we replicate that convention here for
    the check only.)
    """
    proc = make_processor("amd_opteron_2218")
    n = 1.25
    f_h, v_h = 2.4, 1.25
    f_l, v_l = 1.0, 1.10
    v_m = 1.15  # gear at 1.8 GHz per the text's example
    d_ed = f_h * (v_m**2 - v_h**2) - (n - 1.0) * f_l * v_l**2
    d_el = n * v_m - v_h - (n - 1.0) * v_l
    assert math.isclose(d_ed, -0.8785, abs_tol=1e-4), d_ed
    assert math.isclose(d_el, -0.0875, abs_tol=1e-4), d_el
    return {"dEd": d_ed, "dEl": d_el}
