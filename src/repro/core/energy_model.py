"""CMOS power/energy model with published DVFS gear tables.

Model (the one the paper's group uses across its 2014 papers):

    P_node = n_cores * (A * C * f * V^2 * act + I_sub * V) + P_const

        A      -- fraction of gates switching (activity); lower when idle
        C      -- total capacitive load of the chip (effective, per core here)
        f, V   -- operating point from the processor's DVFS gear table
        I_sub  -- subthreshold leakage current (treated constant, see
                  Taur et al. 2004: converges past a threshold voltage)
        P_const-- non-CPU nodal power (RAM, NIC, board, fans) -- unaffected
                  by CPU DVFS.

Energy of a schedule = sum over timeline segments of P(gear, state) * dt.

Gear tables are published operating points (companion paper, Table 2) plus
the ARC cluster's Opteron 6128 gear set used in the paper's own experiments
(voltages for the 6128 are not published; values below are estimated from
the 2380's V/f slope and flagged as such).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Sequence

import numpy as np

# --------------------------------------------------------------------------
# Gear tables: list of (frequency GHz, voltage V), highest gear first.
# --------------------------------------------------------------------------

GEAR_TABLES: dict[str, tuple[tuple[float, float], ...]] = {
    # AMD Opteron 2380 (gears 0..3)
    "amd_opteron_2380": ((2.5, 1.300), (1.8, 1.200), (1.3, 1.100), (0.8, 1.025)),
    # AMD Opteron 846 / Athlon64 3200+
    "amd_opteron_846": ((2.0, 1.500), (1.8, 1.400), (1.6, 1.300), (0.8, 0.900)),
    # AMD Opteron 2218 -- the worked EXAMPLE processor in the companion text
    "amd_opteron_2218": ((2.4, 1.250), (2.2, 1.200), (1.8, 1.150), (1.0, 1.100)),
    # Intel Pentium M
    "intel_pentium_m": ((1.4, 1.484), (1.2, 1.436), (1.0, 1.308), (0.8, 1.180)),
    # Intel Pentium 4 HT 530 (only two published points)
    "intel_pentium4_ht530": ((3.0, 1.430), (2.1, 1.250)),
    # Intel Xeon E5-2687W (only two published points)
    "intel_xeon_e5_2687w": ((3.1, 1.200), (1.2, 0.840)),
    # Intel Core i7-2760QM
    "intel_core_i7_2760qm": ((2.4, 1.060), (2.0, 0.970), (1.6, 0.890), (0.8, 0.760)),
    # ARC cluster: 2x 8-core AMD Opteron 6128 per node; freq set published in
    # the paper ({0.8,1.0,1.2,1.5,2.0} GHz), voltages ESTIMATED (see module doc).
    "arc_opteron_6128": (
        (2.0, 1.3000),
        (1.5, 1.2000),
        (1.2, 1.1625),
        (1.0, 1.1250),
        (0.8, 1.0875),
    ),
}


@dataclasses.dataclass(frozen=True)
class Gear:
    index: int
    freq_ghz: float
    voltage: float


def bracketing_gears_in(gears: Sequence[Gear],
                        freq_ghz: float) -> tuple[Gear, Gear]:
    """Adjacent gears of a descending table with g_lo.f <= freq <= g_hi.f.

    Clamps to the table's ends. Shared by `ProcessorModel.bracketing_gears`
    (full ladder) and the dvfs split functions (asymmetric subtables), so
    the first-match rule cannot diverge between the two paths.
    """
    if freq_ghz >= gears[0].freq_ghz:
        return gears[0], gears[0]
    if freq_ghz <= gears[-1].freq_ghz:
        return gears[-1], gears[-1]
    for hi, lo in zip(gears[:-1], gears[1:]):
        if lo.freq_ghz <= freq_ghz <= hi.freq_ghz:
            return hi, lo
    return gears[0], gears[-1]


@dataclasses.dataclass(frozen=True)
class ProcessorModel:
    """Per-node power model with a discrete DVFS gear table."""

    name: str
    gears: tuple[Gear, ...]               # highest frequency first
    n_cores: int = 16                     # ARC: 2 sockets x 8 cores
    # Calibrated so that a 3-node ARC group reproduces the paper's trace
    # levels (~950 W peak / ~850 W mid for 3 nodes; the comm-low level is
    # derived, not hardcoded -- see `comm_low_power_w` and the LinkModel
    # annotation path in benchmarks/power_trace.py).
    eff_cap_nf: float = 2.87              # A*C lumped, nF per core (active)
    idle_activity: float = 0.30           # A_idle / A_active
    i_sub_amps: float = 0.50              # subthreshold leakage per core
    p_const_watts: float = 150.0          # non-CPU nodal power (P_c)
    # DVFS transition cost: the core stalls for switch_latency_s and burns
    # the *higher* gear's active power during the switch.
    switch_latency_s: float = 100e-6

    # -- gear helpers ------------------------------------------------------
    @property
    def f_max(self) -> float:
        """Top-gear frequency in GHz (the reference for durations)."""
        return self.gears[0].freq_ghz

    @property
    def f_min(self) -> float:
        """Lowest-gear frequency in GHz (the halt gear)."""
        return self.gears[-1].freq_ghz

    def gear_for_freq(self, freq_ghz: float) -> Gear:
        """Lowest-power gear with frequency >= freq_ghz (clamped)."""
        for g in reversed(self.gears):           # lowest first
            if g.freq_ghz >= freq_ghz - 1e-12:
                return g
        return self.gears[0]

    def gear_subtable(self, indices: Sequence[int]) -> tuple[Gear, ...]:
        """An asymmetric (per-task-type) table: the gears at `indices`.

        Indices must be strictly increasing positions into `self.gears`
        (which is descending in frequency), so the subtable is itself a
        valid descending ladder whose Gear objects keep their original
        indices -- the simulator's power/switch lookups stay valid.
        """
        idx = tuple(indices)
        if not idx:
            raise ValueError("a gear subtable needs at least one gear")
        if any(i < 0 or i >= len(self.gears) for i in idx):
            raise ValueError(f"gear index out of range [0, {len(self.gears)})")
        if any(a >= b for a, b in zip(idx, idx[1:])):
            raise ValueError("gear indices must be strictly increasing")
        return tuple(self.gears[i] for i in idx)

    def gear_prefix(self, depth: float) -> tuple[Gear, ...]:
        """The top portion of the ladder, by fractional depth.

        depth 0.0 -> top gear only (latency-critical task types stay on the
        'big' operating points); depth 1.0 -> the full table. Intermediate
        depths round to the nearest ladder position.
        """
        if not 0.0 <= depth <= 1.0:
            raise ValueError(f"depth must be in [0, 1], got {depth}")
        k = 1 + int(round(depth * (len(self.gears) - 1)))
        return self.gears[:k]

    def bracketing_gears(self, freq_ghz: float) -> tuple[Gear, Gear]:
        """Adjacent gears (g_hi, g_lo) with g_lo.f <= freq <= g_hi.f."""
        return bracketing_gears_in(self.gears, freq_ghz)

    # -- power -------------------------------------------------------------
    def core_dynamic_w(self, gear: Gear, active: bool) -> float:
        """Per-core dynamic (switching) power A*C*f*V^2 at this gear."""
        act = 1.0 if active else self.idle_activity
        # eff_cap in nF * f in GHz -> nF*1e-9 * GHz*1e9 = F*Hz; watts = C f V^2
        return self.eff_cap_nf * gear.freq_ghz * gear.voltage**2 * act

    def core_power_w(self, gear: Gear, active: bool) -> float:
        """Per-core power: dynamic + subthreshold leakage (no nodal const)."""
        return self.core_dynamic_w(gear, active) + self.i_sub_amps * gear.voltage

    def node_power_w(self, gear: Gear, active: bool) -> float:
        """Whole-node power: all cores at this gear plus the nodal const."""
        return self.n_cores * self.core_power_w(gear, active) + self.p_const_watts

    def switch_energy_j(self, from_gear: Gear, to_gear: Gear) -> float:
        """Per-core energy of one DVFS transition (core stalls at the higher
        gear's active power for switch_latency_s)."""
        if from_gear.index == to_gear.index:
            return 0.0
        hi = from_gear if from_gear.freq_ghz >= to_gear.freq_ghz else to_gear
        return self.core_power_w(hi, active=True) * self.switch_latency_s


def make_processor(name: str, **overrides) -> ProcessorModel:
    """Build a ProcessorModel from a published gear table (`GEAR_TABLES`).

    Keyword overrides replace any `ProcessorModel` field (e.g.
    `switch_latency_s=50e-6`).
    """
    table = GEAR_TABLES[name]
    gears = tuple(Gear(i, f, v) for i, (f, v) in enumerate(table))
    return ProcessorModel(name=name, gears=gears, **overrides)


# A "TPU-like" device: no software DVFS ladder -- only active vs idle power
# states (race-to-halt is the only hardware-supported strategy). Used by the
# hardware-adaptation experiments (DESIGN.md S3.2).
def make_tpu_like(name: str = "tpu_v5e_like") -> ProcessorModel:
    """A single-gear accelerator model: only active vs idle power states."""
    # Model a v5e-ish chip: ~200 W active, ~60 W idle, one "gear".
    gears = (Gear(0, 0.94, 0.75),)  # nominal core clock / core voltage
    return ProcessorModel(
        name=name,
        gears=gears,
        n_cores=1,
        eff_cap_nf=265.0,    # calibrated: ~200 W active
        idle_activity=0.20,  # ~88 W idle incl. HBM refresh
        i_sub_amps=8.0,
        p_const_watts=52.0,
        switch_latency_s=10e-6,
    )


# --------------------------------------------------------------------------
# Machine models: per-rank processor assignment (asymmetric clusters).
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MachineModel:
    """A cluster as a per-rank assignment of ProcessorModels.

    `procs` is a repeating pattern over ranks: rank r runs
    `procs[r % len(procs)]` -- a single-entry pattern is a homogeneous
    machine, `(big, little)` interleaves two core types, and
    `(big,) * 4 + (little,) * 12` carves a 16-core node into clusters
    (Costero et al.'s big.LITTLE framing). The pattern form keeps a
    machine independent of any particular task graph's rank count.

    Every API that accepts a `ProcessorModel` (both simulator engines,
    `PlanContext`, `CostModel.durations_top`, `evaluate_strategies`)
    also accepts a `MachineModel`; `as_machine` normalizes between the
    two. `MachineModel.homogeneous(proc)` wraps a single processor and
    is a provable no-op: every per-rank lookup returns the *same object*
    the bare-processor path would use, so schedules, energies, and gear
    switches are bit-identical (pinned by tests/test_heterogeneous.py
    against tests/data/strategy_golden.json).
    """

    name: str
    procs: tuple[ProcessorModel, ...]

    def __post_init__(self):
        if not self.procs:
            raise ValueError("a MachineModel needs at least one processor")

    @classmethod
    def homogeneous(cls, proc: ProcessorModel) -> "MachineModel":
        """Every rank runs `proc` -- equivalent to passing `proc` directly."""
        return cls(name=proc.name, procs=(proc,))

    @functools.cached_property
    def is_homogeneous(self) -> bool:
        """True when every rank resolves to one (equal) processor model."""
        p0 = self.procs[0]
        return all(p is p0 or p == p0 for p in self.procs[1:])

    def proc_for_rank(self, rank: int) -> ProcessorModel:
        """The processor rank `rank` runs (the pattern repeats over ranks)."""
        return self.procs[rank % len(self.procs)]

    def rank_procs(self, n_ranks: int) -> list[ProcessorModel]:
        """The concrete per-rank processor list for an n_ranks-rank job."""
        return [self.procs[r % len(self.procs)] for r in range(n_ranks)]

    def distinct_procs(self, n_ranks: int) -> list[ProcessorModel]:
        """Distinct processors among the first n_ranks ranks (by identity,
        first-appearance order) -- the grouping unit for batched planning."""
        seen: dict[int, ProcessorModel] = {}
        for p in self.rank_procs(n_ranks):
            seen.setdefault(id(p), p)
        return list(seen.values())


def as_machine(proc: "ProcessorModel | MachineModel") -> MachineModel:
    """Normalize a bare processor to its homogeneous machine wrapper."""
    if isinstance(proc, MachineModel):
        return proc
    return MachineModel.homogeneous(proc)


# --------------------------------------------------------------------------
# Link models: per-rank-pair communication bandwidth and transfer energy.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Per-rank-pair communication links: transfer time and wire energy.

    The default-constructed `LinkModel()` is *trivial*: no bandwidth or
    latency override and zero transfer energy. A trivial link makes
    `CostModel.comm_cost` return the legacy scalar `comm_time` and every
    comm-energy term exactly `0.0`, so schedules and energies are
    bit-identical to the pre-link implementation -- the same no-op proof
    shape as `MachineModel.homogeneous` (pinned by
    tests/test_plan_feasibility.py against tests/data/migrate_golden.json).

    Non-trivial links describe rank pairs through repeating pattern
    tables, mirroring `MachineModel.procs`: the link from rank i to rank
    j uses pattern entry `[i % P][j % P]` where P is the table's side, so
    one table serves any rank count. Uniform overrides
    (`bandwidth_gbs` / `latency_s` / `energy_per_byte_j`) apply when the
    corresponding pair table is absent. Intra-rank transfers (the matrix
    diagonal) are always free, matching the engines' owner-local rule.
    """

    name: str = "uniform"
    bandwidth_gbs: float | None = None      # None -> CostModel's default
    latency_s: float | None = None          # None -> CostModel's default
    energy_per_byte_j: float = 0.0          # wire energy per transferred byte
    pair_bandwidth_gbs: tuple[tuple[float, ...], ...] | None = None
    pair_energy_per_byte_j: tuple[tuple[float, ...], ...] | None = None

    def __post_init__(self):
        for label, table in (("pair_bandwidth_gbs", self.pair_bandwidth_gbs),
                             ("pair_energy_per_byte_j",
                              self.pair_energy_per_byte_j)):
            if table is None:
                continue
            p = len(table)
            if p == 0 or any(len(row) != p for row in table):
                raise ValueError(f"{label} must be a non-empty square table")
            if label == "pair_bandwidth_gbs":
                if any(v <= 0.0 for row in table for v in row):
                    raise ValueError("pair bandwidths must be positive")
            elif any(v < 0.0 for row in table for v in row):
                raise ValueError("pair transfer energies must be >= 0")
        if self.bandwidth_gbs is not None and self.bandwidth_gbs <= 0.0:
            raise ValueError("bandwidth_gbs must be positive")
        if self.energy_per_byte_j < 0.0:
            raise ValueError("energy_per_byte_j must be >= 0")

    @property
    def is_trivial(self) -> bool:
        """True when this link is the provable zero-cost no-op default."""
        return (self.bandwidth_gbs is None and self.latency_s is None
                and self.energy_per_byte_j == 0.0
                and self.pair_bandwidth_gbs is None
                and self.pair_energy_per_byte_j is None)

    def _pattern(self, table, uniform: float, n_ranks: int) -> np.ndarray:
        """Tile a P x P pattern table (or a uniform value) to (R, R)."""
        if table is None:
            return np.full((n_ranks, n_ranks), uniform)
        pat = np.asarray(table, dtype=np.float64)
        idx = np.arange(n_ranks) % pat.shape[0]
        return pat[np.ix_(idx, idx)]

    def bandwidth_matrix(self, n_ranks: int,
                         default_bandwidth_gbs: float) -> np.ndarray:
        """(R, R) link bandwidth in GB/s; entry [i, j] is the i->j link."""
        uni = (self.bandwidth_gbs if self.bandwidth_gbs is not None
               else default_bandwidth_gbs)
        return self._pattern(self.pair_bandwidth_gbs, uni, n_ranks)

    def time_matrix(self, n_ranks: int, n_bytes: float,
                    default_bandwidth_gbs: float,
                    default_latency_s: float) -> np.ndarray:
        """(R, R) transfer time of an `n_bytes` message; zero diagonal.

        Entry [i, j] = n_bytes / bandwidth(i, j) + latency, the delay a
        cross-rank dependency edge i->j adds before its successor may
        start. The diagonal is zeroed: owner-local edges are free.
        """
        bw = self.bandwidth_matrix(n_ranks, default_bandwidth_gbs)
        lat = self.latency_s if self.latency_s is not None \
            else default_latency_s
        mat = n_bytes / (bw * 1e9) + lat
        np.fill_diagonal(mat, 0.0)
        return mat

    def energy_matrix(self, n_ranks: int, n_bytes: float) -> np.ndarray:
        """(R, R) wire energy (J) of an `n_bytes` transfer; zero diagonal."""
        e = self._pattern(self.pair_energy_per_byte_j,
                          self.energy_per_byte_j, n_ranks)
        mat = e * float(n_bytes)
        np.fill_diagonal(mat, 0.0)
        return mat

    def transfer_power_w(self, src: int, dst: int,
                         default_bandwidth_gbs: float) -> float:
        """Wire power (W) while a src->dst transfer is in flight.

        J/byte x bytes/s: the nodal power a saturated link adds on top of
        the idling cores -- the model-derived 'comm-low' annotation level
        used by benchmarks/power_trace.py (previously a hardcoded ~700 W
        calibration comment).
        """
        if src == dst:
            return 0.0
        bw = self.bandwidth_matrix(max(src, dst) + 1, default_bandwidth_gbs)
        e = self._pattern(self.pair_energy_per_byte_j,
                          self.energy_per_byte_j, max(src, dst) + 1)
        return float(e[src, dst] * bw[src, dst] * 1e9)


def comm_low_power_w(proc: ProcessorModel, n_nodes: int = 1,
                     gear: Gear | None = None,
                     link_power_w: float = 0.0) -> float:
    """Model-derived nodal power floor during communication slack.

    Every core idles at `gear` (default: the halt gear, the deepest
    operating point an energy strategy parks waiting cores at) while the
    in-flight transfers add `link_power_w` of wire power -- the quantity
    the paper's Fig. 2 annotates as the '~700 W comm-low' level for three
    ARC nodes. Deriving it from the models replaces that hardcoded
    calibration constant.
    """
    g = gear if gear is not None else proc.gears[-1]
    return n_nodes * proc.node_power_w(g, active=False) + link_power_w


def scale_processor(proc: ProcessorModel, name: str,
                    freq_scale: float = 1.0, volt_scale: float = 1.0,
                    cap_scale: float = 1.0, leak_scale: float = 1.0,
                    const_scale: float = 1.0) -> ProcessorModel:
    """A derated/overclocked sibling of `proc` with its own power curve.

    Scales the gear table's frequencies/voltages and the lumped power
    parameters; gear indices are preserved so the sibling's ladder is the
    same shape as the original's (per-rank plans may still mix the two in
    one machine).
    """
    gears = tuple(Gear(g.index, g.freq_ghz * freq_scale,
                       g.voltage * volt_scale) for g in proc.gears)
    return dataclasses.replace(
        proc, name=name, gears=gears,
        eff_cap_nf=proc.eff_cap_nf * cap_scale,
        i_sub_amps=proc.i_sub_amps * leak_scale,
        p_const_watts=proc.p_const_watts * const_scale)


def make_big_little(big: "ProcessorModel | str" = "arc_opteron_6128",
                    little: ProcessorModel | None = None,
                    n_big: int = 1, n_little: int = 1,
                    name: str | None = None) -> MachineModel:
    """Canned asymmetric cluster: `n_big` fast ranks per `n_little` slow
    ranks, repeating block-wise over the rank space.

    The default LITTLE core is a derated sibling of the big one: 60% of
    the clock at 85% of the voltage, ~45% of the switched capacitance and
    ~60% of the leakage (small-core scaling a la big.LITTLE); the nodal
    constant stays the big core's (boards are shared). Pass an explicit
    `little` ProcessorModel to model a genuinely different part.
    """
    if isinstance(big, str):
        big = make_processor(big)
    if little is None:
        little = scale_processor(big, big.name + "_little",
                                 freq_scale=0.6, volt_scale=0.85,
                                 cap_scale=0.45, leak_scale=0.6)
    if n_big < 1 or n_little < 1:
        raise ValueError("need at least one big and one LITTLE rank")
    procs = (big,) * n_big + (little,) * n_little
    return MachineModel(
        name=name or f"{big.name}+{little.name}_{n_big}b{n_little}l",
        procs=procs)


def make_tpu_mixed(n_full: int = 1, n_lite: int = 1,
                   name: str = "tpu_v5e_mixed") -> MachineModel:
    """Mixed accelerator pod: full-clock `tpu_v5e_like` chips alongside a
    derated (70% clock, ~55% dynamic power) variant -- the accelerator
    analogue of a big.LITTLE cluster, with single-gear parts on both
    sides (race-to-halt is the only per-chip policy; heterogeneity shows
    up purely through per-rank durations and power curves).
    """
    full = make_tpu_like()
    lite = scale_processor(full, "tpu_v5e_lite", freq_scale=0.7,
                           volt_scale=0.9, cap_scale=0.68, leak_scale=0.8,
                           const_scale=0.9)
    if n_full < 1 or n_lite < 1:
        raise ValueError("need at least one full and one lite chip")
    return MachineModel(name=name, procs=(full,) * n_full + (lite,) * n_lite)


# --------------------------------------------------------------------------
# Analytical strategy-gap terms (Eqns 7-9 of the companion analysis).
# These power the `strategy_gap` benchmark: Delta E_d and Delta E_l between
# CP-aware slack reclamation (S2) and race-to-halt (S1), per unit A*C*T and
# I_sub*T respectively.
# --------------------------------------------------------------------------

def strategy_gap_terms(proc: ProcessorModel, n: float) -> tuple[float, float]:
    """Return (dEd_coeff, dEl_coeff) for slack ratio n (T' = (n-1) T).

    E(S2) - E(S1) = dEd_coeff * (A C T) + dEl_coeff * (I_sub T).
    Negative => CP-aware (S2) saves more energy than race-to-halt (S1).
    """
    if n < 1.0:
        raise ValueError(f"n must be >= 1, got {n}")
    f_h, v_h = proc.gears[0].freq_ghz, proc.gears[0].voltage
    f_l, v_l = proc.gears[-1].freq_ghz, proc.gears[-1].voltage
    f_m = f_h / n
    # voltage at f_m: the gear actually used to realize f_m (paper assumes
    # f_m available; with a discrete table we take the bracketing-high gear's
    # voltage, the conservative choice).
    g_hi, g_lo = proc.bracketing_gears(f_m)
    if g_hi.index == g_lo.index:
        v_m = g_hi.voltage
    else:  # linear interpolation between adjacent gears
        w = (f_m - g_lo.freq_ghz) / (g_hi.freq_ghz - g_lo.freq_ghz)
        v_m = g_lo.voltage + w * (g_hi.voltage - g_lo.voltage)
    d_ed = f_h * (v_m**2 - v_h**2) - (n - 1.0) * f_l * v_l**2
    d_el = n * v_m - v_h - (n - 1.0) * v_l
    return d_ed, d_el


def max_slack_ratio(proc: ProcessorModel) -> float:
    """Upper bound on n: f_h / f_l."""
    return proc.f_max / proc.f_min


def verify_worked_example() -> dict[str, float]:
    """The companion text's worked example (AMD Opteron 2218, n = 1.25).

    Expected: dEd = -0.8785 * ACT, dEl = -0.0875 * I_sub T.
    (The text picks 1.8 GHz as the realized gear for f_m = 1.92 GHz, i.e. it
    rounds DOWN to the published gear; we replicate that convention here for
    the check only.)
    """
    proc = make_processor("amd_opteron_2218")
    n = 1.25
    f_h, v_h = 2.4, 1.25
    f_l, v_l = 1.0, 1.10
    v_m = 1.15  # gear at 1.8 GHz per the text's example
    d_ed = f_h * (v_m**2 - v_h**2) - (n - 1.0) * f_l * v_l**2
    d_el = n * v_m - v_h - (n - 1.0) * v_l
    assert math.isclose(d_ed, -0.8785, abs_tol=1e-4), d_ed
    assert math.isclose(d_el, -0.0875, abs_tol=1e-4), d_el
    return {"dEd": d_ed, "dEl": d_el}
