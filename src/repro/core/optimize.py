"""Search-based gear planning: `simulate_fleet` as a batched objective.

Every other registered strategy is a greedy heuristic -- it commits to a
slack model (realized local slack, TDS classes, uniform gears) and never
looks at what the engine actually does with the resulting plan. This
module closes ROADMAP open item 2 by *searching* the plan space instead:
`plan_search` runs coordinate descent with annealing-style jitter over
per-task extra-time vectors, scoring hundreds of candidate plans per
round in ONE structure-of-arrays fleet pass.

Why per-task extra time is the right search space: Rizvandi et al.
(PAPERS.md) prove the optimal frequency schedule needs at most a
two-frequency mix per task, and `two_gear_split` already maps any target
window `d + e` to that optimal mix. A candidate plan is therefore fully
described by one nonnegative vector `e` (seconds of stretch per task) --
the split, the gears, and the mid-task switch all follow deterministically,
so the search never leaves the `StrategyPlan` vocabulary and the three
engines score it without any modification (the "search layer" argument in
docs/ARCHITECTURE.md).

Hot-loop design (the ISSUE 7 tentpole):

  * the frozen `PlanContext` arrays (durations / betas / slack / baseline)
    are computed once and shared by every candidate in every round;
  * `CandidateEvaluator` pre-builds the per-rank machine columns (power
    tables, switch latencies, idle gears) ONCE and reuses preallocated
    fleet lane buffers across rounds -- a candidate batch costs one
    `dvfs.two_gear_split_arrays` broadcast per distinct processor plus one
    `fleet._fleet_lane_pass` sweep, with zero per-candidate Python segment
    lists;
  * mutations on independent DAG levels batch into the same pass: one
    round scores every (level-band x move) mutation plus the annealing
    jitter as lanes of a single evaluation.

`benchmarks/sim_speed.py` gates the resulting candidate throughput at a
hard >= 30x floor over the naive per-candidate fast-engine loop
(`scripts/bench_compare.py --search-floor`), and
`benchmarks/strategy_gap.py` uses the search result as the per-cell upper
bound behind its `oracle_gap` metrics.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .dvfs import duration_at, two_gear_split_arrays
from .fleet import (_fleet_lane_pass, _proc_tables, _wave_structure,
                    simulate_fleet)
from .scheduler import (StrategyPlan, machine_nodal_const_power_w,
                        plan_comm_energy_j)
from .strategies import (PlanContext, get_strategy, register_strategy,
                         registered_strategies)

__all__ = ["CandidateEvaluator", "search_plan", "PlanSearchStrategy"]


class CandidateEvaluator:
    """Batched scorer for per-task extra-time candidate plans.

    Evaluates B candidate vectors `e` (seconds of stretch per task, >= 0)
    against one `PlanContext`, returning each candidate's total energy and
    makespan exactly as `simulate` would report them for the corresponding
    `StrategyPlan` (segments `ctx.reclaimed_segments(e, 0.0)`, idle at
    every rank's lowest gear, switches hidden in waits, zero overhead) --
    timelines bit-identical, energies to the documented 1e-9 relative
    tolerance of the fleet engine.

    All machine-side arrays (power/switch tables, per-rank codes, idle
    gears) are built once at construction; candidate batches are split
    into chunks of at most `max_lanes` lanes and scored into preallocated
    slot/state buffers, so the per-candidate cost is pure vectorized
    NumPy: one `two_gear_split_arrays` broadcast per distinct processor
    and one `_fleet_lane_pass` sweep per chunk. No per-candidate Python
    segment lists are ever materialized.
    """

    def __init__(self, ctx: PlanContext, max_lanes: int = 192):
        """Freeze the context's machine structure into reusable buffers.

        Parameters
        ----------
        ctx : PlanContext
            Shared planning inputs; `durations`, `betas`, and the
            per-rank machine structure are read once here.
        max_lanes : int
            Chunk width: candidate batches larger than this are scored in
            consecutive passes over the same preallocated buffers.
        """
        self.ctx = ctx
        graph = ctx.graph
        n = ctx.n_tasks
        n_ranks = graph.n_ranks
        self.n_tasks = n
        self._n_ranks = n_ranks
        self.max_lanes = max_lanes = max(1, int(max_lanes))
        self._d = ctx.durations
        self._betas = ctx.betas

        # compact processor codes + padded power/switch tables, exactly as
        # simulate_fleet builds them -- but once, not per evaluation
        rank_procs = ctx.rank_procs
        proc_code: dict[int, int] = {}
        procs = []
        code = np.empty((n_ranks, 1), dtype=np.int64)
        for r, p in enumerate(rank_procs):
            c = proc_code.get(id(p))
            if c is None:
                c = proc_code[id(p)] = len(procs)
                procs.append(p)
            code[r, 0] = c
        self._code = code
        (self._pw_act, self._pw_idle, self._sw_tab,
         t_sw_tab) = _proc_tables(procs)
        self._tsw = t_sw_tab[code]                          # (n_ranks, 1)
        # candidate plans have min_halt_window_s == 0.0
        self._halt_win = 2.0 * self._tsw
        self._hide = np.ones(1, dtype=bool)
        self._idle = np.asarray([[p.gears[-1].index] for p in rank_procs],
                                dtype=np.int64)             # (n_ranks, 1)
        self._overhead = np.zeros((n, 1))
        self._ovh_any = [False] * n
        self._nodal = machine_nodal_const_power_w(ctx.machine, n_ranks)

        comm_val = ctx.cost.comm_cost(graph)
        tasks = graph.tasks
        self._owner = [t.owner for t in tasks]
        if np.ndim(comm_val):
            cm = np.asarray(comm_val)
            self._dep_info = [[(d, float(cm[tasks[d].owner, t.owner]))
                               for d in t.deps] for t in tasks]
        else:
            comm = float(comm_val)
            self._dep_info = [[(d, comm if tasks[d].owner != t.owner else 0.0)
                               for d in t.deps] for t in tasks]
        # wire energy of the (frozen) mapping: a per-lane constant, 0.0
        # under a trivial LinkModel so the legacy energies stay bit-exact
        self._comm_e = plan_comm_energy_j(graph, ctx.cost)
        # dependency/rank-chain wave grouping: graph-only, so built once
        self._waves = _wave_structure(n, n_ranks, self._owner,
                                      self._dep_info)
        # per distinct processor: the task ids it owns, its gear ladder's
        # true Gear.index values (positions in the FULL ladder; `ident`
        # flags the identity mapping so gathers can be skipped), the
        # hoisted full-task duration table for `two_gear_split_arrays`
        # (same IEEE expression, computed once instead of per batch), and
        # the cheapest row selector for the slot-buffer writes
        self._groups = []
        for p, sel in ctx.task_proc_groups:
            gear_index = np.asarray([g.index for g in p.gears],
                                    dtype=np.int64)
            ident = bool(np.array_equal(
                gear_index, np.arange(len(gear_index), dtype=np.int64)))
            freqs = np.asarray([g.freq_ghz for g in p.gears])
            d3 = self._d[sel][:, None, None]
            b3 = self._betas[sel][:, None, None]
            t_full = d3 * (b3 * p.f_max / freqs + (1.0 - b3))
            rows = (slice(None)
                    if np.array_equal(sel, np.arange(n, dtype=np.int64))
                    else sel)
            self._groups.append((p, sel, gear_index, ident, t_full, rows))

        # preallocated slot + lane-state buffers, reused across chunks and
        # rounds (two slots: a two-gear split never needs more)
        L = max_lanes
        self._counts = np.zeros((n, L), dtype=np.int64)
        self._seg_gear = np.zeros((2, n, L), dtype=np.int64)
        self._seg_dt = np.zeros((2, n, L))
        self._valid = np.zeros((2, n, L), dtype=bool)
        self._start2d = np.zeros((n, L))
        self._fin2d = np.zeros((n + 1, L))    # extra row: dep-gather pad
        self._rank_free = np.zeros((n_ranks, L))
        self._rank_gear = np.zeros((n_ranks, L), dtype=np.int64)
        self._core_e = np.zeros(L)
        self._sw_e = np.zeros(L)
        self._sw_cnt = np.zeros(L, dtype=np.int64)

    def _fill_slots(self, e_chunk: np.ndarray, m: int) -> None:
        """Scatter the two-gear splits of `e_chunk` ((m, n) extra times)
        into the first `m` lanes of the slot buffers: every duration and
        every emitted gear matches `fleet._segment_slots` of the
        equivalent plans bit for bit, and invalid slots keep the dt == 0.0
        padding the engines' folds rely on (their gear values are free --
        always valid-masked or multiplied by the zero dt -- so unemitted
        slots are left holding whatever bracketing index was computed
        rather than being zeroed with extra `where` passes)."""
        counts = self._counts[:, :m]
        g0, g1 = self._seg_gear[0, :, :m], self._seg_gear[1, :, :m]
        dt0, dt1 = self._seg_dt[0, :, :m], self._seg_dt[1, :, :m]
        where = np.where
        for proc, sel, gear_index, ident, t_full, rows in self._groups:
            a = two_gear_split_arrays(
                proc.gears, proc.f_max, self._d[sel][:, None],
                e_chunk[:, sel].T, self._betas[sel][:, None],
                t_full=t_full)
            emit_hi = a["split"] & (a["w"] > 1e-12)
            emit_lo = a["split"] & (a["w_rem"] > 1e-12)
            two = emit_hi & emit_lo
            if ident:
                hi, lo = a["hi_idx"], a["lo_idx"]
            else:
                hi, lo = gear_index[a["hi_idx"]], gear_index[a["lo_idx"]]
            single_case = a["flat"] | a["overrun"]
            # the cases are mutually disjoint, so nested where chains pick
            # exactly what an np.select over them would (but faster); a
            # split lane always emits at least one half (w + w_rem == 1),
            # so the non-emit_hi branch is simply `lo`
            counts[rows] = where(a["empty"], 0, where(two, 2, 1))
            g0[rows] = where(single_case, gear_index[0],
                             where(a["floor"], gear_index[-1],
                                   where(a["single"] | emit_hi, hi, lo)))
            dt0[rows] = where(
                a["empty"], 0.0,
                where(single_case, a["d_at_top"],
                      where(a["floor"], a["t_floor"],
                            where(a["single"], a["t_hi_full"],
                                  where(emit_hi, a["t_hi"], a["t_lo"])))))
            g1[rows] = lo
            dt1[rows] = where(two, a["t_lo"], 0.0)

    def evaluate(self, extra: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Score candidate extra-time vectors in batched fleet passes.

        Parameters
        ----------
        extra : np.ndarray
            (B, n_tasks) nonnegative seconds of stretch per task, one
            candidate plan per row.

        Returns
        -------
        tuple of np.ndarray
            `(energy_j, makespan_s)`, each of shape (B,): exactly what
            `simulate` reports for the equivalent `StrategyPlan` of each
            row (bit-identical makespan, 1e-9-relative energy).
        """
        extra = np.atleast_2d(np.asarray(extra, dtype=float))
        B = extra.shape[0]
        if extra.shape[1] != self.n_tasks:
            raise ValueError(f"candidates must have {self.n_tasks} columns")
        energy = np.empty(B)
        makespan = np.empty(B)
        n = self.n_tasks
        for at in range(0, B, self.max_lanes):
            m = min(self.max_lanes, B - at)
            self._fill_slots(extra[at:at + m], m)
            counts = self._counts[:, :m]
            valid = self._valid[:, :, :m]
            np.greater(counts[None, :, :], np.arange(2)[:, None, None],
                       out=valid)
            max_slots = counts.max(axis=1).tolist() if n else []
            rank_free = self._rank_free[:, :m]
            rank_gear = self._rank_gear[:, :m]
            core_e, sw_e = self._core_e[:m], self._sw_e[:m]
            sw_cnt = self._sw_cnt[:m]
            rank_free[:] = 0.0
            rank_gear[:] = 0
            core_e[:] = 0.0
            sw_e[:] = 0.0
            sw_cnt[:] = 0
            mk = _fleet_lane_pass(
                n, self._n_ranks, self._owner, self._dep_info,
                self._code, self._pw_act, self._pw_idle, self._sw_tab,
                self._tsw, self._halt_win, self._hide, self._idle,
                self._overhead, self._ovh_any, self._seg_gear[:, :, :m],
                self._seg_dt[:, :, :m], valid, max_slots,
                self._start2d[:, :m], self._fin2d[:, :m], rank_free,
                rank_gear, core_e, sw_e, sw_cnt, waves=self._waves)
            makespan[at:at + m] = mk
            energy[at:at + m] = core_e + sw_e + self._nodal * mk \
                + self._comm_e
        return energy, makespan


def _level_bands(levels: np.ndarray, max_bands: int) -> list[np.ndarray]:
    """Partition tasks into at most `max_bands` contiguous level bands.

    Mutations applied to different bands are (nearly) independent, so one
    search round scores every (band x move) combination as lanes of the
    same batched pass."""
    if not len(levels):
        return []
    n_levels = int(levels.max()) + 1
    bands = min(n_levels, max_bands)
    band_of = (levels * bands) // n_levels
    return [band_of == b for b in range(bands) if (band_of == b).any()]


def _uniform_depth_seeds(ctx: PlanContext) -> list[np.ndarray]:
    """Extra-time vectors reproducing every per-rank uniform-gear plan
    (the Rizvandi family `single_freq_opt` sweeps), as search seeds."""
    procs = ctx.rank_procs
    depths = {0.0}
    for p in ctx.machine.distinct_procs(ctx.graph.n_ranks):
        if len(p.gears) > 1:
            depths.update(i / (len(p.gears) - 1) for i in range(len(p.gears)))
    d, betas = ctx.durations, ctx.betas
    seeds = []
    for depth in sorted(depths):
        e = np.empty(ctx.n_tasks)
        for t, dt, b in zip(ctx.graph.tasks, d, betas):
            p = procs[t.owner]
            g = p.gears[int(round(depth * (len(p.gears) - 1)))]
            e[t.tid] = max(0.0, duration_at(float(dt), p.f_max, g.freq_ghz,
                                            float(b)) - float(dt))
        seeds.append(e)
    return seeds


def search_plan(ctx: PlanContext) -> StrategyPlan:
    """Search the two-gear plan space under the slowdown cap.

    Coordinate descent over per-task extra-time vectors with
    annealing-style jitter: each round mutates the incumbent on every
    DAG-level band (scale / shift moves) and adds seeded random
    perturbations, scoring ALL candidates in one batched
    `CandidateEvaluator` pass; improving per-band moves are additionally
    composed into one combined candidate. Seeding covers the zero vector
    (always feasible: its timeline is bit-identical to the baseline),
    scaled realized slack, every per-rank uniform-gear plan, and every
    other registered strategy's actual plan (scored via `simulate_fleet`
    with its own overheads and idle policy) -- so the search result is
    never worse than the best registered heuristic on the same context.

    Parameters
    ----------
    ctx : PlanContext
        Shared planning inputs; `plan_search_slowdown_cap`,
        `plan_search_rounds`, `plan_search_lanes`, and `plan_search_seed`
        on `ctx.cfg` control the makespan bound and the search budget.

    Returns
    -------
    StrategyPlan
        The best plan found: either the winning extra-time vector
        rendered through `ctx.reclaimed_segments`, or (renamed) the best
        heuristic plan when none of the searched vectors beat it.
    """
    cfg = ctx.cfg
    n = ctx.n_tasks
    name = PlanSearchStrategy.name
    idle, rank_idle = ctx._idle_gears(-1)

    def plan_of(e: np.ndarray) -> StrategyPlan:
        return StrategyPlan(name, ctx.reclaimed_segments(e, 0.0),
                            idle_gear=idle,
                            per_task_overhead=np.zeros(n),
                            hide_switch_in_wait=True,
                            rank_idle_gears=rank_idle)

    if n == 0:
        return plan_of(np.zeros(0))

    cap = ctx.makespan_cap(cfg.plan_search_slowdown_cap)
    ev = CandidateEvaluator(ctx, cfg.plan_search_lanes)
    d = ctx.durations

    # -- heuristic seeds: every other strategy's plan, scored as-is -------
    peers = [m for m in registered_strategies() if m not in (name, "original")]
    peer_plans = [get_strategy(m).plan(ctx) for m in peers]
    best_peer: tuple[float, StrategyPlan] | None = None
    if peer_plans:
        fleet = simulate_fleet(ctx.graph, ctx.proc, ctx.cost, peer_plans)
        p_energy, p_make = fleet.total_energy_j(), fleet.makespan
        for i, p in enumerate(peer_plans):
            if p_make[i] <= cap + 1e-12 and \
                    (best_peer is None or p_energy[i] < best_peer[0]):
                best_peer = (float(p_energy[i]), p)

    # -- e-space seeds ----------------------------------------------------
    seeds = [np.zeros(n)]
    slack = np.maximum(ctx.slack, 0.0)
    seeds.extend(slack * lam for lam in (0.25, 0.5, 0.75, 1.0))
    seeds.extend(_uniform_depth_seeds(ctx))
    for p in peer_plans:
        tot = np.fromiter((sum(t for _, t in segs)
                           for segs in p.task_segments), np.float64, n)
        seeds.append(np.maximum(tot - d, 0.0))
    E = np.asarray(seeds)
    energy, make = ev.evaluate(E)
    feas = np.flatnonzero(make <= cap + 1e-12)   # row 0 (e = 0) is always in
    best_i = feas[np.argmin(energy[feas])]
    e_cur, best_e = E[best_i].copy(), float(energy[best_i])

    # -- coordinate-descent rounds with annealing jitter ------------------
    rng = np.random.default_rng(cfg.plan_search_seed)
    bands = _level_bands(ctx.graph.task_levels(), 16)
    scales = (0.0, 0.5, 0.75, 1.25, 1.5)
    stale = 0
    for _ in range(max(0, int(cfg.plan_search_rounds))):
        cands, band_of_cand = [], []
        for bi, mask in enumerate(bands):
            for s in scales:
                c = e_cur.copy()
                c[mask] *= s
                cands.append(c)
                band_of_cand.append(bi)
            for shift in (0.25, -0.25):
                c = e_cur.copy()
                c[mask] = np.maximum(c[mask] + shift * d[mask], 0.0)
                cands.append(c)
                band_of_cand.append(bi)
        n_jit = 8
        jitter = (e_cur[None, :] * rng.uniform(0.6, 1.4, (n_jit, n))
                  + rng.uniform(0.0, 0.15, (n_jit, n)) * d[None, :])
        E = np.concatenate([np.asarray(cands), jitter]) if cands else jitter
        energy, make = ev.evaluate(E)
        ok = make <= cap + 1e-12
        # compose the best improving move of each band into one candidate
        comp = e_cur.copy()
        composed = 0
        for bi, mask in enumerate(bands):
            rows = [i for i, b in enumerate(band_of_cand) if b == bi]
            good = [i for i in rows if ok[i] and energy[i] < best_e]
            if good:
                win = min(good, key=lambda i: energy[i])
                comp[mask] = E[win][mask]
                composed += 1
        if composed >= 2:
            c_energy, c_make = ev.evaluate(comp[None, :])
            if c_make[0] <= cap + 1e-12:
                E = np.concatenate([E, comp[None, :]])
                energy = np.concatenate([energy, c_energy])
                ok = np.concatenate([ok, [True]])
        feas = np.flatnonzero(ok)
        if len(feas):
            i = feas[np.argmin(energy[feas])]
            if energy[i] < best_e * (1.0 - 1e-9):
                e_cur, best_e = E[i].copy(), float(energy[i])
                stale = 0
                continue
        stale += 1
        if stale >= 2:
            break

    # prefer the heuristic plan unless the searched vector beats it by more
    # than the cross-engine energy tolerance -- guarantees plan_search is
    # never (even by 1e-9) worse than a registered heuristic under simulate
    if best_peer is not None and best_e >= best_peer[0] * (1.0 - 1e-7):
        return dataclasses.replace(best_peer[1], name=name)
    return plan_of(e_cur)


@register_strategy
class PlanSearchStrategy:
    """Search-based planner: batched coordinate descent over two-gear plans.

    Treats the fleet engine as an objective evaluator -- hundreds of
    candidate per-task extra-time vectors per round, scored in one
    structure-of-arrays pass by `CandidateEvaluator` -- and keeps the best
    plan whose makespan stays within `plan_search_slowdown_cap` of the
    baseline. Seeded with every other registered strategy's plan, so its
    savings are a per-context upper bound over the whole registry: the
    `oracle_gap` metrics in `benchmarks/strategy_gap.py` report each
    heuristic's savings as a fraction of this strategy's.
    """

    name = "plan_search"

    def plan(self, ctx: PlanContext) -> StrategyPlan:
        """Run `search_plan` on the shared context."""
        return search_plan(ctx)
