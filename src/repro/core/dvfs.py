"""DVFS gear selection: slack -> frequency plan.

Given a task with duration `d` at the top gear and usable slack `s`, the
energy-optimal single frequency is f_m = f_h * d / (d + s) (eliminate the
slack exactly). Real processors expose a discrete gear table, so f_m is
realized with the two-adjacent-gear split of Ishihara & Yasuura (1998):
run part of the task at the bracketing higher gear and the rest at the
bracketing lower gear such that the task finishes exactly at d + s.

Frequency sensitivity: a task's runtime does not always scale 1/f (memory-
bound phases don't). We model d(f) = d_h * (beta * f_h / f + (1 - beta))
with beta = 1 for compute-bound kernels (the paper's assumption) and
beta < 1 available for memory-bound kinds.
"""

from __future__ import annotations

from .energy_model import Gear, ProcessorModel

Segment = tuple[Gear, float]      # (gear, seconds)


def duration_at(d_top: float, f_top: float, f: float, beta: float = 1.0) -> float:
    """Task duration at frequency f, given duration d_top at f_top."""
    if f <= 0:
        raise ValueError("frequency must be positive")
    return d_top * (beta * f_top / f + (1.0 - beta))


def two_gear_split(proc: ProcessorModel, d_top: float, slack: float,
                   beta: float = 1.0) -> list[Segment]:
    """Frequency plan filling [0, d_top + slack] with the least energy.

    Returns a list of (gear, seconds) segments whose total *work* equals the
    task and whose total time is <= d_top + slack (equality when the slack
    is reclaimable within the gear table's range).
    """
    top = proc.gears[0]
    if d_top <= 0.0:
        return []
    if slack <= 1e-15:
        return [(top, d_top)]
    target = d_top + slack
    # time the task would take entirely at the lowest gear
    t_floor = duration_at(d_top, top.freq_ghz, proc.f_min, beta)
    if t_floor <= target + 1e-15:
        # even the lowest gear cannot absorb all the slack: run at f_min,
        # residual slack stays idle (the caller halts during it).
        return [(proc.gears[-1], t_floor)]
    # effective continuous frequency that lands exactly on target
    # beta*f_h/f + (1-beta) = target/d_top  =>  f = beta*f_h / (target/d - (1-beta))
    denom = target / d_top - (1.0 - beta)
    f_m = beta * top.freq_ghz / denom
    g_hi, g_lo = proc.bracketing_gears(f_m)
    if g_hi.index == g_lo.index:
        return [(g_hi, duration_at(d_top, top.freq_ghz, g_hi.freq_ghz, beta))]
    # split work fraction w at g_hi, (1-w) at g_lo so total time == target
    t_hi_full = duration_at(d_top, top.freq_ghz, g_hi.freq_ghz, beta)
    t_lo_full = duration_at(d_top, top.freq_ghz, g_lo.freq_ghz, beta)
    w = (target - t_lo_full) / (t_hi_full - t_lo_full)
    w = min(max(w, 0.0), 1.0)
    segs: list[Segment] = []
    if w > 1e-12:
        segs.append((g_hi, w * t_hi_full))
    if 1.0 - w > 1e-12:
        segs.append((g_lo, (1.0 - w) * t_lo_full))
    return segs


def plan_energy_j(proc: ProcessorModel, segs: list[Segment]) -> float:
    """Active-core energy of a frequency plan (excludes nodal constant)."""
    return sum(proc.core_power_w(g, active=True) * t for g, t in segs)
