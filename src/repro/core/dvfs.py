"""DVFS gear selection: slack -> frequency plan.

Given a task with duration `d` at the top gear and usable slack `s`, the
energy-optimal single frequency is f_m = f_h * d / (d + s) (eliminate the
slack exactly). Real processors expose a discrete gear table, so f_m is
realized with the two-adjacent-gear split of Ishihara & Yasuura (1998):
run part of the task at the bracketing higher gear and the rest at the
bracketing lower gear such that the task finishes exactly at d + s.

Frequency sensitivity: a task's runtime does not always scale 1/f (memory-
bound phases don't). We model d(f) = d_h * (beta * f_h / f + (1 - beta))
with beta = 1 for compute-bound kernels (the paper's assumption) and
beta < 1 available for memory-bound kinds.

Asymmetric gear tables (Costero et al.): every split function accepts an
optional `gears` subsequence of the processor's ladder -- the gears a task
of a given type is *allowed* to use. Durations stay referenced to the full
processor's top gear (`proc.f_max`); a restricted table whose fastest gear
is slower than f_max therefore overruns the task's nominal window, which is
exactly the big.LITTLE semantics: a task pinned to the LITTLE cluster runs
slow regardless of slack. `two_gear_split_batch_by_table` dispatches a
whole graph through per-task-type tables in one pass per table.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from .energy_model import Gear, ProcessorModel, bracketing_gears_in

Segment = tuple[Gear, float]      # (gear, seconds)


def duration_at(d_top: float, f_top: float, f: float, beta: float = 1.0) -> float:
    """Task duration at frequency f, given duration d_top at f_top."""
    if f <= 0:
        raise ValueError("frequency must be positive")
    return d_top * (beta * f_top / f + (1.0 - beta))


def two_gear_split(proc: ProcessorModel, d_top: float, slack: float,
                   beta: float = 1.0,
                   gears: tuple[Gear, ...] | None = None) -> list[Segment]:
    """Frequency plan filling [0, d_top + slack] with the least energy.

    Returns a list of (gear, seconds) segments whose total *work* equals the
    task and whose total time is <= d_top + slack (equality when the slack
    is reclaimable within the gear table's range).

    `gears` restricts the plan to a subsequence of the processor's ladder
    (asymmetric per-task-type tables); `d_top` is always referenced to the
    full processor's top gear. A restricted table whose fastest gear is
    below `proc.f_max` overruns `d_top + slack` when the slack is smaller
    than the forced slowdown -- the caller opted that task type into the
    slow cluster.

    Parameters
    ----------
    proc : ProcessorModel
        Supplies the gear ladder and the reference frequency `f_max`.
    d_top : float
        Task duration at the processor's top gear.
    slack : float
        Reclaimable window beyond `d_top` the plan may fill.
    beta : float
        Frequency sensitivity: d(f) = d_top * (beta * f_max/f + 1 - beta).
    gears : tuple of Gear, optional
        Restrict the split to this descending subtable of the ladder.

    Returns
    -------
    list of (Gear, float)
        Frequency segments whose total work equals the task's.
    """
    if gears is None:
        gears = proc.gears
    top = gears[0]
    f_ref = proc.f_max            # the frequency d_top is measured at
    if d_top <= 0.0:
        return []
    d_at_top = d_top if top.freq_ghz == f_ref else \
        duration_at(d_top, f_ref, top.freq_ghz, beta)
    if slack <= 1e-15:
        return [(top, d_at_top)]
    target = d_top + slack
    if target <= d_at_top + 1e-15:
        # the restricted table's fastest gear already fills (or overruns)
        # the window: nothing to split
        return [(top, d_at_top)]
    # time the task would take entirely at the table's lowest gear
    t_floor = duration_at(d_top, f_ref, gears[-1].freq_ghz, beta)
    if t_floor <= target + 1e-15:
        # even the lowest gear cannot absorb all the slack: run at the
        # floor, residual slack stays idle (the caller halts during it).
        return [(gears[-1], t_floor)]
    # effective continuous frequency that lands exactly on target
    # beta*f_h/f + (1-beta) = target/d_top  =>  f = beta*f_h / (target/d - (1-beta))
    denom = target / d_top - (1.0 - beta)
    f_m = beta * f_ref / denom
    g_hi, g_lo = bracketing_gears_in(gears, f_m)
    if g_hi.index == g_lo.index:
        return [(g_hi, duration_at(d_top, f_ref, g_hi.freq_ghz, beta))]
    # split work fraction w at g_hi, (1-w) at g_lo so total time == target
    t_hi_full = duration_at(d_top, f_ref, g_hi.freq_ghz, beta)
    t_lo_full = duration_at(d_top, f_ref, g_lo.freq_ghz, beta)
    w = (target - t_lo_full) / (t_hi_full - t_lo_full)
    w = min(max(w, 0.0), 1.0)
    segs: list[Segment] = []
    if w > 1e-12:
        segs.append((g_hi, w * t_hi_full))
    if 1.0 - w > 1e-12:
        segs.append((g_lo, (1.0 - w) * t_lo_full))
    return segs


def two_gear_split_arrays(gears: tuple[Gear, ...], f_ref: float,
                          d_top: np.ndarray, slack: np.ndarray,
                          beta: np.ndarray | float = 1.0,
                          t_full: np.ndarray | None = None) -> dict:
    """Elementwise `two_gear_split` decisions as broadcast NumPy arrays.

    The array core shared by `two_gear_split_batch` (which assembles the
    per-task segment lists) and the batched plan optimizer in
    `core/optimize.py` (which scatters the same decisions straight into
    preallocated fleet slot buffers without materializing any Python
    lists). Every arithmetic expression mirrors the scalar function
    elementwise, so downstream consumers agree with it bit for bit.
    Inputs broadcast against each other, so a 2-D (candidates x tasks)
    slack matrix against a 1-D duration vector sweeps many candidate
    plans in one call.

    Parameters
    ----------
    gears : tuple of Gear
        Descending gear ladder (or subtable) the split may use.
    f_ref : float
        Reference frequency the durations are measured at (`proc.f_max`).
    d_top, slack : np.ndarray
        Top-gear durations and reclaimable windows; broadcast together.
    beta : np.ndarray or float
        Frequency sensitivity, broadcast with the durations.
    t_full : np.ndarray, optional
        Precomputed full-task durations per gear, shape `d_top.shape +
        (len(gears),)` with `t_full[..., i] = d * (beta * f_ref /
        gears[i].freq_ghz + (1 - beta))` -- i.e. exactly the elementwise
        expression this function would evaluate, hoisted out by a caller
        that sweeps many slack columns against fixed durations (the plan
        optimizer builds it once per processor group). When given, the
        hi/lo full-task durations become table gathers instead of
        recomputations; the gathered floats are bit-identical because
        the table rows are produced by the identical IEEE expression.

    Returns
    -------
    dict
        Broadcast-compatible arrays keyed by name: the disjoint case
        masks ``empty``/``flat``/``overrun``/``floor``/``single``/
        ``split`` (``split`` means two bracketing gears; emission of each
        half is still guarded by ``w``/``w_rem`` > 1e-12 as in the scalar
        rule), positions ``hi_idx``/``lo_idx`` into `gears`, and
        durations ``d_at_top``/``t_floor``/``t_hi_full``/``t_hi``/
        ``t_lo`` plus the work fractions ``w``/``w_rem``. Slack-
        independent quantities (``empty``/``d_at_top``/``t_floor``) keep
        their natural input shape rather than being materialized to the
        full broadcast shape -- with a (tasks, 1) duration column against
        a (tasks, candidates) slack matrix they stay one column wide, so
        the per-candidate cost of a sweep excludes them entirely.
    """
    d = np.asarray(d_top, dtype=float)
    s = np.asarray(slack, dtype=float)
    b = np.asarray(beta, dtype=float)
    top = gears[0]
    freqs = np.asarray([g.freq_ghz for g in gears])
    target = d + s
    if top.freq_ghz == f_ref:
        d_at_top = d
    else:
        d_at_top = d * (b * f_ref / top.freq_ghz + (1.0 - b))

    empty = d <= 0.0
    flat = ~empty & (s <= 1e-15)
    live = ~empty & ~flat
    overrun = live & (target <= d_at_top + 1e-15)
    live = live & ~overrun
    with np.errstate(divide="ignore", invalid="ignore"):
        t_floor = (t_full[..., -1] if t_full is not None
                   else d * (b * f_ref / freqs[-1] + (1.0 - b)))
        denom = target / d - (1.0 - b)
        # the bracketing search consumes -f_m, and (-x)/y == -(x/y)
        # exactly under IEEE division, so only the negation is built
        neg_f_m = -(b * f_ref) / denom
    floor = live & (t_floor <= target + 1e-15)
    split = live & ~floor

    # bracketing gears: first adjacent pair (hi, lo) with lo.f <= f <= hi.f,
    # i.e. lo = first gear with freq <= f_m (freqs are descending)
    neg_freqs = -freqs
    lo_idx = np.searchsorted(neg_freqs, neg_f_m, side="left")
    lo_idx = np.clip(lo_idx, 1, len(gears) - 1)
    hi_idx = lo_idx - 1
    # the clamp masks are deliberately NOT &-ed with `split`: non-split
    # elements never have hi/lo consumed, so clamping them too is free
    at_top = neg_f_m <= neg_freqs[0]       # f_m >= freqs[0]
    at_floor = neg_f_m >= neg_freqs[-1]    # f_m <= freqs[-1]
    hi_idx = np.where(at_top, 0, hi_idx)
    lo_idx = np.where(at_top, 0, lo_idx)
    hi_idx = np.where(at_floor, len(gears) - 1, hi_idx)
    lo_idx = np.where(at_floor, len(gears) - 1, lo_idx)

    single = split & (hi_idx == lo_idx)
    with np.errstate(divide="ignore", invalid="ignore"):
        if t_full is not None:
            t_hi_full = np.take_along_axis(t_full, hi_idx[..., None],
                                           axis=-1)[..., 0]
            t_lo_full = np.take_along_axis(t_full, lo_idx[..., None],
                                           axis=-1)[..., 0]
        else:
            t_hi_full = d * (b * f_ref / freqs[hi_idx] + (1.0 - b))
            t_lo_full = d * (b * f_ref / freqs[lo_idx] + (1.0 - b))
        w = (target - t_lo_full) / (t_hi_full - t_lo_full)
    w = np.clip(w, 0.0, 1.0)
    w_rem = 1.0 - w
    t_hi = w * t_hi_full
    t_lo = w_rem * t_lo_full
    split = split & ~single
    return {
        "empty": empty, "flat": flat, "overrun": overrun, "floor": floor,
        "single": single, "split": split, "hi_idx": hi_idx, "lo_idx": lo_idx,
        "d_at_top": d_at_top, "t_floor": t_floor, "t_hi_full": t_hi_full,
        "t_hi": t_hi, "t_lo": t_lo, "w": w, "w_rem": w_rem,
    }


def two_gear_split_batch(proc: ProcessorModel, d_top: np.ndarray,
                         slack: np.ndarray,
                         beta: np.ndarray | float = 1.0,
                         gears: tuple[Gear, ...] | None = None
                         ) -> list[list[Segment]]:
    """Vectorized `two_gear_split` over arrays of tasks.

    Produces, per task, exactly the segments the scalar function would
    (identical floats, not merely close: `two_gear_split_arrays` mirrors
    every scalar arithmetic expression elementwise, and the
    bracketing-gear search is the same first-match rule). The
    per-strategy plan builders call this once per graph instead of
    looping `two_gear_split` per task; the only remaining Python loop
    assembles the output lists from the precomputed arrays. `gears`
    restricts the whole batch to a subtable, as in the scalar function.

    Parameters
    ----------
    proc : ProcessorModel
        Supplies the gear ladder and the reference frequency `f_max`.
    d_top, slack : np.ndarray
        Per-task top-gear durations and reclaimable windows.
    beta : np.ndarray or float
        Per-task (or shared) frequency sensitivity.
    gears : tuple of Gear, optional
        Restrict the whole batch to this descending subtable.

    Returns
    -------
    list of list of (Gear, float)
        Per-task segments, exactly what the scalar function would emit.
    """
    if gears is None:
        gears = proc.gears
    d = np.asarray(d_top, dtype=float)
    n = len(d)
    a = two_gear_split_arrays(gears, proc.f_max, d,
                              np.asarray(slack, dtype=float), beta)
    empty, flat, overrun = a["empty"], a["flat"], a["overrun"]
    floor, single = a["floor"], a["single"]
    hi_idx, lo_idx = a["hi_idx"], a["lo_idx"]
    d_at_top, t_floor, t_hi_full = a["d_at_top"], a["t_floor"], a["t_hi_full"]
    t_hi, t_lo, w, w_rem = a["t_hi"], a["t_lo"], a["w"], a["w_rem"]

    top = gears[0]
    low_gear = gears[-1]
    out: list[list[Segment]] = []
    for i in range(n):
        if empty[i]:
            out.append([])
        elif flat[i] or overrun[i]:
            out.append([(top, float(d_at_top[i]))])
        elif floor[i]:
            out.append([(low_gear, float(t_floor[i]))])
        elif single[i]:
            out.append([(gears[int(hi_idx[i])], float(t_hi_full[i]))])
        else:
            segs: list[Segment] = []
            if w[i] > 1e-12:
                segs.append((gears[int(hi_idx[i])], float(t_hi[i])))
            if w_rem[i] > 1e-12:
                segs.append((gears[int(lo_idx[i])], float(t_lo[i])))
            out.append(segs)
    return out


def two_gear_split_batch_by_table(proc: ProcessorModel, d_top: np.ndarray,
                                  slack: np.ndarray,
                                  beta: np.ndarray | float,
                                  table_ids: np.ndarray,
                                  tables: Sequence[tuple[Gear, ...]]
                                  ) -> list[list[Segment]]:
    """Per-task asymmetric gear tables: task i may only use tables[table_ids[i]].

    One `two_gear_split_batch` call per distinct table (a handful, e.g.
    panel/solve/update classes), scattered back into task order; each task's
    segments are exactly what the scalar `two_gear_split` with its table
    would produce.

    Parameters
    ----------
    proc : ProcessorModel
        Supplies the reference frequency the durations are measured at.
    d_top, slack : np.ndarray
        Per-task top-gear durations and reclaimable windows.
    beta : np.ndarray or float
        Per-task (or shared) frequency sensitivity.
    table_ids : np.ndarray
        Index into `tables` per task.
    tables : sequence of gear tuples
        The asymmetric tables (each a descending subsequence of the
        ladder).

    Returns
    -------
    list of list of (Gear, float)
        Per-task segments, each confined to its task's table.
    """
    d = np.asarray(d_top, dtype=float)
    s = np.asarray(slack, dtype=float)
    b = np.broadcast_to(np.asarray(beta, dtype=float), d.shape)
    ids = np.asarray(table_ids)
    if ids.shape != d.shape:
        raise ValueError("table_ids must have one entry per task")
    if len(d) and (ids.min() < 0 or ids.max() >= len(tables)):
        raise ValueError(f"table_ids out of range [0, {len(tables)})")
    out: list[list[Segment]] = [[] for _ in range(len(d))]
    for t, table in enumerate(tables):
        sel = np.flatnonzero(ids == t)
        if not len(sel):
            continue
        sub = two_gear_split_batch(proc, d[sel], s[sel], b[sel], gears=table)
        for j, i in enumerate(sel):
            out[i] = sub[j]
    return out


def plan_energy_j(proc: ProcessorModel, segs: list[Segment]) -> float:
    """Active-core energy of a frequency plan (excludes nodal constant)."""
    return sum(proc.core_power_w(g, active=True) * t for g, t in segs)
