"""Roofline-informed per-kind frequency sensitivity (beta).

The engines model every task's duration at gear frequency `f` as

    d(f) = d_top * (beta * f_top / f + (1 - beta))

(`CostModel.beta`, consumed by `dvfs.two_gear_split*` and all three
engines through the plans). The paper hand-sets beta per task kind; this
module derives it from *measured* roofline terms instead — the committed
`results/roofline.json` artifact produced by `repro.launch.zoo`, which
compiles every model-zoo config per phase (train / prefill / decode) and
extracts per-device compute, memory, and collective seconds from the HLO
(docs/ROOFLINE.md documents the pipeline and the JSON schema).

The derivation (`beta_from_terms`): only the compute term scales with
clock frequency, so the true step time at a frequency ratio
`s = f_top / f` is

    d(s) = max(compute_s * s, memory_s, collective_s)

Linearizing between the exact value at `s = 1` and the exact asymptotic
slope as `s -> inf` gives beta = compute_s / max(all three) — the
`roofline_frac` of `launch/roofline.py`. A compute-bound step (frac 1.0)
stretches linearly with the clock; a memory- or collective-bound step is
nearly gear-invariant (Calore et al. measure exactly this on HPC
processors and accelerators). A floor keeps beta away from 0.0: control
flow and issue logic always retain some clock sensitivity, and a
measured-zero beta would make downclocking literally free.

Because betas enter planning purely through `CostModel.freq_sensitivity`
— plans carry `(gear, seconds)` segments, not betas — no engine changes
are needed and `simulate` / `simulate_reference` / `simulate_fleet`
inherit the values in lockstep (the PR 5 corollary of the differential
policy; pinned by `tests/test_roofline.py`).
"""

from __future__ import annotations

import dataclasses
import json
import os

from .scheduler import CostModel

# The committed artifact (repo root); regenerated + drift-checked in CI by
# `python -m repro.launch.zoo --check`.
ROOFLINE_JSON = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                             "results", "roofline.json")

#: Phases measured per architecture, in row order.
PHASES = ("train", "prefill", "decode")

#: Default beta floor (see `beta_from_terms`).
BETA_FLOOR = 0.05


def beta_from_terms(compute_s: float, memory_s: float, collective_s: float,
                    *, floor: float = BETA_FLOOR) -> float:
    """Frequency-sensitivity beta of a step from its roofline terms.

    Only the compute term scales with the clock, so slowing the clock by
    `s = f_top / f` gives `d(s) = max(compute_s * s, memory_s,
    collective_s)`; the linear surrogate `d_top * (beta * s + 1 - beta)`
    that is exact at `s = 1` and has the exact `s -> inf` slope uses

        beta = compute_s / max(compute_s, memory_s, collective_s)

    i.e. 1.0 when the step sits on the compute roofline (linear stretch)
    and -> 0 when memory or collectives bound it (gear-invariant).

    Parameters
    ----------
    compute_s, memory_s, collective_s : float
        The step's three roofline terms in seconds (any common scale —
        only the ratio matters).
    floor : float
        Lower clamp for the result; clock/control overhead never fully
        vanishes, and a beta of exactly 0.0 would make downclocking
        free. The upper clamp is 1.0.

    Returns
    -------
    float
        Beta in `[floor, 1.0]`.
    """
    bound = max(compute_s, memory_s, collective_s)
    frac = compute_s / bound if bound > 0.0 else 1.0
    return min(max(frac, floor), 1.0)


@dataclasses.dataclass(frozen=True)
class RooflineTable:
    """Parsed `results/roofline.json` (schema ``roofline/v2``).

    `rows` holds one dict per (arch, phase) with the measured per-device
    roofline terms and the derived beta; `meta` keeps the generator
    header (mesh, device count, hardware constants, beta floor) so
    downstream consumers can attribute the numbers.
    """

    rows: tuple[dict, ...]
    meta: dict

    @classmethod
    def load(cls, path: str | None = None) -> "RooflineTable":
        """Load the committed roofline artifact.

        Parameters
        ----------
        path : str, optional
            JSON path; defaults to the repo's `results/roofline.json`.

        Returns
        -------
        RooflineTable
            The parsed table.

        Raises
        ------
        FileNotFoundError
            If the artifact is missing (run
            ``python -m repro.launch.zoo --out results/roofline.json``).
        ValueError
            If the file is not a ``roofline/v2`` document (e.g. the
            legacy `dryrun.json` list schema).
        """
        path = path or ROOFLINE_JSON
        with open(path) as f:
            doc = json.load(f)
        if not isinstance(doc, dict) or \
                not str(doc.get("schema", "")).startswith("roofline/"):
            raise ValueError(f"{path} is not a roofline/v2 document; "
                             "regenerate with `python -m repro.launch.zoo`")
        rows = tuple(doc["rows"])
        meta = {k: v for k, v in doc.items() if k != "rows"}
        return cls(rows=rows, meta=meta)

    def archs(self) -> tuple[str, ...]:
        """Architectures present, in first-appearance order."""
        seen: dict[str, None] = {}
        for r in self.rows:
            seen.setdefault(r["arch"], None)
        return tuple(seen)

    def get(self, arch: str, phase: str) -> dict:
        """The measured row of one (arch, phase) cell.

        Parameters
        ----------
        arch : str
            Architecture key (a `repro.configs.ARCHS` name).
        phase : str
            One of `PHASES`.

        Returns
        -------
        dict
            The row (terms, bottleneck, beta, flops_per_token, ...).

        Raises
        ------
        KeyError
            If the cell is not in the table.
        """
        for r in self.rows:
            if r["arch"] == arch and r["phase"] == phase:
                return r
        raise KeyError(f"no roofline row for ({arch!r}, {phase!r}); "
                       f"known archs: {self.archs()}")

    def beta(self, arch: str, phase: str) -> float:
        """Derived frequency-sensitivity beta of one (arch, phase) cell."""
        return float(self.get(arch, phase)["beta"])

    def flops_per_token(self, arch: str, phase: str) -> float:
        """Measured dot flops per token of one (arch, phase) cell."""
        return float(self.get(arch, phase)["flops_per_token"])

    def kind_betas(self, arch: str) -> dict[str, float]:
        """Per-task-kind betas of one architecture.

        Maps the serving/LM task kinds onto the measured phases:
        `TRAIN` / `PREFILL` / `DECODE` from the same-named rows, plus
        `CLOCK: 0.0` (the serving wall-clock rank must stay
        gear-invariant — `build_serving_graph` validates it).

        Parameters
        ----------
        arch : str
            Architecture key (a `repro.configs.ARCHS` name).

        Returns
        -------
        dict[str, float]
            `{"TRAIN": ..., "PREFILL": ..., "DECODE": ..., "CLOCK": 0.0}`.
        """
        return {
            "TRAIN": self.beta(arch, "train"),
            "PREFILL": self.beta(arch, "prefill"),
            "DECODE": self.beta(arch, "decode"),
            "CLOCK": 0.0,
        }


def load_roofline(path: str | None = None) -> RooflineTable:
    """Load the committed roofline table (see `RooflineTable.load`).

    Parameters
    ----------
    path : str, optional
        JSON path; defaults to the repo's `results/roofline.json`.

    Returns
    -------
    RooflineTable
        The parsed table.
    """
    return RooflineTable.load(path)


def roofline_cost_model(arch: str, *, table: RooflineTable | None = None,
                        flops_per_cycle: float = 4.0,
                        comm_bandwidth_gbs: float = 5.0,
                        comm_latency_s: float = 5e-6) -> CostModel:
    """A `CostModel` whose per-kind betas come from measured rooflines.

    The returned model prices `TRAIN` / `PREFILL` / `DECODE` tasks with
    the architecture's measured phase betas (`RooflineTable.kind_betas`)
    and pins `CLOCK` at 0.0, so serving graphs built against it keep
    their gear-invariant wave cadence. All three engines consume the
    betas through the plans — no engine-side configuration is needed.

    Parameters
    ----------
    arch : str
        Architecture key (a `repro.configs.ARCHS` name).
    table : RooflineTable, optional
        Parsed table; loaded from the committed artifact when omitted.
    flops_per_cycle, comm_bandwidth_gbs, comm_latency_s : float
        Forwarded to `CostModel`.

    Returns
    -------
    CostModel
        Ready for `PlanContext` / `build_serving_graph`.
    """
    table = table or load_roofline()
    return CostModel(flops_per_cycle=flops_per_cycle,
                     freq_sensitivity=table.kind_betas(arch),
                     comm_bandwidth_gbs=comm_bandwidth_gbs,
                     comm_latency_s=comm_latency_s)
