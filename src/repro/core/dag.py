"""Task DAGs for tiled Cholesky, LU, and QR factorizations.

The paper's central object: the *statically known* task graph of a blocked
dense factorization over a 2-D block-cyclic tile layout. Every task carries

    kind        -- POTRF/TRSM/SYRK/GEMM (Cholesky), GETRF/TRSM_ROW/TRSM_COL/
                   GEMM (LU), GEQRT/UNMQR/TSQRT/SSRFB (QR, flat tree)
    (k, i, j)   -- iteration and tile indices
    owner       -- rank under the (P x Q) block-cyclic map (owner computes)
    flops       -- analytic flop count for a b x b tile
    deps        -- task ids (data dependencies; the scheduler adds the
                   same-rank program-order edge itself)
    out_tile    -- tile written (for transfer-size modeling on cross-rank
                   edges: a consumer on another rank pays tile_bytes/bw + lat)

Because the DAG, ownership, and costs are known before execution, the DVFS
schedule can be computed *algorithmically* -- that is the paper's thesis; all
of core/strategies.py consumes this graph.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterator

import numpy as np

# Relative efficiency of each kernel kind at peak gear (fraction of peak
# flop-rate a tuned kernel of that kind achieves; GEMM-like ops run near
# peak, panel ops are memory/latency bound). Used by the cost model.
KIND_EFFICIENCY: dict[str, float] = {
    "POTRF": 0.30, "GETRF": 0.25, "GEQRT": 0.25,
    "TRSM": 0.75, "TRSM_ROW": 0.75, "TRSM_COL": 0.75,
    "SYRK": 0.85, "GEMM": 0.90, "UNMQR": 0.80, "TSQRT": 0.35, "SSRFB": 0.85,
    # serving kinds (core/serving.py): prefill is a GEMM-shaped
    # compute-bound pass, decode is memory-bandwidth-bound token
    # generation, CLOCK is the zero-power wall-clock chain that gates
    # continuous-batching waves (calibrated so 1.0 is exact).
    "PREFILL": 0.85, "DECODE": 0.30, "CLOCK": 1.0,
}

# Panel kinds sit on (or next to) the critical path of iteration k.
# Serving graphs map prefill onto the same class: a compute-bound step
# that gates everything behind it (core/serving.py).
PANEL_KINDS = frozenset({"POTRF", "GETRF", "GEQRT", "TSQRT", "PREFILL"})


@dataclasses.dataclass
class Task:
    """One kernel invocation of the factorization (see module docstring)."""

    tid: int
    kind: str
    k: int
    i: int
    j: int
    owner: int
    flops: float
    deps: list[int]
    out_tile: tuple[int, int]


@dataclasses.dataclass
class TaskGraph:
    """A factorization's task DAG plus its block-cyclic layout metadata."""

    name: str                      # "cholesky" | "lu" | "qr"
    n_tiles: int                   # T: matrix is (T*b) x (T*b)
    tile_size: int                 # b
    grid: tuple[int, int]          # (P, Q) process grid
    tasks: list[Task]
    dtype_bytes: int = 8           # fp64, as in the paper's ScaLAPACK runs

    @property
    def n_ranks(self) -> int:
        """Number of MPI ranks: P * Q of the block-cyclic process grid."""
        return self.grid[0] * self.grid[1]

    @property
    def tile_bytes(self) -> int:
        """Bytes of one b x b tile (the unit of cross-rank transfer)."""
        return self.tile_size * self.tile_size * self.dtype_bytes

    def successors(self) -> list[list[int]]:
        """Per-task consumer lists (cached; treat the result as read-only)."""
        succ = self.__dict__.get("_succ")
        if succ is None:
            succ = [[] for _ in self.tasks]
            for t in self.tasks:
                for d in t.deps:
                    succ[d].append(t.tid)
            self.__dict__["_succ"] = succ
        return succ

    def tasks_by_rank(self) -> list[list[int]]:
        """Program order per rank (tasks are emitted in SPMD loop order).

        Cached; treat the result as read-only.
        """
        per = self.__dict__.get("_per_rank")
        if per is None:
            per = [[] for _ in range(self.n_ranks)]
            for t in self.tasks:
                per[t.owner].append(t.tid)
            self.__dict__["_per_rank"] = per
        return per

    def total_flops(self) -> float:
        """Sum of the analytic flop counts over every task."""
        return sum(t.flops for t in self.tasks)

    # -- cached NumPy views (shared by the scheduler, slack, and CP code) --
    # TaskGraph is a plain mutable dataclass, so caches live in __dict__ and
    # are computed at most once per graph; builders never mutate `tasks`
    # after construction.

    def dep_edge_arrays(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Flat dependency edges: (src, dst, cross_rank) arrays.

        src[e] -> dst[e] is a data edge (dst consumes src's output);
        cross_rank[e] is True when the edge pays the communication delay.
        """
        cached = self.__dict__.get("_dep_edges")
        if cached is None:
            src = [d for t in self.tasks for d in t.deps]
            dst = [t.tid for t in self.tasks for _ in t.deps]
            src_a = np.asarray(src, dtype=np.int64)
            dst_a = np.asarray(dst, dtype=np.int64)
            owner = np.asarray([t.owner for t in self.tasks], dtype=np.int64)
            cross = (owner[src_a] != owner[dst_a]) if len(src) else \
                np.zeros(0, dtype=bool)
            cached = (src_a, dst_a, cross)
            self.__dict__["_dep_edges"] = cached
        return cached

    def rank_order_pairs(self) -> tuple[np.ndarray, np.ndarray]:
        """Consecutive same-rank pairs (prev, next) in program order."""
        cached = self.__dict__.get("_rank_pairs")
        if cached is None:
            prev: list[int] = []
            nxt: list[int] = []
            for rank_tasks in self.tasks_by_rank():
                prev.extend(rank_tasks[:-1])
                nxt.extend(rank_tasks[1:])
            cached = (np.asarray(prev, dtype=np.int64),
                      np.asarray(nxt, dtype=np.int64))
            self.__dict__["_rank_pairs"] = cached
        return cached

    def task_levels(self) -> np.ndarray:
        """Longest-path depth of each task over data edges (level 0 = roots).

        Consumers sit strictly above all their producers, so processing
        tasks level-by-level is a valid (vectorizable) topological sweep.
        """
        cached = self.__dict__.get("_levels")
        if cached is None:
            level = np.zeros(len(self.tasks), dtype=np.int64)
            for t in self.tasks:          # tids are already topological
                if t.deps:
                    level[t.tid] = 1 + max(int(level[d]) for d in t.deps)
            cached = level
            self.__dict__["_levels"] = cached
        return cached

    def dep_edges_by_level(self) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                                          np.ndarray]:
        """Dependency edges sorted by the consumer's level, plus group bounds.

        Returns (src, dst, cross_rank, bounds) where edges with consumer
        level L occupy slice [bounds[L], bounds[L+1]). Enables level-wise
        vectorized forward/backward CP passes.
        """
        cached = self.__dict__.get("_edges_by_level")
        if cached is None:
            src, dst, cross = self.dep_edge_arrays()
            level = self.task_levels()
            n_levels = int(level.max()) + 1 if len(level) else 1
            order = np.argsort(level[dst], kind="stable") if len(dst) else \
                np.zeros(0, dtype=np.int64)
            src_s, dst_s, cross_s = src[order], dst[order], cross[order]
            bounds = np.searchsorted(level[dst_s], np.arange(n_levels + 1))
            cached = (src_s, dst_s, cross_s, bounds)
            self.__dict__["_edges_by_level"] = cached
        return cached


def block_cyclic_owner(i: int, j: int, grid: tuple[int, int]) -> int:
    """Rank owning tile (i, j) under the 2-D block-cyclic (P x Q) map."""
    p, q = grid
    return (i % p) * q + (j % q)


class _Builder:
    def __init__(self, grid: tuple[int, int]):
        self.grid = grid
        self.tasks: list[Task] = []
        self.last_writer: dict[tuple[int, int], int] = {}

    def add(self, kind: str, k: int, i: int, j: int, flops: float,
            reads: list[tuple[int, int]], writes: tuple[int, int],
            extra_deps: tuple[int, ...] = ()) -> int:
        tid = len(self.tasks)
        deps: list[int] = []
        for tile in reads + [writes]:      # read-after-write + write-after-write
            w = self.last_writer.get(tile)
            if w is not None and w not in deps:
                deps.append(w)
        for d in extra_deps:
            if d not in deps:
                deps.append(d)
        self.tasks.append(Task(tid, kind, k, i, j,
                               block_cyclic_owner(*writes, self.grid),
                               flops, deps, writes))
        self.last_writer[writes] = tid
        return tid


def build_cholesky_dag(n_tiles: int, tile_size: int,
                       grid: tuple[int, int]) -> TaskGraph:
    """Right-looking tiled Cholesky (lower)."""
    b = float(tile_size)
    bd = _Builder(grid)
    for k in range(n_tiles):
        bd.add("POTRF", k, k, k, b**3 / 3.0, [], (k, k))
        for i in range(k + 1, n_tiles):
            bd.add("TRSM", k, i, k, b**3, [(k, k)], (i, k))
        for i in range(k + 1, n_tiles):
            bd.add("SYRK", k, i, i, b**3, [(i, k)], (i, i))
            for j in range(k + 1, i):
                bd.add("GEMM", k, i, j, 2.0 * b**3, [(i, k), (j, k)], (i, j))
    return TaskGraph("cholesky", n_tiles, tile_size, grid, bd.tasks)


def build_lu_dag(n_tiles: int, tile_size: int,
                 grid: tuple[int, int]) -> TaskGraph:
    """Right-looking tiled LU (block variant; pivoting confined to panel)."""
    b = float(tile_size)
    bd = _Builder(grid)
    for k in range(n_tiles):
        bd.add("GETRF", k, k, k, 2.0 * b**3 / 3.0, [], (k, k))
        for j in range(k + 1, n_tiles):    # U row: L_kk^-1 applied
            bd.add("TRSM_ROW", k, k, j, b**3, [(k, k)], (k, j))
        for i in range(k + 1, n_tiles):    # L column: U_kk^-1 applied
            bd.add("TRSM_COL", k, i, k, b**3, [(k, k)], (i, k))
        for i in range(k + 1, n_tiles):
            for j in range(k + 1, n_tiles):
                bd.add("GEMM", k, i, j, 2.0 * b**3, [(i, k), (k, j)], (i, j))
    return TaskGraph("lu", n_tiles, tile_size, grid, bd.tasks)


def build_qr_dag(n_tiles: int, tile_size: int,
                 grid: tuple[int, int]) -> TaskGraph:
    """Tiled Householder QR with a flat reduction tree (PLASMA-style).

    GEQRT(k)        factor diagonal tile
    UNMQR(k, j)     apply V_kk to row-k tiles
    TSQRT(i, k)     couple tile (i,k) with the R of (k,k)  [sequential in i]
    SSRFB(i, j, k)  apply the (i,k) reflectors to rows i and k of column j
    """
    b = float(tile_size)
    bd = _Builder(grid)
    for k in range(n_tiles):
        geqrt = bd.add("GEQRT", k, k, k, (4.0 / 3.0) * b**3, [], (k, k))
        for j in range(k + 1, n_tiles):
            bd.add("UNMQR", k, k, j, 2.0 * b**3, [(k, k)], (k, j),
                   extra_deps=(geqrt,))
        prev_ts = geqrt
        for i in range(k + 1, n_tiles):
            prev_ts = bd.add("TSQRT", k, i, k, (10.0 / 3.0) * b**3,
                             [(k, k)], (i, k), extra_deps=(prev_ts,))
            for j in range(k + 1, n_tiles):
                # updates both (k,j) and (i,j): register the write on (i,j)
                # and mark the task as the last writer of (k,j) too, so the
                # next SSRFB down column j is correctly serialized.
                tid = bd.add("SSRFB", k, i, j, 4.0 * b**3,
                             [(i, k), (k, j)], (i, j))
                bd.last_writer[(k, j)] = tid
    return TaskGraph("qr", n_tiles, tile_size, grid, bd.tasks)


DAG_BUILDERS: dict[str, Callable[[int, int, tuple[int, int]], TaskGraph]] = {
    "cholesky": build_cholesky_dag,
    "lu": build_lu_dag,
    "qr": build_qr_dag,
}


def build_dag(name: str, n_tiles: int, tile_size: int,
              grid: tuple[int, int]) -> TaskGraph:
    """Build the named factorization's DAG ("cholesky" | "lu" | "qr")."""
    return DAG_BUILDERS[name](n_tiles, tile_size, grid)


def factorization_flops(name: str, n: int) -> float:
    """Analytic flop count of the full n x n factorization."""
    if name == "cholesky":
        return n**3 / 3.0
    if name == "lu":
        return 2.0 * n**3 / 3.0
    if name.startswith("qr"):       # qr | qr-cholqr2 (same useful flops)
        return 4.0 * n**3 / 3.0
    raise ValueError(name)
