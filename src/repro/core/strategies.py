"""The four energy strategies evaluated by the paper.

 * original        -- peak gear everywhere, idle at peak gear.
 * race_to_halt    -- peak gear while computing, lowest gear while idle;
                      *reactive*: pays a wake-up gear-switch stall and a
                      per-task completion-monitoring overhead.
 * cp_aware        -- online CP-aware slack reclamation (Adagio-style):
                      stretches off-CP tasks into their measured slack with
                      the two-gear split; pays a per-task detection overhead.
 * algorithmic     -- THE PAPER: identical slack reclamation *computed
                      offline* from the factorization's known task DAG and
                      cost model: zero runtime detection overhead, gear
                      switches pre-armed during waits (no wake-up stall),
                      plus scheduled-communication low gear during waits.

All strategies other than `original` halt (lowest gear) during waits --
communication slack handling is shared, as in the paper's experiments.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .critical_path import schedule_slack
from .dag import TaskGraph
from .dvfs import two_gear_split
from .energy_model import ProcessorModel
from .scheduler import CostModel, Schedule, StrategyPlan, simulate

STRATEGIES = ("original", "race_to_halt", "cp_aware", "algorithmic")


@dataclasses.dataclass
class StrategyConfig:
    # fraction of each task spent on online CP/slack detection (cp_aware)
    cp_detect_overhead: float = 0.005
    # fraction of each task spent on completion monitoring (race_to_halt)
    monitor_overhead: float = 0.001
    # fraction of realized local slack a strategy dares to reclaim (< 1.0
    # guards against cost-model error in the online strategy; the
    # algorithmic plan knows the DAG exactly and uses everything)
    cp_aware_slack_use: float = 0.9
    algorithmic_slack_use: float = 1.0
    # ignore slacks too small to be worth a switch
    min_reclaim_s: float = 500e-6


def _top_gear_segments(graph: TaskGraph, proc: ProcessorModel,
                       cost: CostModel) -> list[list]:
    top = proc.gears[0]
    durs = cost.durations_top(graph, proc)
    return [[(top, float(durs[t.tid]))] for t in graph.tasks]


def _baseline_schedule(graph: TaskGraph, proc: ProcessorModel,
                       cost: CostModel) -> Schedule:
    """Pure peak-gear schedule with no overheads (the timing oracle)."""
    plan = StrategyPlan(
        name="baseline",
        task_segments=_top_gear_segments(graph, proc, cost),
        idle_gear=proc.gears[0],
        per_task_overhead=np.zeros(len(graph.tasks)),
        hide_switch_in_wait=True,
    )
    return simulate(graph, proc, cost, plan)


def _reclaimed_segments(graph: TaskGraph, proc: ProcessorModel,
                        cost: CostModel, base: Schedule,
                        slack_use: float, min_reclaim_s: float) -> list[list]:
    slack = schedule_slack(base.start, base.finish, graph,
                           cost.comm_time(graph))
    durs = cost.durations_top(graph, proc)
    segs = []
    for t in graph.tasks:
        d = float(durs[t.tid])
        s = float(slack[t.tid]) * slack_use
        if s < min_reclaim_s:
            segs.append([(proc.gears[0], d)])
        else:
            segs.append(two_gear_split(proc, d, s, cost.beta(t.kind)))
    return segs


def make_plan(name: str, graph: TaskGraph, proc: ProcessorModel,
              cost: CostModel,
              cfg: StrategyConfig | None = None) -> StrategyPlan:
    cfg = cfg or StrategyConfig()
    n = len(graph.tasks)
    top, low = proc.gears[0], proc.gears[-1]
    durs = cost.durations_top(graph, proc)

    if name == "original":
        return StrategyPlan("original", _top_gear_segments(graph, proc, cost),
                            idle_gear=top,
                            per_task_overhead=np.zeros(n),
                            hide_switch_in_wait=True)

    if name == "race_to_halt":
        return StrategyPlan("race_to_halt",
                            _top_gear_segments(graph, proc, cost),
                            idle_gear=low,
                            per_task_overhead=durs * cfg.monitor_overhead,
                            hide_switch_in_wait=False)  # reactive wake-up

    base = _baseline_schedule(graph, proc, cost)

    if name == "cp_aware":
        segs = _reclaimed_segments(graph, proc, cost, base,
                                   cfg.cp_aware_slack_use, cfg.min_reclaim_s)
        return StrategyPlan("cp_aware", segs, idle_gear=low,
                            per_task_overhead=durs * cfg.cp_detect_overhead,
                            hide_switch_in_wait=True)

    if name == "algorithmic":
        segs = _reclaimed_segments(graph, proc, cost, base,
                                   cfg.algorithmic_slack_use,
                                   cfg.min_reclaim_s)
        return StrategyPlan("algorithmic", segs, idle_gear=low,
                            per_task_overhead=np.zeros(n),
                            hide_switch_in_wait=True)

    raise ValueError(f"unknown strategy {name!r}; choose from {STRATEGIES}")


@dataclasses.dataclass
class StrategyResult:
    name: str
    makespan_s: float
    energy_j: float
    avg_power_w: float
    slowdown_pct: float        # vs original
    energy_saved_pct: float    # vs original
    switch_count: int
    schedule: Schedule


def evaluate_strategies(graph: TaskGraph, proc: ProcessorModel,
                        cost: CostModel,
                        names: tuple[str, ...] = STRATEGIES,
                        cfg: StrategyConfig | None = None,
                        ) -> dict[str, StrategyResult]:
    results: dict[str, StrategyResult] = {}
    ref_time = ref_energy = None
    for name in names:
        sched = simulate(graph, proc, cost, make_plan(name, graph, proc,
                                                      cost, cfg))
        t, e = sched.makespan, sched.total_energy_j()
        if name == "original":
            ref_time, ref_energy = t, e
        results[name] = StrategyResult(
            name=name, makespan_s=t, energy_j=e,
            avg_power_w=e / t if t else 0.0,
            slowdown_pct=100.0 * (t / ref_time - 1.0) if ref_time else 0.0,
            energy_saved_pct=100.0 * (1.0 - e / ref_energy)
            if ref_energy else 0.0,
            switch_count=sched.switch_count,
            schedule=sched)
    return results
