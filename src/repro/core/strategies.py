"""Pluggable DVFS strategy engine: registry, shared PlanContext, and the
paper's strategies (plus TX, the explicit TDS-driven plan).

Built-in strategies:

 * original        -- peak gear everywhere, idle at peak gear.
 * race_to_halt    -- peak gear while computing, lowest gear while idle;
                      *reactive*: pays a wake-up gear-switch stall and a
                      per-task completion-monitoring overhead.
 * cp_aware        -- online CP-aware slack reclamation (Adagio-style):
                      stretches off-CP tasks into their measured slack with
                      the two-gear split; pays a per-task detection overhead.
 * algorithmic     -- THE PAPER: identical slack reclamation *computed
                      offline* from the factorization's known task DAG and
                      cost model: zero runtime detection overhead, gear
                      switches pre-armed during waits (no wake-up stall),
                      plus scheduled-communication low gear during waits.
 * task_type_gears -- per-task-type gear policy on asymmetric gear tables
                      (Costero et al.): panel / solve / update task classes
                      each reclaim slack within their own slice of the
                      ladder (`kind_gear_depth`), so latency-critical kinds
                      are robust by construction.
 * single_freq_opt -- optimal single-frequency selection (Rizvandi et
                      al.): the energy-minimizing uniform gear under a
                      makespan bound, swept over the table with the fast
                      engine pricing communication and switch stalls.
 * tx_online       -- TX planned from noise-perturbed duration estimates
                      (seeded, `tx_online_rel_err`) but realized on the
                      true work: quantifies how much of TX's savings
                      survive an imperfect cost model.
 * tx_replan       -- closed-loop variant of tx_online (`core/replan.py`):
                      same noisy estimates, but the schedule executes in
                      per-iteration waves and the remaining slack/TDS is
                      re-derived from *observed* finish times before each
                      wave's gears are committed (receding-horizon
                      re-planning via `PlanContext.restricted_to`).
 * tx              -- the paper's TDS mechanism made explicit: classify
                      every wait/slack window via `core/tds.py` (panel /
                      communication / load imbalance) and apply a per-class
                      policy -- fully stretch into imbalance and
                      communication slack down to a few switch latencies
                      (the transfer schedule is known, so the low gear can
                      be *scheduled*, not merely reacted to), but stay
                      conservative on panel-bound slack so a cost-model
                      error can never push the next panel start (the
                      up-switch is pre-armed instead).
 * tx_migrate      -- TX plus task *migration* on heterogeneous machines
                      (Costero et al.): candidate re-mappings move the
                      heaviest update-class tasks off LITTLE ranks onto
                      the least-loaded big ranks, each candidate is
                      re-planned with the TX policy under its new owners,
                      and one batched fleet pass (link transfer times and
                      energies included) picks the cheapest mapping within
                      `tx_migrate_slowdown_cap`; never worse than `tx`.

All strategies other than `original` halt (lowest gear) during waits --
communication slack handling is shared, as in the paper's experiments.

Registry API (the extension point every scaling PR plugs into):

    @register_strategy
    class MyStrategy:
        name = "mine"
        def plan(self, ctx: PlanContext) -> StrategyPlan: ...

  * `PlanContext` carries everything a planner may need -- graph, processor,
    cost model, config, top-gear durations, the baseline schedule, realized
    slack, and the TDS analysis -- each computed lazily *once* and shared by
    every strategy planned from the same context. Planners must treat its
    arrays as read-only (copy before mutating).
  * `make_plan(name, ...)` / `evaluate_strategies(...)` dispatch through the
    registry; `registered_strategies()` lists names in registration order.
  * Differential-suite obligation: any registered strategy is automatically
    exercised by `tests/test_scheduler_differential.py` (fast engine vs the
    `simulate_reference` oracle, exact agreement) -- including on randomized
    heterogeneous machines. A new strategy must keep that suite green --
    plans it emits may only use the `StrategyPlan` vocabulary both engines
    implement, and on a `MachineModel` every gear in a task's segments (and
    `rank_idle_gears`) must come from the owning rank's own ladder.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Protocol, Sequence, runtime_checkable

import numpy as np

from .critical_path import (residual_schedule_slack, residual_schedule_times,
                            schedule_slack)
from .dag import TaskGraph
from .dvfs import (duration_at, two_gear_split_batch,
                   two_gear_split_batch_by_table)
from .energy_model import Gear, MachineModel, ProcessorModel, as_machine
from .fleet import simulate_fleet
from .scheduler import CostModel, Schedule, StrategyPlan, simulate
from .tds import (GEAR_CLASS_NAMES, GEAR_CLASS_UPDATE, WAIT_PANEL, TdsResult,
                  analyze_residual_tds, analyze_tds, task_gear_classes)

# The four strategies the paper evaluates (fixed, used by the paper-table
# benchmarks); `registered_strategies()` additionally includes `tx` and any
# strategy registered by downstream code.
STRATEGIES = ("original", "race_to_halt", "cp_aware", "algorithmic")


@dataclasses.dataclass
class StrategyConfig:
    # fraction of each task spent on online CP/slack detection (cp_aware)
    cp_detect_overhead: float = 0.005
    # fraction of each task spent on completion monitoring (race_to_halt)
    monitor_overhead: float = 0.001
    # fraction of realized local slack a strategy dares to reclaim (< 1.0
    # guards against cost-model error in the online strategy; the
    # algorithmic plan knows the DAG exactly and uses everything)
    cp_aware_slack_use: float = 0.9
    algorithmic_slack_use: float = 1.0
    # ignore slacks too small to be worth a switch
    min_reclaim_s: float = 500e-6
    # tx: fraction of *panel-bound* slack to reclaim (stretching into it
    # risks delaying the next panel if the cost model errs; TX pre-arms the
    # up-switch and keeps a guard band instead)
    tx_panel_slack_use: float = 0.5
    # tx: comm/imbalance slack is reclaimed down to this many switch
    # latencies (the wait is scheduled, so even short windows pay off)
    tx_min_reclaim_switches: float = 4.0
    # task_type_gears: ladder depth allowed per gear class (Costero-style
    # asymmetric tables). 0.0 = top gear only, 1.0 = the full table; keys
    # are `tds.GEAR_CLASS_NAMES`. Panel tasks stay on the fast operating
    # points (they bound every iteration), solves get the upper half,
    # trailing updates may stretch through the whole ladder.
    kind_gear_depth: dict[str, float] = dataclasses.field(
        default_factory=lambda: {"panel": 0.0, "solve": 0.5, "update": 1.0})
    # single_freq_opt: makespan bound as a fraction over the baseline
    # (Rizvandi-style optimal uniform frequency under a deadline)
    single_freq_slowdown_cap: float = 0.05
    # tx_online: relative cost-model error of the planner's duration
    # estimates (uniform in [-err, +err], per task; must be in [0, 1) so
    # an estimate can never go non-positive) and the noise seed.
    # tx_replan shares BOTH knobs -- the closed-loop planner starts from
    # the identical noise draw, so any savings difference between the two
    # is attributable to the feedback loop alone.
    tx_online_rel_err: float = 0.10
    tx_online_seed: int = 0
    # tx_replan: iterations (panel steps k) per re-planning wave. 1 =
    # re-derive residual slack/TDS from observed finishes before every
    # iteration; a value >= the graph's iteration count degenerates to a
    # single wave, i.e. exactly tx_online's one-shot plan.
    replan_every: int = 1
    # tx_replan: what the residual view is anchored on. "model" (default)
    # pins the executed prefix at the duration-reconciled top-gear
    # reconstruction -- the estimates corrected by the true work each
    # observed finish reveals -- which makes rel_err = 0 a provable fixed
    # point (plan bit-identical to `tx`). "observed" pins the prefix at
    # the raw realized finish times instead: the planner additionally
    # re-plans around engine effects the TX slack model does not price
    # (visible switch stalls), at the cost of the exact-identity property.
    replan_anchor: str = "model"
    # plan_search (core/optimize.py): makespan bound as a fraction over
    # the baseline, search rounds (coordinate-descent sweeps; each round
    # scores every level-band mutation in one batched fleet pass), the
    # evaluator's lane-buffer width, and the jitter seed.
    plan_search_slowdown_cap: float = 0.05
    plan_search_rounds: int = 4
    plan_search_lanes: int = 192
    plan_search_seed: int = 0
    # tx_migrate: makespan bound (fraction over baseline) a migrated
    # mapping must honor, and the cap on how many update-class tasks the
    # greedy mover may pull off LITTLE ranks (candidate mappings are
    # doubling prefixes 1, 2, 4, ... of the move list, so the cap bounds
    # the batched scoring pass, not a per-move loop).
    tx_migrate_slowdown_cap: float = 0.005
    tx_migrate_max_moves: int = 32
    # tx_replan: also re-map (not just re-gear) pending tasks at each
    # wave, scoring candidate migrations against the wave's makespan cap.
    # Off by default: the False path is bit-identical to the pre-migration
    # replan driver.
    replan_migrate: bool = False
    # serving SLO (core/serving.py): absolute makespan deadline in
    # seconds. For a serving trace this is the latency cap -- the trace
    # horizon plus the per-request SLO -- and it tightens the relative
    # slowdown caps above through `PlanContext.makespan_cap`: strategies
    # that honor a makespan bound (`single_freq_opt`, `plan_search`) cap
    # at min(relative cap, SLO), never below the baseline makespan (the
    # top-gear schedule stays feasible). None (default) leaves every
    # existing cap bit-identical.
    slo_latency_s: float | None = None

    def __setattr__(self, name, value):
        # knob-name validation: a misspelled knob set after construction
        # (cfg.tx_panel_slack_us = ...) used to pass silently and leave
        # the real knob at its default; the constructor already rejects
        # unknown keyword arguments via the dataclass __init__.
        if name not in self.__dataclass_fields__:
            raise ValueError(
                f"unknown StrategyConfig knob {name!r}; valid knobs: "
                f"{sorted(self.__dataclass_fields__)}")
        super().__setattr__(name, value)


class PlanContext:
    """Shared precomputed planning inputs for one (graph, proc, cost, cfg).

    Contract: every derived quantity is computed at most once, on first
    access, and cached for the context's lifetime; strategies planned from
    the same context therefore share the baseline schedule, slack, and TDS
    arrays instead of recomputing them. All exposed arrays are read-only by
    convention.

    `proc` may be a bare `ProcessorModel` (homogeneous cluster, the legacy
    path -- kept bit-identical) or a `MachineModel` assigning a possibly
    different processor to each rank. On a mixed machine, `durations` are
    referenced to each task's *owner rank's* top gear, so the baseline
    schedule, realized slack, and TDS classification all see fast and slow
    ranks as they actually are; plan-construction helpers group tasks by
    their owner's processor and split within that processor's own ladder.
    """

    def __init__(self, graph: TaskGraph,
                 proc: ProcessorModel | MachineModel,
                 cost: CostModel, cfg: StrategyConfig | None = None):
        self.graph = graph
        self.proc = proc
        self.cost = cost
        self.cfg = cfg or StrategyConfig()

    @property
    def n_tasks(self) -> int:
        """Number of tasks in the context's graph."""
        return len(self.graph.tasks)

    @functools.cached_property
    def machine(self) -> MachineModel:
        """The (possibly homogeneous-wrapped) per-rank machine model."""
        return as_machine(self.proc)

    @functools.cached_property
    def is_homogeneous(self) -> bool:
        """True when every rank runs one (equal) processor model."""
        return self.machine.is_homogeneous

    @functools.cached_property
    def _uproc(self) -> ProcessorModel:
        """The single processor of a homogeneous machine (identical to the
        constructor's `proc` when a bare ProcessorModel was passed)."""
        return self.machine.procs[0]

    @functools.cached_property
    def rank_procs(self) -> list[ProcessorModel]:
        """Concrete per-rank processor list for this graph's rank count."""
        return self.machine.rank_procs(self.graph.n_ranks)

    @functools.cached_property
    def task_proc_groups(self) -> list[tuple[ProcessorModel, np.ndarray]]:
        """Tasks grouped by their owner rank's processor (identity), in
        first-appearance order -- the batching unit for mixed machines."""
        procs = self.rank_procs
        groups: dict[int, tuple[ProcessorModel, list[int]]] = {}
        for t in self.graph.tasks:
            p = procs[t.owner]
            groups.setdefault(id(p), (p, []))[1].append(t.tid)
        return [(p, np.asarray(tids, dtype=np.int64))
                for p, tids in groups.values()]

    @functools.cached_property
    def task_switch_latency_s(self) -> "float | np.ndarray":
        """Switch latency of each task's owner (scalar when homogeneous)."""
        if self.is_homogeneous:
            return self._uproc.switch_latency_s
        procs = self.rank_procs
        return np.asarray([procs[t.owner].switch_latency_s
                           for t in self.graph.tasks])

    def _idle_gears(self, pos: int) -> tuple[Gear, "Sequence[Gear] | None"]:
        """(idle_gear, rank_idle_gears) pair for StrategyPlan: position 0 =
        every rank's top gear, -1 = every rank's lowest. Homogeneous
        machines get rank_idle_gears=None, i.e. the legacy plan shape."""
        if self.is_homogeneous:
            return self._uproc.gears[pos], None
        per_rank = [p.gears[pos] for p in self.rank_procs]
        return per_rank[0], per_rank

    @functools.cached_property
    def durations(self) -> np.ndarray:
        """Per-task durations at the owning rank's top gear."""
        return self.cost.durations_top(self.graph, self.proc)

    @functools.cached_property
    def betas(self) -> np.ndarray:
        """Per-task frequency sensitivity (beta) from the cost model."""
        return np.asarray([self.cost.beta(t.kind) for t in self.graph.tasks])

    @functools.cached_property
    def gear_classes(self) -> np.ndarray:
        """Per-task gear-class codes (panel / solve / update)."""
        return task_gear_classes(self.graph)

    def with_durations(self, durations: np.ndarray) -> "PlanContext":
        """A sibling context whose baseline/slack/TDS derive from the given
        durations instead of the cost model's.

        This is how an *online* planner with an imperfect cost model is
        expressed: plan against the estimated durations, then realize the
        chosen gears on the true work (see `TxOnlineStrategy`).
        """
        ctx = PlanContext(self.graph, self.proc, self.cost, self.cfg)
        ctx.__dict__["durations"] = np.asarray(durations, dtype=float)
        return ctx

    def with_owners(self, owners: "Sequence[int]") -> "PlanContext":
        """A sibling context whose tasks are remapped to `owners`.

        The migration-planning primitive (`TxMigrateStrategy`, migrating
        `tx_replan`): the returned context owns a *fresh* graph whose
        tasks carry the new owners (dependencies unchanged), so its
        baseline schedule, durations (each task timed at its NEW owner's
        top gear), slack, and TDS analysis all see the candidate mapping
        exactly as the engines would realize it. The original graph and
        its caches are untouched. An engine-consumable plan built from
        the returned context must still carry `task_owners=owners`,
        because the engines execute the ORIGINAL graph plus the override.
        """
        owners = [int(o) for o in owners]
        if len(owners) != self.n_tasks:
            raise ValueError(f"owners has {len(owners)} entries for "
                             f"{self.n_tasks} tasks")
        tasks = [dataclasses.replace(t, owner=o, deps=list(t.deps))
                 for t, o in zip(self.graph.tasks, owners)]
        graph = dataclasses.replace(self.graph, tasks=tasks)
        return PlanContext(graph, self.proc, self.cost, self.cfg)

    def restricted_to(self, tasks: "np.ndarray | Sequence[int]",
                      observed_finishes: np.ndarray) -> "ResidualPlanContext":
        """A residual view: plan only `tasks`, anchored on observed times.

        The closed-loop re-planning primitive (`core/replan.py`): mid-run,
        with everything outside `tasks` already executed, the view's
        `slack` and `tds` are re-derived on the residual subgraph from the
        *hybrid* schedule -- frozen tasks pinned at their realized finish
        times, pending tasks predicted forward at this context's (possibly
        estimated) top-gear durations. Gears already burned into the past
        cannot be revised, so frozen entries come back neutral (zero
        slack, `WAIT_NONE`); plan-construction helpers
        (`reclaimed_segments` etc.) keep working and simply emit don't-care
        segments for frozen tasks.

        Parameters
        ----------
        tasks : array-like
            The pending (not-yet-started) tasks: either a boolean mask
            over all tasks or an array of task ids. Must leave a frozen
            complement that is dependency-closed and a per-rank
            program-order prefix (`validate_frozen_closure`).
        observed_finishes : np.ndarray
            Full-length array of realized finish times; only frozen
            entries are read.

        Returns
        -------
        ResidualPlanContext
            A sibling context sharing this context's graph, machine, cost
            model, config, and durations, whose `slack`/`tds` are the
            residual analyses.
        """
        tasks = np.asarray(tasks)
        if tasks.dtype == bool:
            if tasks.shape != (self.n_tasks,):
                raise ValueError("pending mask must have one entry per task")
            pending = tasks.copy()
        else:
            pending = np.zeros(self.n_tasks, dtype=bool)
            pending[tasks] = True
        ctx = ResidualPlanContext(self.graph, self.proc, self.cost, self.cfg)
        ctx.__dict__["durations"] = self.durations
        ctx.pending = pending
        ctx.observed_finish = np.asarray(observed_finishes, dtype=float)
        if ctx.observed_finish.shape != (self.n_tasks,):
            raise ValueError("observed_finishes must have one entry per task")
        return ctx

    @functools.cached_property
    def baseline(self) -> Schedule:
        """Pure peak-gear schedule with no overheads (the timing oracle).

        Identical timing/energy to the `original` strategy's schedule, so
        it doubles as the reference for slowdown/savings percentages.
        """
        idle, rank_idle = self._idle_gears(0)
        return simulate(self.graph, self.proc, self.cost,
                        StrategyPlan(
                            name="baseline",
                            task_segments=self.top_gear_segments(),
                            idle_gear=idle,
                            per_task_overhead=np.zeros(self.n_tasks),
                            hide_switch_in_wait=True,
                            rank_idle_gears=rank_idle))

    @functools.cached_property
    def slack(self) -> np.ndarray:
        """Realized local slack on the baseline schedule."""
        base = self.baseline
        return schedule_slack(base.start, base.finish, self.graph,
                              self.cost.comm_cost(self.graph))

    @functools.cached_property
    def tds(self) -> TdsResult:
        """Task Dependency Set analysis over the baseline schedule."""
        base = self.baseline
        return analyze_tds(self.graph, base.start, base.finish,
                           self.cost.comm_cost(self.graph),
                           slack=self.slack)

    def makespan_cap(self, slowdown_frac: float) -> float:
        """Makespan bound for cap-honoring planners, SLO-aware.

        Parameters
        ----------
        slowdown_frac : float
            Allowed relative slowdown over the baseline makespan (e.g.
            `cfg.single_freq_slowdown_cap`).

        Returns
        -------
        float
            `baseline.makespan * (1 + slowdown_frac)`, tightened to
            `cfg.slo_latency_s` (the serving latency deadline) when that
            knob is set -- but never below the baseline makespan itself,
            so the top-gear plan is always feasible and an over-tight SLO
            degrades gracefully to "no slowdown allowed" instead of an
            infeasible sweep. With `slo_latency_s=None` the returned cap
            is bit-identical to the pre-SLO expression.
        """
        base = self.baseline.makespan
        cap = base * (1.0 + slowdown_frac)
        slo = self.cfg.slo_latency_s
        if slo is not None:
            cap = min(cap, max(float(slo), base))
        return cap

    # -- plan-construction helpers (vectorized) ---------------------------
    def top_gear_segments(self) -> list[list]:
        """One flat-out segment per task at its owner's top gear."""
        if self.is_homogeneous:
            top = self._uproc.gears[0]
            return [[(top, float(d))] for d in self.durations]
        procs = self.rank_procs
        return [[(procs[t.owner].gears[0], float(d))]
                for t, d in zip(self.graph.tasks, self.durations)]

    def reclaimed_segments(self, usable_slack: np.ndarray,
                           min_reclaim_s: np.ndarray | float,
                           tables=None,
                           table_ids: np.ndarray | None = None) -> list[list]:
        """Two-gear-split every task into its usable slack, batched.

        Tasks whose usable slack is below `min_reclaim_s` (scalar or
        per-task array) run flat-out at the top gear. With `tables` +
        `table_ids` (asymmetric per-task-type gear tables), every task --
        including the non-reclaimed ones -- is confined to its table, so a
        task type pinned below the processor's top gear runs slow even
        with zero slack (the big.LITTLE semantics). `tables` is either a
        sequence of gear tuples (one per table id) or, to support mixed
        machines whose ladders differ per rank, a callable
        `proc -> sequence of gear tuples` resolved per distinct processor.

        On a heterogeneous machine the batch runs once per distinct
        processor (`task_proc_groups`): each task splits within its owner's
        own ladder, with durations referenced to that owner's top gear.
        """
        d = self.durations
        reclaim = usable_slack >= min_reclaim_s
        gated = np.where(reclaim, usable_slack, 0.0)
        resolve = tables if callable(tables) else \
            (lambda proc: tables) if tables is not None else None
        if self.is_homogeneous:
            proc = self._uproc
            if resolve is not None:
                return two_gear_split_batch_by_table(proc, d, gated,
                                                     self.betas, table_ids,
                                                     resolve(proc))
            segs = two_gear_split_batch(proc, d, gated, self.betas)
            top = proc.gears[0]
            for i in np.flatnonzero(~reclaim):
                segs[i] = [(top, float(d[i]))]
            return segs
        betas = self.betas
        out: list[list] = [[] for _ in range(self.n_tasks)]
        for proc, sel in self.task_proc_groups:
            if resolve is not None:
                sub = two_gear_split_batch_by_table(
                    proc, d[sel], gated[sel], betas[sel], table_ids[sel],
                    resolve(proc))
            else:
                sub = two_gear_split_batch(proc, d[sel], gated[sel],
                                           betas[sel])
                top = proc.gears[0]
                for j in np.flatnonzero(~reclaim[sel]):
                    sub[j] = [(top, float(d[sel[j]]))]
            for j, i in enumerate(sel):
                out[i] = sub[j]
        return out


class ResidualPlanContext(PlanContext):
    """A `PlanContext` over the residual (not-yet-started) subgraph.

    Built by `PlanContext.restricted_to`; carries a `pending` mask and the
    `observed_finish` times of the frozen complement. `slack` and `tds`
    are overridden with the residual analyses
    (`critical_path.residual_schedule_slack`, `tds.analyze_residual_tds`)
    over the hybrid observed/predicted schedule; everything else --
    durations, per-rank machine structure, plan-construction helpers -- is
    inherited unchanged. With an all-true `pending` mask the overrides
    reproduce the parent context's `slack`/`tds` bit-identically.
    """

    pending: np.ndarray           # bool mask of plannable tasks
    observed_finish: np.ndarray   # realized finishes (frozen entries read)

    @functools.cached_property
    def hybrid_times(self) -> tuple[np.ndarray, np.ndarray]:
        """(start, finish) of the residual schedule: observed finishes for
        frozen tasks, top-gear predictions (at this context's durations)
        for pending ones."""
        return residual_schedule_times(
            self.graph, self.durations, self.cost.comm_cost(self.graph),
            frozen=~self.pending, observed_finish=self.observed_finish)

    @functools.cached_property
    def slack(self) -> np.ndarray:
        """Residual local slack (0.0 for frozen tasks)."""
        start, finish = self.hybrid_times
        return residual_schedule_slack(start, finish, self.graph,
                                       self.cost.comm_cost(self.graph),
                                       pending=self.pending)

    @functools.cached_property
    def tds(self) -> TdsResult:
        """Residual TDS analysis (neutral entries for frozen tasks)."""
        start, finish = self.hybrid_times
        return analyze_residual_tds(self.graph, start, finish,
                                    self.cost.comm_cost(self.graph),
                                    pending=self.pending, slack=self.slack)


@runtime_checkable
class Strategy(Protocol):
    """A named planner: consumes a shared PlanContext, emits a StrategyPlan."""

    name: str

    def plan(self, ctx: PlanContext) -> StrategyPlan:
        """Emit this strategy's StrategyPlan for the given context."""
        ...


_REGISTRY: dict[str, Strategy] = {}


def register_strategy(cls: type) -> type:
    """Class decorator: instantiate `cls` and register it under `cls.name`.

    Re-registering a name replaces the previous strategy (latest wins), so
    downstream code can override a built-in policy.
    """
    inst = cls()
    if not isinstance(inst, Strategy):
        raise TypeError(f"{cls!r} does not implement the Strategy protocol")
    _REGISTRY[inst.name] = inst
    return cls


def get_strategy(name: str) -> Strategy:
    """Look up a registered strategy by name (ValueError when unknown)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown strategy {name!r}; choose from "
                         f"{registered_strategies()}") from None


def registered_strategies() -> tuple[str, ...]:
    """All registered strategy names, in registration order."""
    return tuple(_REGISTRY)


@register_strategy
class OriginalStrategy:
    """Peak gear everywhere; the reference for savings/slowdown."""

    name = "original"

    def plan(self, ctx: PlanContext) -> StrategyPlan:
        """Top gear everywhere, idle at the top gear too."""
        idle, rank_idle = ctx._idle_gears(0)
        return StrategyPlan(self.name, ctx.top_gear_segments(),
                            idle_gear=idle,
                            per_task_overhead=np.zeros(ctx.n_tasks),
                            hide_switch_in_wait=True,
                            rank_idle_gears=rank_idle)


@register_strategy
class RaceToHaltStrategy:
    """Compute at peak, halt at the lowest gear while idle (reactive)."""

    name = "race_to_halt"

    def plan(self, ctx: PlanContext) -> StrategyPlan:
        """Top gear while computing, halt gear while idle."""
        idle, rank_idle = ctx._idle_gears(-1)
        return StrategyPlan(self.name, ctx.top_gear_segments(),
                            idle_gear=idle,
                            per_task_overhead=ctx.durations *
                            ctx.cfg.monitor_overhead,
                            hide_switch_in_wait=False,  # reactive wake-up
                            rank_idle_gears=rank_idle)


@register_strategy
class CpAwareStrategy:
    """Online CP-aware slack reclamation (Adagio-style)."""

    name = "cp_aware"

    def plan(self, ctx: PlanContext) -> StrategyPlan:
        """Stretch into measured slack, minus the guard band."""
        cfg = ctx.cfg
        segs = ctx.reclaimed_segments(ctx.slack * cfg.cp_aware_slack_use,
                                      cfg.min_reclaim_s)
        idle, rank_idle = ctx._idle_gears(-1)
        return StrategyPlan(self.name, segs, idle_gear=idle,
                            per_task_overhead=ctx.durations *
                            cfg.cp_detect_overhead,
                            hide_switch_in_wait=True,
                            rank_idle_gears=rank_idle)


@register_strategy
class AlgorithmicStrategy:
    """The paper: offline slack reclamation from the known DAG."""

    name = "algorithmic"

    def plan(self, ctx: PlanContext) -> StrategyPlan:
        """Stretch into the full offline-computed slack."""
        cfg = ctx.cfg
        segs = ctx.reclaimed_segments(ctx.slack * cfg.algorithmic_slack_use,
                                      cfg.min_reclaim_s)
        idle, rank_idle = ctx._idle_gears(-1)
        return StrategyPlan(self.name, segs, idle_gear=idle,
                            per_task_overhead=np.zeros(ctx.n_tasks),
                            hide_switch_in_wait=True,
                            rank_idle_gears=rank_idle)


# -- shared TX policy machinery (used by tx, tx_online, and tx_replan) ------

def tx_policy_segments(ctx: PlanContext) -> list[list]:
    """The TX per-wait-class reclamation policy as segment lists.

    Classifies every task's slack via `ctx.tds` (panel / communication /
    load imbalance), reclaims comm/imbalance slack down to
    `tx_min_reclaim_switches` of the *owning rank's* switch latency, stays
    conservative (`tx_panel_slack_use`) on panel-bound slack, and batches
    the two-gear splits per distinct processor. Shared verbatim by the
    `tx`, `tx_online`, and `tx_replan` strategies -- on a
    `ResidualPlanContext` the TDS arrays are the residual ones, so frozen
    tasks come back with don't-care top-gear segments the caller discards.

    Parameters
    ----------
    ctx : PlanContext
        Shared planning inputs; may be a `with_durations` estimate sibling
        or a `restricted_to` residual view.

    Returns
    -------
    list of list of (Gear, float)
        Per-task frequency segments, indexed by task id.
    """
    cfg = ctx.cfg
    tds = ctx.tds
    panel_bound = tds.slack_class == WAIT_PANEL
    usable = tds.slack_s * np.where(panel_bound,
                                    cfg.tx_panel_slack_use, 1.0)
    # reclaim floor in units of the *owning rank's* switch latency
    threshold = np.where(
        panel_bound, cfg.min_reclaim_s,
        cfg.tx_min_reclaim_switches * ctx.task_switch_latency_s)
    return ctx.reclaimed_segments(usable, threshold)


def draw_duration_noise(cfg: StrategyConfig, n_tasks: int) -> np.ndarray:
    """The seeded relative duration-estimate noise of the online planners.

    Validates and applies the `tx_online_rel_err` / `tx_online_seed`
    knobs; `tx_online` and `tx_replan` both draw through this helper so
    the two plan from the *identical* noisy estimates.

    Parameters
    ----------
    cfg : StrategyConfig
        Supplies `tx_online_rel_err` (must be in [0, 1)) and
        `tx_online_seed`.
    n_tasks : int
        Number of per-task noise factors to draw.

    Returns
    -------
    np.ndarray
        eps with d_est = d_true * (1 + eps), eps ~ U[-err, +err].
    """
    if not 0.0 <= cfg.tx_online_rel_err < 1.0:
        # err >= 1 could drive an estimated duration negative, breaking
        # the executes-true-work guarantee
        raise ValueError("tx_online_rel_err must be in [0, 1), got "
                         f"{cfg.tx_online_rel_err}")
    rng = np.random.default_rng(cfg.tx_online_seed)
    return rng.uniform(-cfg.tx_online_rel_err, cfg.tx_online_rel_err,
                       n_tasks)


def realize_on_true_work(segs: list[list], d_true: np.ndarray,
                         d_est: np.ndarray) -> list[list]:
    """Rescale estimate-derived segments so they perform the true work.

    Because d(f) is linear in a task's work, multiplying every segment
    time by d_true / d_est makes the chosen gears execute exactly the real
    task: a planner that underestimated overruns its window (and the
    simulator charges the induced delays), but the work is never wrong.

    Parameters
    ----------
    segs : list of list of (Gear, float)
        Per-task segments planned from the estimated durations.
    d_true, d_est : np.ndarray
        True and estimated top-gear durations, indexed by task id.

    Returns
    -------
    list of list of (Gear, float)
        The realized segments (input lists are reused when the ratio is
        exactly 1).
    """
    with np.errstate(divide="ignore", invalid="ignore"):
        ratio = np.where(d_est > 0.0, d_true / d_est, 1.0)
    return [[(g, t * r) for g, t in s] if r != 1.0 else s
            for s, r in zip(segs, ratio)]


@register_strategy
class TxStrategy:
    """Explicit TDS-driven plan: per-wait-class slack policy.

    The TDS classification (see `core/tds.py`) splits each task's
    reclaimable window by what bounds it:

      * imbalance / communication slack -- the bound is a hole in the
        rank's own schedule or a consumer that pays wire time anyway; TX
        stretches into it fully, and because the transfer schedule is
        statically known, it reclaims windows all the way down to a few
        switch latencies (`tx_min_reclaim_switches`) instead of the
        conservative global `min_reclaim_s` floor.
      * panel slack -- the bound is the next panel factorization, i.e. the
        iteration's critical path; TX reclaims only
        `tx_panel_slack_use` of it so a cost-model error cannot delay the
        panel, and pre-arms the up-switch (hide_switch_in_wait) so waking
        costs nothing.

    Waits themselves are handled as in the algorithmic plan: the rank is
    scheduled to the lowest gear during them (idle_gear), with switches
    hidden inside the wait -- the paper's scheduled-communication slowdown.
    """

    name = "tx"

    def plan(self, ctx: PlanContext) -> StrategyPlan:
        """Apply the per-wait-class TX policy (tx_policy_segments)."""
        segs = tx_policy_segments(ctx)
        idle, rank_idle = ctx._idle_gears(-1)
        return StrategyPlan(self.name, segs, idle_gear=idle,
                            per_task_overhead=np.zeros(ctx.n_tasks),
                            hide_switch_in_wait=True,
                            rank_idle_gears=rank_idle)


@register_strategy
class TaskTypeGearsStrategy:
    """Per-task-type gear policy on asymmetric gear tables (Costero et al.).

    Asymmetric architectures (and policy-restricted DVFS domains) give each
    task *type* its own operating-point table rather than one global
    ladder. This strategy reclaims slack exactly like the algorithmic plan
    but confines every task to its class's table
    (`StrategyConfig.kind_gear_depth`, resolved through
    `ProcessorModel.gear_prefix`):

      * panel tasks   -- fast gears only: they bound each iteration, so a
                         mispredicted stretch would serialize the whole
                         factorization; restricting the table makes the
                         plan robust by construction rather than by a
                         slack-fraction guard band.
      * solve tasks   -- the upper half of the ladder.
      * update tasks  -- the full ladder: abundant, off-critical-path
                         GEMM-like work is where deep downshifts pay.

    Segments come from `two_gear_split_batch_by_table`: one batched split
    per class table, exact scalar parity.
    """

    name = "task_type_gears"

    def plan(self, ctx: PlanContext) -> StrategyPlan:
        """Slack reclamation confined to per-class tables."""
        cfg = ctx.cfg

        # resolved per distinct processor: on a mixed machine each rank's
        # class tables are prefixes of its OWN ladder
        def tables_for(proc: ProcessorModel):
            return tuple(proc.gear_prefix(cfg.kind_gear_depth[name])
                         for name in GEAR_CLASS_NAMES)

        segs = ctx.reclaimed_segments(
            ctx.slack * cfg.algorithmic_slack_use, cfg.min_reclaim_s,
            tables=tables_for, table_ids=ctx.gear_classes)
        idle, rank_idle = ctx._idle_gears(-1)
        return StrategyPlan(self.name, segs, idle_gear=idle,
                            per_task_overhead=np.zeros(ctx.n_tasks),
                            hide_switch_in_wait=True,
                            rank_idle_gears=rank_idle)


@register_strategy
class SingleFreqOptStrategy:
    """Optimal single-frequency selection (Rizvandi et al.).

    The degenerate-but-strong baseline for any per-task policy: run *every*
    task at one uniform gear, chosen to minimize total energy subject to a
    makespan bound (`single_freq_slowdown_cap` over the context's
    baseline). The candidate durations for all gears are built in one
    vectorized (n_gears x n_tasks) expression -- no per-task Python loops --
    and each candidate plan is scored with the fast event-driven engine, so
    communication (which does not scale with frequency) and visible switch
    stalls are priced exactly rather than via the linear-scaling
    approximation. The top gear is always feasible (it reproduces the
    baseline makespan), so the sweep never comes back empty.

    Heterogeneous machines: uniform-gear becomes *per-rank* uniform under
    the shared makespan cap -- each rank runs all of its tasks at one gear
    of its OWN ladder. The sweep enumerates fractional ladder depths (the
    union of every distinct processor's gear positions); at depth d each
    rank uses the gear nearest d down its own table, so ladders of
    different lengths downshift together. Depth 0 is every rank's top
    gear and reproduces the baseline makespan, keeping the sweep
    non-empty.
    """

    name = "single_freq_opt"

    def plan(self, ctx: PlanContext) -> StrategyPlan:
        """Sweep uniform gears, keep the cheapest feasible."""
        cap = ctx.makespan_cap(ctx.cfg.single_freq_slowdown_cap)
        if ctx.is_homogeneous:
            proc = ctx._uproc
            freqs = np.asarray([g.freq_ghz for g in proc.gears])
            # durations of every task at every gear: (n_gears, n_tasks)
            durs = ctx.durations[None, :] * (
                ctx.betas[None, :] * proc.f_max / freqs[:, None]
                + (1.0 - ctx.betas[None, :]))
            candidates = [[[(gear, float(t))] for t in durs[gi]]
                          for gi, gear in enumerate(proc.gears)]
            idle, rank_idle = proc.gears[-1], None
        else:
            candidates = [self._depth_segments(ctx, depth)
                          for depth in self._depths(ctx)]
            idle, rank_idle = ctx._idle_gears(-1)
        cands = [StrategyPlan(self.name, segs, idle_gear=idle,
                              per_task_overhead=np.zeros(ctx.n_tasks),
                              hide_switch_in_wait=True,
                              rank_idle_gears=rank_idle)
                 for segs in candidates]
        # one batched pass scores every candidate; the fleet engine is
        # timeline-exact vs the serial engines, so feasibility and the
        # energy argmin are unchanged (first-feasible-minimum wins ties,
        # matching the old serial sweep)
        fleet = simulate_fleet(ctx.graph, ctx.proc, ctx.cost, cands)
        energies = fleet.total_energy_j()
        makespans = fleet.makespan
        best: tuple[float, StrategyPlan] | None = None
        for i, cand in enumerate(cands):
            if makespans[i] <= cap + 1e-12 and \
                    (best is None or energies[i] < best[0]):
                best = (float(energies[i]), cand)
        assert best is not None    # the top gear / depth 0 meets the bound
        return best[1]

    @staticmethod
    def _depths(ctx: PlanContext) -> list[float]:
        """Union of fractional ladder positions over distinct processors."""
        depths = {0.0}
        for p in ctx.machine.distinct_procs(ctx.graph.n_ranks):
            if len(p.gears) > 1:
                depths.update(i / (len(p.gears) - 1)
                              for i in range(len(p.gears)))
        return sorted(depths)

    @staticmethod
    def _depth_segments(ctx: PlanContext, depth: float) -> list[list]:
        """One-gear-per-task segments at fractional ladder depth `depth`,
        each task on its owner's gear nearest that depth."""
        procs = ctx.rank_procs
        segs = []
        for t, d, b in zip(ctx.graph.tasks, ctx.durations, ctx.betas):
            p = procs[t.owner]
            gear = p.gears[int(round(depth * (len(p.gears) - 1)))]
            segs.append([(gear, duration_at(float(d), p.f_max,
                                            gear.freq_ghz, float(b)))])
        return segs


@register_strategy
class TxOnlineStrategy:
    """TX planned from noise-perturbed duration estimates (online variant).

    Quantifies how much of TX's savings survive an imperfect cost model:
    the planner sees durations d * (1 + eps), eps ~ U[-rel_err, +rel_err]
    (seeded, deterministic), computes the baseline schedule / slack / TDS
    *from those estimates*, and commits to gears and work fractions. The
    emitted plan then realizes those decisions on the TRUE work: each
    task's segment times are the estimate-derived split rescaled by
    d_true / d_est, which -- because d(f) is linear in the task's work --
    is exactly the time the chosen gears take on the real task. A task
    whose duration was underestimated therefore overruns its window and
    pushes its consumers, and the simulator charges that delay; with
    rel_err = 0 the plan is bit-identical to `tx`.
    """

    name = "tx_online"

    def plan(self, ctx: PlanContext) -> StrategyPlan:
        """Plan TX on noisy estimates, realize the true work."""
        d_true = ctx.durations
        eps = draw_duration_noise(ctx.cfg, ctx.n_tasks)
        d_est = d_true * (1.0 + eps)
        est = ctx.with_durations(d_est)
        segs = realize_on_true_work(tx_policy_segments(est), d_true, d_est)
        idle, rank_idle = ctx._idle_gears(-1)
        return StrategyPlan(self.name, segs, idle_gear=idle,
                            per_task_overhead=np.zeros(ctx.n_tasks),
                            hide_switch_in_wait=True,
                            rank_idle_gears=rank_idle)


# -- migration machinery (tx_migrate; reused by the migrating tx_replan) ----

def migration_mappings(ctx: PlanContext,
                       movable: "np.ndarray | None" = None,
                       max_moves: int | None = None) -> list[list[int]]:
    """Candidate task->rank remappings: update work moved off LITTLE ranks.

    The Costero-style migration heuristic. Big ranks are those whose
    processor reaches the machine's highest top frequency; movable tasks
    are frequency-sensitive (`beta > 0`, so gear-invariant pacing tasks
    such as serving CLOCK ticks never move) update-class tasks owned by
    slower ranks. The mover sorts movable tasks by descending top-gear
    duration and greedily assigns each to the currently least-loaded big
    rank (loads seeded with the work already mapped there; a moved task
    contributes its duration rescaled to the big rank's frequency). The
    returned candidates are doubling prefixes of that move list -- moving
    the 1, 2, 4, ... heaviest tasks -- so a single batched fleet pass can
    score every migration depth and pick the cheapest feasible one.

    Parameters
    ----------
    ctx : PlanContext
        Shared planning inputs on the TRUE machine.
    movable : np.ndarray, optional
        Boolean mask further restricting which tasks may move (the
        migrating re-planner passes its pending mask; frozen tasks stay
        put). Default: every task is eligible.
    max_moves : int, optional
        Cap on the move-list length (default
        `ctx.cfg.tx_migrate_max_moves`).

    Returns
    -------
    list of list of int
        Full-length owner vectors, one per candidate mapping, ordered by
        increasing migration depth. Empty on homogeneous machines or when
        nothing is eligible to move.
    """
    if max_moves is None:
        max_moves = ctx.cfg.tx_migrate_max_moves
    procs = ctx.rank_procs
    f = np.asarray([p.f_max for p in procs])
    f_big = float(f.max())
    little = f < f_big
    if not little.any() or max_moves < 1:
        return []
    owner0 = [t.owner for t in ctx.graph.tasks]
    d, betas, classes = ctx.durations, ctx.betas, ctx.gear_classes
    movable_ids = [t.tid for t in ctx.graph.tasks
                   if little[t.owner]
                   and classes[t.tid] == GEAR_CLASS_UPDATE
                   and betas[t.tid] > 0.0
                   and (movable is None or movable[t.tid])]
    if not movable_ids:
        return []
    movable_ids.sort(key=lambda tid: (-d[tid], tid))
    # greedy least-loaded assignment over the big ranks
    load = {r: 0.0 for r in np.flatnonzero(~little)}
    for t in ctx.graph.tasks:
        if t.owner in load:
            load[t.owner] += float(d[t.tid])
    moves: list[tuple[int, int]] = []
    for tid in movable_ids[:max_moves]:
        r = min(load, key=lambda k: (load[k], k))
        b = float(betas[tid])
        d_big = float(d[tid]) * (b * f[owner0[tid]] / f_big + (1.0 - b))
        load[r] += d_big
        moves.append((tid, int(r)))
    mappings: list[list[int]] = []
    k = 1
    while True:
        owners = list(owner0)
        for tid, r in moves[:k]:
            owners[tid] = r
        mappings.append(owners)
        if k >= len(moves):
            return mappings
        k = min(2 * k, len(moves))


def migration_plans(ctx: PlanContext, name: str,
                    mappings: "Sequence[Sequence[int]]") -> list[StrategyPlan]:
    """TX plans realizing each candidate mapping, ready for fleet scoring.

    Each mapping is planned through `tx_policy_segments` on a
    `with_owners` sibling context -- so slack/TDS, gear ladders, and
    durations are all referenced to the candidate's new owners -- and the
    emitted plan carries `task_owners` so the engines execute that
    mapping on the original graph.
    """
    plans = []
    idle, rank_idle = ctx._idle_gears(-1)
    for owners in mappings:
        sub = ctx.with_owners(owners)
        plans.append(StrategyPlan(
            name, tx_policy_segments(sub), idle_gear=idle,
            per_task_overhead=np.zeros(ctx.n_tasks),
            hide_switch_in_wait=True, rank_idle_gears=rank_idle,
            task_owners=list(owners)))
    return plans


@register_strategy
class TxMigrateStrategy:
    """TX plus task migration on heterogeneous machines (Costero et al.).

    Re-gearing alone leaves energy on the table when the mapping itself is
    wrong: a LITTLE rank stuck with heavy trailing updates binds the
    schedule no matter what gears it runs. This strategy keeps the frozen
    `tx` plan as its baseline candidate and additionally scores TX plans
    for each `migration_mappings` candidate -- the 1, 2, 4, ... heaviest
    movable update tasks pulled onto the least-loaded big ranks -- in ONE
    batched fleet pass on the true machine (cross-rank transfer times and
    link energies priced by the `CostModel`'s `LinkModel`). The cheapest
    candidate within `tx_migrate_slowdown_cap` of the baseline makespan
    wins; the frozen plan wins ties, so tx_migrate never loses to `tx`.
    On a homogeneous machine there is nothing to migrate and the plan is
    the frozen `tx` plan (renamed), bit-identically.
    """

    name = "tx_migrate"

    def plan(self, ctx: PlanContext) -> StrategyPlan:
        """Score frozen-mapping tx against candidate migrations, keep the
        cheapest feasible."""
        frozen = dataclasses.replace(get_strategy("tx").plan(ctx),
                                     name=self.name)
        if ctx.is_homogeneous:
            return frozen
        mappings = migration_mappings(ctx)
        if not mappings:
            return frozen
        cands = [frozen] + migration_plans(ctx, self.name, mappings)
        fleet = simulate_fleet(ctx.graph, ctx.proc, ctx.cost, cands)
        energies, makespans = fleet.total_energy_j(), fleet.makespan
        cap = ctx.makespan_cap(ctx.cfg.tx_migrate_slowdown_cap)
        best = 0
        for i in range(1, len(cands)):
            # strict <: the frozen-mapping plan (lane 0) wins ties
            if makespans[i] <= cap + 1e-12 and energies[i] < energies[best]:
                best = i
        return cands[best]


def make_plan(name: str, graph: TaskGraph,
              proc: ProcessorModel | MachineModel, cost: CostModel,
              cfg: StrategyConfig | None = None) -> StrategyPlan:
    """Plan a single strategy (one-shot convenience around the registry).

    Evaluating several strategies on one graph? Build one `PlanContext`
    and call each strategy's `.plan(ctx)` -- or use `evaluate_strategies`
    -- so the baseline schedule/slack/TDS are computed once, not per call.

    Parameters
    ----------
    name : str
        A registered strategy name (`registered_strategies()` lists them).
    graph : TaskGraph
        The factorization DAG to plan.
    proc : ProcessorModel or MachineModel
        Power/gear model; a `MachineModel` assigns one per rank.
    cost : CostModel
        Task/communication cost model.
    cfg : StrategyConfig, optional
        Policy knobs (defaults when omitted).

    Returns
    -------
    StrategyPlan
        The strategy's plan, consumable by either engine.
    """
    return get_strategy(name).plan(PlanContext(graph, proc, cost, cfg))


@dataclasses.dataclass
class StrategyResult:
    """One strategy's simulated outcome plus percentages vs `original`.

    The scalar fields come straight from the batched fleet pass
    `evaluate_strategies` runs; the full per-rank `Schedule` is
    materialized lazily through the `schedule` property (one fast-engine
    call, exact by the differential contract), so sweeps that only read
    energies never pay for per-strategy segment timelines.
    """

    name: str
    makespan_s: float
    energy_j: float
    avg_power_w: float
    slowdown_pct: float        # vs original
    energy_saved_pct: float    # vs original
    switch_count: int
    _schedule: "Schedule | None" = dataclasses.field(
        default=None, repr=False)
    _schedule_factory: "object | None" = dataclasses.field(
        default=None, repr=False)

    @property
    def schedule(self) -> Schedule:
        """The strategy's full `Schedule`, simulated on first access."""
        if self._schedule is None:
            self._schedule = self._schedule_factory()
        return self._schedule


def evaluate_strategies(graph: TaskGraph,
                        proc: ProcessorModel | MachineModel,
                        cost: CostModel,
                        names: tuple[str, ...] = STRATEGIES,
                        cfg: StrategyConfig | None = None,
                        ) -> dict[str, StrategyResult]:
    """Simulate each named strategy; percentages are always vs `original`.

    The reference is the context's baseline schedule (identical to the
    `original` strategy's), simulated regardless of whether -- or where --
    "original" appears in `names`.

    Parameters
    ----------
    graph : TaskGraph
        The factorization DAG to plan and simulate.
    proc : ProcessorModel or MachineModel
        Power/gear model; a `MachineModel` assigns one per rank.
    cost : CostModel
        Task/communication cost model.
    names : tuple of str
        Registered strategy names to evaluate (default: the paper's four).
    cfg : StrategyConfig, optional
        Policy knobs shared by every strategy (defaults when omitted).

    Returns
    -------
    dict of str to StrategyResult
        Per-strategy makespan/energy/switches plus slowdown and savings
        percentages vs `original`, keyed by strategy name. Each result's
        `.schedule` is materialized lazily (one fast-engine call on first
        access); the scalar fields come from one batched `simulate_fleet`
        pass over all named strategies -- makespans and switch counts
        bit-identical to the old serial sweep, energies within the
        documented 1e-9 relative cross-engine tolerance.
    """
    ctx = PlanContext(graph, proc, cost, cfg)
    ref = ctx.baseline
    ref_time, ref_energy = ref.makespan, ref.total_energy_j()
    planned = [nm for nm in names if nm != "original"]
    plans = [get_strategy(nm).plan(ctx) for nm in planned]
    fleet = simulate_fleet(graph, proc, cost, plans)
    energies, makespans = fleet.total_energy_j(), fleet.makespan
    lane = {nm: i for i, nm in enumerate(planned)}
    results: dict[str, StrategyResult] = {}
    for name in names:
        if name == "original":
            t, e, sw = ref_time, ref_energy, ref.switch_count
            sched, factory = ref, None
        else:
            i = lane[name]
            t, e = float(makespans[i]), float(energies[i])
            sw = int(fleet.switch_count[i])
            sched, factory = None, functools.partial(simulate, graph, proc,
                                                     cost, plans[i])
        results[name] = StrategyResult(
            name=name, makespan_s=t, energy_j=e,
            avg_power_w=e / t if t else 0.0,
            slowdown_pct=100.0 * (t / ref_time - 1.0) if ref_time else 0.0,
            energy_saved_pct=100.0 * (1.0 - e / ref_energy)
            if ref_energy else 0.0,
            switch_count=sw,
            _schedule=sched, _schedule_factory=factory)
    return results
