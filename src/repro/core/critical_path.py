"""Critical-path and slack analysis over a scheduled task graph.

Two related computations:

1. `cp_analysis(graph, durations, comm)` -- classic earliest/latest times
   over the DAG alone (infinite processors): gives the critical-path length
   (a lower bound on any schedule's makespan) and *structural* slack.

2. `schedule_slack(schedule, graph)` -- *realized* local slack of each task
   in a concrete simulated schedule: the gap between a task's finish and the
   earliest start among everything that waits on it (DAG successors AND the
   next task in the same rank's program order, AND end-of-schedule for
   terminal tasks). Stretching a task into its local slack provably delays
   no other task's start -- this is the quantity both CP-aware reclamation
   (measured online, Adagio-style) and the paper's algorithmic schedule
   (computed offline from this very analysis) reclaim.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .dag import TaskGraph


@dataclasses.dataclass
class CpResult:
    earliest_start: np.ndarray
    earliest_finish: np.ndarray
    latest_start: np.ndarray
    latest_finish: np.ndarray
    cp_length: float
    on_cp: np.ndarray          # bool: zero total float
    total_float: np.ndarray


def _edge_delay(graph: TaskGraph, producer: int, consumer: int,
                comm_time: float) -> float:
    if graph.tasks[producer].owner == graph.tasks[consumer].owner:
        return 0.0
    return comm_time


def cp_analysis(graph: TaskGraph, durations: np.ndarray,
                comm_time: float = 0.0) -> CpResult:
    n = len(graph.tasks)
    es = np.zeros(n)
    # forward pass (tasks are emitted in topological order by construction)
    for t in graph.tasks:
        if t.deps:
            es[t.tid] = max(
                es[d] + durations[d] + _edge_delay(graph, d, t.tid, comm_time)
                for d in t.deps
            )
    ef = es + durations
    cp_len = float(ef.max()) if n else 0.0
    lf = np.full(n, cp_len)
    for t in reversed(graph.tasks):     # backward pass
        for d in t.deps:
            lf[d] = min(lf[d], lf[t.tid] - durations[t.tid]
                        - _edge_delay(graph, d, t.tid, comm_time))
    ls = lf - durations
    tf = ls - es
    return CpResult(es, ef, ls, lf, cp_len, tf <= 1e-12, tf)


def schedule_slack(start: np.ndarray, finish: np.ndarray,
                   graph: TaskGraph, comm_time: float = 0.0) -> np.ndarray:
    """Realized local slack per task in a simulated schedule."""
    n = len(graph.tasks)
    makespan = float(finish.max()) if n else 0.0
    slack = np.full(n, np.inf)
    # DAG successors: producer must deliver by successor's start
    for t in graph.tasks:
        for d in t.deps:
            avail = start[t.tid] - _edge_delay(graph, d, t.tid, comm_time)
            slack[d] = min(slack[d], avail - finish[d])
    # same-rank program order: finishing later would push the next local task
    for rank_tasks in graph.tasks_by_rank():
        for a, b in zip(rank_tasks[:-1], rank_tasks[1:]):
            slack[a] = min(slack[a], start[b] - finish[a])
    # terminal tasks may stretch to the makespan
    slack[np.isinf(slack)] = makespan - finish[np.isinf(slack)]
    return np.maximum(slack, 0.0)
