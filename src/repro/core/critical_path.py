"""Critical-path and slack analysis over a scheduled task graph.

Two related computations:

1. `cp_analysis(graph, durations, comm)` -- classic earliest/latest times
   over the DAG alone (infinite processors): gives the critical-path length
   (a lower bound on any schedule's makespan) and *structural* slack.

2. `schedule_slack(schedule, graph)` -- *realized* local slack of each task
   in a concrete simulated schedule: the gap between a task's finish and the
   earliest start among everything that waits on it (DAG successors AND the
   next task in the same rank's program order, AND end-of-schedule for
   terminal tasks). Stretching a task into its local slack provably delays
   no other task's start -- this is the quantity both CP-aware reclamation
   (measured online, Adagio-style) and the paper's algorithmic schedule
   (computed offline from this very analysis) reclaim.

Both are fully vectorized over the graph's cached NumPy edge arrays
(`TaskGraph.dep_edge_arrays` / `dep_edges_by_level` / `rank_order_pairs`):
`schedule_slack` is a single scatter-min over all edges, and `cp_analysis`
sweeps the DAG level-by-level (consumers sit strictly above producers, so a
per-level scatter-max/min is a valid topological pass). min/max are exact in
floating point, so the results are bit-identical to an edge-at-a-time loop.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .dag import TaskGraph


@dataclasses.dataclass
class CpResult:
    earliest_start: np.ndarray
    earliest_finish: np.ndarray
    latest_start: np.ndarray
    latest_finish: np.ndarray
    cp_length: float
    on_cp: np.ndarray          # bool: zero total float
    total_float: np.ndarray


def cp_analysis(graph: TaskGraph, durations: np.ndarray,
                comm_time: float = 0.0) -> CpResult:
    n = len(graph.tasks)
    durations = np.asarray(durations, dtype=float)
    src, dst, cross, bounds = graph.dep_edges_by_level()
    delay = np.where(cross, comm_time, 0.0)
    n_levels = len(bounds) - 1

    # forward pass: earliest starts, one scatter-max per DAG level
    es = np.zeros(n)
    for lv in range(1, n_levels):
        lo, hi = bounds[lv], bounds[lv + 1]
        if lo == hi:
            continue
        s, d = src[lo:hi], dst[lo:hi]
        np.maximum.at(es, d, es[s] + durations[s] + delay[lo:hi])
    ef = es + durations
    cp_len = float(ef.max()) if n else 0.0

    # backward pass: latest finishes, highest consumer level first
    lf = np.full(n, cp_len)
    for lv in range(n_levels - 1, 0, -1):
        lo, hi = bounds[lv], bounds[lv + 1]
        if lo == hi:
            continue
        s, d = src[lo:hi], dst[lo:hi]
        np.minimum.at(lf, s, lf[d] - durations[d] - delay[lo:hi])
    ls = lf - durations
    tf = ls - es
    return CpResult(es, ef, ls, lf, cp_len, tf <= 1e-12, tf)


def schedule_slack(start: np.ndarray, finish: np.ndarray,
                   graph: TaskGraph, comm_time: float = 0.0) -> np.ndarray:
    """Realized local slack per task in a simulated schedule."""
    n = len(graph.tasks)
    makespan = float(finish.max()) if n else 0.0
    slack = np.full(n, np.inf)
    # DAG successors: producer must deliver by successor's start
    src, dst, cross = graph.dep_edge_arrays()
    if len(src):
        avail = start[dst] - np.where(cross, comm_time, 0.0)
        np.minimum.at(slack, src, avail - finish[src])
    # same-rank program order: finishing later would push the next local task
    prev, nxt = graph.rank_order_pairs()
    if len(prev):
        np.minimum.at(slack, prev, start[nxt] - finish[prev])
    # terminal tasks may stretch to the makespan
    term = np.isinf(slack)
    slack[term] = makespan - finish[term]
    return np.maximum(slack, 0.0)
