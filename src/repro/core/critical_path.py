"""Critical-path and slack analysis over a scheduled task graph.

Three related computations:

1. `cp_analysis(graph, durations, comm)` -- classic earliest/latest times
   over the DAG alone (infinite processors): gives the critical-path length
   (a lower bound on any schedule's makespan) and *structural* slack.

2. `schedule_slack(schedule, graph)` -- *realized* local slack of each task
   in a concrete simulated schedule: the gap between a task's finish and the
   earliest start among everything that waits on it (DAG successors AND the
   next task in the same rank's program order, AND end-of-schedule for
   terminal tasks). Stretching a task into its local slack provably delays
   no other task's start -- this is the quantity both CP-aware reclamation
   (measured online, Adagio-style) and the paper's algorithmic schedule
   (computed offline from this very analysis) reclaim.

3. Residual-graph entry points (`residual_schedule_times`,
   `residual_schedule_slack`) -- the closed-loop re-planning substrate
   (`core/replan.py`): mid-run, with some tasks already executed, predict
   the top-gear times of everything still pending *anchored on the
   observed finish times of the frozen past*, then restrict the slack
   analysis to the pending (residual) subgraph. With nothing frozen they
   reproduce the full baseline bit-identically.

Both full-graph passes are fully vectorized over the graph's cached NumPy
edge arrays (`TaskGraph.dep_edge_arrays` / `dep_edges_by_level` /
`rank_order_pairs`): `schedule_slack` is a single scatter-min over all
edges, and `cp_analysis` sweeps the DAG level-by-level (consumers sit
strictly above producers, so a per-level scatter-max/min is a valid
topological pass). min/max are exact in floating point, so the results are
bit-identical to an edge-at-a-time loop.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .dag import TaskGraph


def _edge_delays(graph: TaskGraph, src: np.ndarray, dst: np.ndarray,
                 cross: np.ndarray, comm_time) -> np.ndarray:
    """Per-edge transfer delays for either comm-pricing form.

    A scalar `comm_time` (the legacy uniform model) is broadcast over
    cross-rank edges exactly as before -- bit-identical. An (R, R) matrix
    (`CostModel.comm_cost` under a non-trivial `LinkModel`) is gathered
    per edge by owner pair; its zero diagonal makes the cross mask
    redundant.
    """
    if np.ndim(comm_time) == 0:
        return np.where(cross, comm_time, 0.0)
    owner = np.asarray([t.owner for t in graph.tasks], dtype=np.int64)
    return np.asarray(comm_time)[owner[src], owner[dst]]


@dataclasses.dataclass
class CpResult:
    """Earliest/latest times and float of every task over the bare DAG."""

    earliest_start: np.ndarray
    earliest_finish: np.ndarray
    latest_start: np.ndarray
    latest_finish: np.ndarray
    cp_length: float
    on_cp: np.ndarray          # bool: zero total float
    total_float: np.ndarray


def cp_analysis(graph: TaskGraph, durations: np.ndarray,
                comm_time: float = 0.0) -> CpResult:
    """Classic forward/backward critical-path pass over the DAG alone.

    Parameters
    ----------
    graph : TaskGraph
        The task DAG (only its data edges are used -- no rank contention).
    durations : np.ndarray
        Per-task durations, indexed by task id.
    comm_time : float or np.ndarray
        Transfer delay charged on cross-rank dependency edges: a uniform
        scalar, or the (R, R) per-rank-pair matrix from
        `CostModel.comm_cost`.

    Returns
    -------
    CpResult
        Earliest/latest start and finish arrays, the critical-path length
        (a lower bound on any schedule's makespan), and per-task total
        float with the zero-float (on-critical-path) mask.
    """
    n = len(graph.tasks)
    durations = np.asarray(durations, dtype=float)
    src, dst, cross, bounds = graph.dep_edges_by_level()
    delay = _edge_delays(graph, src, dst, cross, comm_time)
    n_levels = len(bounds) - 1

    # forward pass: earliest starts, one scatter-max per DAG level
    es = np.zeros(n)
    for lv in range(1, n_levels):
        lo, hi = bounds[lv], bounds[lv + 1]
        if lo == hi:
            continue
        s, d = src[lo:hi], dst[lo:hi]
        np.maximum.at(es, d, es[s] + durations[s] + delay[lo:hi])
    ef = es + durations
    cp_len = float(ef.max()) if n else 0.0

    # backward pass: latest finishes, highest consumer level first
    lf = np.full(n, cp_len)
    for lv in range(n_levels - 1, 0, -1):
        lo, hi = bounds[lv], bounds[lv + 1]
        if lo == hi:
            continue
        s, d = src[lo:hi], dst[lo:hi]
        np.minimum.at(lf, s, lf[d] - durations[d] - delay[lo:hi])
    ls = lf - durations
    tf = ls - es
    return CpResult(es, ef, ls, lf, cp_len, tf <= 1e-12, tf)


def schedule_slack(start: np.ndarray, finish: np.ndarray,
                   graph: TaskGraph, comm_time: float = 0.0) -> np.ndarray:
    """Realized local slack per task in a simulated schedule.

    Parameters
    ----------
    start, finish : np.ndarray
        Per-task times of a concrete schedule, indexed by task id.
    graph : TaskGraph
        The scheduled task graph (data edges + per-rank program order).
    comm_time : float or np.ndarray
        Transfer delay on cross-rank dependency edges: a uniform scalar
        or the (R, R) per-rank-pair matrix from `CostModel.comm_cost`.

    Returns
    -------
    np.ndarray
        Per-task reclaimable window: the gap between the task's finish and
        the earliest moment anything (a DAG consumer, the next task in its
        rank's program order, or the end of the schedule) needs it.
        Stretching a task within its local slack delays no other task.
    """
    n = len(graph.tasks)
    makespan = float(finish.max()) if n else 0.0
    slack = np.full(n, np.inf)
    # DAG successors: producer must deliver by successor's start
    src, dst, cross = graph.dep_edge_arrays()
    if len(src):
        avail = start[dst] - _edge_delays(graph, src, dst, cross, comm_time)
        np.minimum.at(slack, src, avail - finish[src])
    # same-rank program order: finishing later would push the next local task
    prev, nxt = graph.rank_order_pairs()
    if len(prev):
        np.minimum.at(slack, prev, start[nxt] - finish[prev])
    # terminal tasks may stretch to the makespan
    term = np.isinf(slack)
    slack[term] = makespan - finish[term]
    return np.maximum(slack, 0.0)


# ---------------------------------------------------------------------------
# Residual-graph entry points (closed-loop re-planning, core/replan.py).
# ---------------------------------------------------------------------------

def validate_frozen_closure(graph: TaskGraph, frozen: np.ndarray) -> None:
    """Check that `frozen` is a valid executed prefix of the schedule.

    A frozen (already-executed) set is only meaningful when it is closed
    under everything that determines its members' timing: every frozen
    task's dependencies must be frozen, and on each rank the frozen tasks
    must form a prefix of the rank's program order (a rank cannot have run
    its 3rd task without its 2nd). Iteration-prefix waves -- the shape
    `core/replan.py` produces -- satisfy both by construction.

    Parameters
    ----------
    graph : TaskGraph
        The task graph the mask indexes into.
    frozen : np.ndarray
        Boolean mask of executed tasks, indexed by task id.

    Returns
    -------
    None
        Raises ``ValueError`` on the first violated closure property.
    """
    src, dst, _ = graph.dep_edge_arrays()
    if len(src):
        bad = frozen[dst] & ~frozen[src]
        if bad.any():
            e = int(np.flatnonzero(bad)[0])
            raise ValueError(
                f"frozen set is not dependency-closed: task {int(dst[e])} "
                f"is frozen but its dependency {int(src[e])} is not")
    prev, nxt = graph.rank_order_pairs()
    if len(prev):
        bad = frozen[nxt] & ~frozen[prev]
        if bad.any():
            e = int(np.flatnonzero(bad)[0])
            raise ValueError(
                f"frozen set is not a per-rank prefix: task {int(nxt[e])} "
                f"is frozen but its program-order predecessor "
                f"{int(prev[e])} is not")


def residual_schedule_times(graph: TaskGraph, durations: np.ndarray,
                            comm_time: float = 0.0,
                            frozen: np.ndarray | None = None,
                            observed_finish: np.ndarray | None = None,
                            ) -> tuple[np.ndarray, np.ndarray]:
    """Predicted times of the residual schedule, anchored on observations.

    The mid-run re-planning primitive: tasks in `frozen` have already
    executed and their *realized* finish times are facts
    (`observed_finish`); everything still pending is predicted forward at
    the given (estimated top-gear) durations under the same semantics as
    the baseline schedule -- each rank runs its pending tasks in program
    order, starting each when the rank is free and every dependency's
    output (observed for frozen producers, predicted for pending ones) has
    arrived. With an empty frozen set this reproduces the zero-overhead
    top-gear baseline's times bit-identically.

    Parameters
    ----------
    graph : TaskGraph
        The full task graph (the residual subgraph is selected by mask).
    durations : np.ndarray
        Per-task top-gear durations; only pending entries are read.
    comm_time : float or np.ndarray
        Transfer delay on cross-rank dependency edges: a uniform scalar
        or the (R, R) per-rank-pair matrix from `CostModel.comm_cost`.
    frozen : np.ndarray, optional
        Boolean mask of already-executed tasks (default: none). Must be
        dependency-closed and a per-rank program-order prefix
        (`validate_frozen_closure`).
    observed_finish : np.ndarray, optional
        Realized finish times; only frozen entries are read. Required when
        `frozen` selects any task.

    Returns
    -------
    (start, finish) : tuple of np.ndarray
        Hybrid per-task times: observed values for frozen tasks (their
        `start` is set to the observed finish and is *undefined* -- no
        residual quantity may depend on it), predictions for pending ones.
    """
    n = len(graph.tasks)
    durations = np.asarray(durations, dtype=float)
    if frozen is None:
        frozen = np.zeros(n, dtype=bool)
    else:
        frozen = np.asarray(frozen, dtype=bool)
        if frozen.shape != (n,):
            raise ValueError("frozen mask must have one entry per task")
    if frozen.any():
        if observed_finish is None:
            raise ValueError("observed_finish is required when any task "
                             "is frozen")
        validate_frozen_closure(graph, frozen)
    start = np.zeros(n)
    finish = np.zeros(n)
    if frozen.any():
        obs = np.asarray(observed_finish, dtype=float)
        finish[frozen] = obs[frozen]
        start[frozen] = obs[frozen]      # undefined; see docstring
    # forward pass in tid order (tids are topological and per-rank program
    # order is tid order), same max() formula as the simulator engines --
    # bit-identical to the baseline schedule when nothing is frozen
    cm = None if np.ndim(comm_time) == 0 \
        else np.asarray(comm_time).tolist()
    rank_free = [0.0] * graph.n_ranks
    for t in graph.tasks:
        if frozen[t.tid]:
            rank_free[t.owner] = max(rank_free[t.owner],
                                     float(finish[t.tid]))
            continue
        ready = rank_free[t.owner]
        for d in t.deps:
            o = graph.tasks[d].owner
            arr = finish[d] + ((comm_time if o != t.owner else 0.0)
                               if cm is None else cm[o][t.owner])
            if arr > ready:
                ready = arr
        start[t.tid] = ready
        fin = ready + durations[t.tid]
        finish[t.tid] = fin
        rank_free[t.owner] = fin
    return start, finish


def residual_schedule_slack(start: np.ndarray, finish: np.ndarray,
                            graph: TaskGraph, comm_time: float = 0.0,
                            pending: np.ndarray | None = None) -> np.ndarray:
    """`schedule_slack` restricted to the pending (residual) subgraph.

    Parameters
    ----------
    start, finish : np.ndarray
        Hybrid per-task times (see `residual_schedule_times`).
    graph : TaskGraph
        The full task graph.
    comm_time : float or np.ndarray
        Transfer delay on cross-rank dependency edges (scalar or matrix,
        as for `schedule_slack`).
    pending : np.ndarray, optional
        Boolean mask of not-yet-started tasks (default: all). Frozen
        tasks' history cannot be re-planned, so their entries are zeroed.

    Returns
    -------
    np.ndarray
        Per-task reclaimable slack; exactly `schedule_slack` for pending
        tasks (frozen producers bound them through their observed
        finishes), 0.0 for frozen ones.
    """
    slack = schedule_slack(start, finish, graph, comm_time)
    if pending is not None:
        slack = np.where(np.asarray(pending, dtype=bool), slack, 0.0)
    return slack
