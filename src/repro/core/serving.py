"""Request-level LM serving simulator on the energy-planning stack.

Maps a continuous-batching serving cluster onto the factorization
machinery so every registered strategy -- and the batched fleet engine --
can plan and score it unchanged (ROADMAP open item 1):

  * **Traffic** -- `make_trace` draws deterministic seeded Poisson
    arrivals modulated by a traffic shape (`TRAFFIC_SHAPES`): a
    sinusoidal diurnal day-curve, a square-wave bursty profile, or a
    flat baseline. All shapes are mean-normalized to the same offered
    request rate, so comparisons across shapes hold load constant.
  * **Waves** -- `build_serving_graph` compiles the trace into a
    `TaskGraph` under a fixed continuous-batching cadence
    (`step_period_s`): each wave admits newly arrived requests
    round-robin to server ranks, runs one `PREFILL` task per admission
    (compute-bound: `PANEL_KINDS` / panel gear class), and one fused
    `DECODE` task per busy server (memory-bound: update gear class,
    low `freq_sensitivity` beta after Calore et al.).
  * **Wall clock** -- `TaskGraph` has no release times, so a dedicated
    *clock rank* carries a chain of `CLOCK` tasks, one per wave, each
    lasting exactly one period; wave-w server tasks depend on the w-th
    clock task. The serving cost model pins `CLOCK`'s beta at 0.0
    (frequency-invariant duration -- `dvfs.two_gear_split` then always
    returns the unstretched duration), and `make_clock_proc` draws no
    power, so no strategy can perturb or be charged for the wall clock.
  * **Scoring** -- one `simulate_fleet` pass per traffic cell evaluates
    every strategy's plan as a lane; `request_latencies` reads
    per-request completion times straight out of the lane finish
    arrays, and `p99_latency_s` / `slo_violation_rate` summarize them
    against the SLO. The same SLO enters planning as
    `StrategyConfig.slo_latency_s` through `PlanContext.makespan_cap`.

`benchmarks/serving_energy.py` builds the J/token + p99 bench section on
top of this module; `examples/serving_energy_demo.py` is the runnable
tour.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from .dag import Task, TaskGraph
from .energy_model import (Gear, MachineModel, ProcessorModel, as_machine,
                           make_processor, scale_processor)
from .scheduler import CostModel

# Supported traffic shapes (all mean-normalized to the same offered rate).
TRAFFIC_SHAPES = ("diurnal", "bursty", "flat")

# Frequency of the single-gear wall-clock rank (GHz). Any value works --
# CLOCK durations are calibrated against it -- it only needs to be shared
# between `make_clock_proc` and `build_serving_graph`.
CLOCK_FREQ_GHZ = 1.0


@dataclasses.dataclass(frozen=True)
class ServingModelProfile:
    """Per-token cost profile of one served model family.

    The *absolute* flop scale is anchored per family
    (`DECODE_FLOPS_ANCHORS` pre-scales decode cost to the simulated
    cluster's throughput class); everything relative is measured from
    the committed roofline artifact (`profiles_from_roofline`): the
    prefill:decode flops ratio and the per-phase frequency-sensitivity
    betas (memory-bound decode barely stretches under DVFS, per Calore
    et al., while prefill sits much closer to the compute roofline).
    """

    name: str                       # family key ("dense" / "moe" / "ssm")
    arch: str                       # representative repro.configs arch
    prefill_flops_per_token: float  # compute-bound prompt pass
    decode_flops_per_token: float   # memory-bound token generation
    decode_beta: float              # freq_sensitivity of DECODE tasks
    prefill_beta: float = 1.0       # freq_sensitivity of PREFILL tasks


# Decode-side absolute anchors (effective flops/token pre-scaled to the
# simulated cluster). Anchoring *decode* keeps steady-state J/token
# comparable across roofline regenerations; prefill cost then follows the
# measured per-arch prefill:decode ratio.
DECODE_FLOPS_ANCHORS: dict[str, float] = {
    "dense": 1.0e7, "moe": 6.0e6, "ssm": 3.5e6,
}

# Representative `repro.configs.ARCHS` member per served family.
FAMILY_ARCHS: dict[str, str] = {
    "dense": "qwen2.5-3b", "moe": "mixtral-8x7b", "ssm": "mamba2-370m",
}

# Maps each `ModelConfig.family` onto the anchor class whose cluster
# throughput scale it borrows (used by `profile_for_arch` for zoo cells).
_FAMILY_CLASS: dict[str, str] = {
    "dense": "dense", "moe": "moe", "ssm": "ssm",
    "hybrid": "ssm", "recurrent": "ssm",
    "vlm": "dense", "audio": "dense", "encdec": "dense",
}

# Pre-roofline hand-set profiles: the fallback when the committed
# `results/roofline.json` is unavailable (e.g. a partial vendored copy of
# `repro.core`). A fresh checkout always loads the measured profiles.
_HAND_SET_PROFILES: dict[str, ServingModelProfile] = {
    "dense": ServingModelProfile("dense", "qwen2.5-3b", 1.0e7, 1.0e7, 0.25),
    "moe": ServingModelProfile("moe", "mixtral-8x7b", 6.0e6, 6.0e6, 0.30),
    "ssm": ServingModelProfile("ssm", "mamba2-370m", 8.0e6, 3.5e6, 0.55),
}

# `profile_for_arch` clamps the measured prefill:decode flops ratio to
# this range so one outlier phase (e.g. an encoder-heavy prefill) cannot
# produce degenerate wave durations.
_RATIO_CLAMP = (0.05, 20.0)


def _measured_profile(name: str, arch: str, anchor: float,
                      table) -> ServingModelProfile:
    ratio = (table.flops_per_token(arch, "prefill")
             / table.flops_per_token(arch, "decode"))
    ratio = min(max(ratio, _RATIO_CLAMP[0]), _RATIO_CLAMP[1])
    return ServingModelProfile(
        name=name, arch=arch,
        prefill_flops_per_token=anchor * ratio,
        decode_flops_per_token=anchor,
        decode_beta=table.beta(arch, "decode"),
        prefill_beta=table.beta(arch, "prefill"),
    )


def profiles_from_roofline(table=None) -> dict[str, ServingModelProfile]:
    """Family serving profiles derived from the measured roofline table.

    Decode flops/token stay at the family's `DECODE_FLOPS_ANCHORS` value
    (the absolute scale is a cluster-throughput calibration, not a
    measurement); the prefill:decode ratio and both phase betas come
    from the representative arch's committed roofline rows
    (docs/ROOFLINE.md).

    Parameters
    ----------
    table : repro.core.roofline_model.RooflineTable, optional
        Parsed table; the committed `results/roofline.json` when
        omitted.

    Returns
    -------
    dict[str, ServingModelProfile]
        Keyed like `MODEL_PROFILES` ("dense" / "moe" / "ssm").
    """
    if table is None:
        from .roofline_model import load_roofline
        table = load_roofline()
    return {name: _measured_profile(name, arch,
                                    DECODE_FLOPS_ANCHORS[name], table)
            for name, arch in FAMILY_ARCHS.items()}


def profile_for_arch(arch: str, table=None) -> ServingModelProfile:
    """A per-architecture serving profile from its measured roofline rows.

    Used by the model-zoo serving scenarios: the arch borrows the
    decode-flops anchor of its family's throughput class
    (`_FAMILY_CLASS`) and takes its prefill:decode ratio and phase betas
    from its own committed roofline rows, so every zoo config becomes a
    distinct, attributable serving cell.

    Parameters
    ----------
    arch : str
        Architecture key (a `repro.configs.ARCHS` name).
    table : repro.core.roofline_model.RooflineTable, optional
        Parsed table; the committed `results/roofline.json` when
        omitted.

    Returns
    -------
    ServingModelProfile
        Profile named after the arch.
    """
    if table is None:
        from .roofline_model import load_roofline
        table = load_roofline()
    family = table.get(arch, "decode")["family"]
    klass = _FAMILY_CLASS.get(family, "dense")
    return _measured_profile(arch, arch, DECODE_FLOPS_ANCHORS[klass], table)


def _default_profiles() -> dict[str, ServingModelProfile]:
    try:
        return profiles_from_roofline()
    except (OSError, ValueError, KeyError):
        return dict(_HAND_SET_PROFILES)


# Family profiles keyed by `ServingModelProfile.name`; `arch` names the
# representative config in `repro.configs.ARCHS`. Roofline-derived on a
# fresh checkout (see `profiles_from_roofline`); hand-set only when the
# committed artifact is unavailable.
MODEL_PROFILES: dict[str, ServingModelProfile] = _default_profiles()


@dataclasses.dataclass(frozen=True)
class ServingTrace:
    """One deterministic seeded request trace (see `make_trace`)."""

    shape: str                  # member of TRAFFIC_SHAPES
    seed: int                   # trace seed ((shape, seed) is reproducible)
    rate_rps: float             # mean offered request rate (requests/s)
    duration_s: float           # trace horizon (arrivals fall inside it)
    arrival_s: np.ndarray       # sorted arrival times, shape (R,)
    prompt_tokens: np.ndarray   # prompt length per request, shape (R,)
    decode_tokens: np.ndarray   # tokens to generate per request, >= 1

    @property
    def n_requests(self) -> int:
        """Number of requests in the trace."""
        return int(self.arrival_s.size)

    @property
    def total_decode_tokens(self) -> int:
        """Total generated tokens -- the J/token denominator."""
        return int(self.decode_tokens.sum())


def traffic_rate_curve(shape: str, t: np.ndarray,
                       duration_s: float) -> np.ndarray:
    """Mean-normalized rate modulation of a traffic shape.

    Parameters
    ----------
    shape : str
        One of `TRAFFIC_SHAPES`. "diurnal" is one full sinusoidal day
        compressed onto the trace (trough at t=0, peak mid-trace);
        "bursty" is a 0.6x baseline with 3.0x square-wave bursts active
        one-sixth of the time; "flat" is constant.
    t : np.ndarray
        Times (seconds) to evaluate, within `[0, duration_s)`.
    duration_s : float
        Trace horizon; shapes are periodic over it.

    Returns
    -------
    np.ndarray
        Nonnegative multipliers with mean 1.0 over the horizon, so every
        shape offers the same total load (arrival-rate conservation,
        pinned by tests/test_serving.py).
    """
    if shape not in TRAFFIC_SHAPES:
        raise ValueError(f"unknown traffic shape {shape!r}; "
                         f"expected one of {TRAFFIC_SHAPES}")
    t = np.asarray(t, dtype=float)
    x = t / float(duration_s)
    if shape == "flat":
        return np.ones_like(x)
    if shape == "diurnal":
        return 1.0 - 0.8 * np.cos(2.0 * np.pi * x)
    # bursty: mean = 0.6 + 2.4 * (1/6) = 1.0
    return 0.6 + 2.4 * ((6.0 * x) % 1.0 < 1.0 / 6.0)


def make_trace(shape: str, *, rate_rps: float = 8.0, duration_s: float = 16.0,
               seed: int = 0, prompt_tokens: tuple[int, int] = (16, 96),
               decode_tokens: tuple[int, int] = (8, 48),
               bins: int = 256) -> ServingTrace:
    """Draw a deterministic seeded request trace for one traffic shape.

    Arrivals are an inhomogeneous Poisson process: the horizon is split
    into `bins` equal bins, each bin draws `Poisson(rate * shape(t) * dt)`
    requests placed uniformly inside it. Prompt and decode lengths are
    uniform integers. Everything comes from one `np.random.default_rng`
    seeded by `(seed, shape)`, so the same arguments always reproduce the
    same trace (different shapes diverge even at equal seeds).

    Parameters
    ----------
    shape : str
        Traffic shape, one of `TRAFFIC_SHAPES`.
    rate_rps : float
        Mean offered request rate in requests/second (shapes are
        mean-normalized, so this is the average across the horizon).
    duration_s : float
        Trace horizon in seconds; all arrivals land inside it.
    seed : int
        Trace seed.
    prompt_tokens, decode_tokens : tuple[int, int]
        Inclusive (low, high) ranges for per-request prompt length and
        generated-token count; decode low must be >= 1 so every request
        finishes during a decode wave.
    bins : int
        Bin count for the inhomogeneous-Poisson discretization.

    Returns
    -------
    ServingTrace
        Sorted arrivals with per-request token counts.
    """
    if shape not in TRAFFIC_SHAPES:
        raise ValueError(f"unknown traffic shape {shape!r}; "
                         f"expected one of {TRAFFIC_SHAPES}")
    if decode_tokens[0] < 1:
        raise ValueError("decode_tokens low bound must be >= 1")
    rng = np.random.default_rng([seed, TRAFFIC_SHAPES.index(shape)])
    dt = float(duration_s) / bins
    centers = (np.arange(bins) + 0.5) * dt
    lam = rate_rps * traffic_rate_curve(shape, centers, duration_s) * dt
    counts = rng.poisson(lam)
    n = int(counts.sum())
    offsets = rng.random(n) * dt
    arrival = np.repeat(centers - 0.5 * dt, counts) + offsets
    order = np.argsort(arrival, kind="stable")
    return ServingTrace(
        shape=shape, seed=seed, rate_rps=float(rate_rps),
        duration_s=float(duration_s), arrival_s=arrival[order],
        prompt_tokens=rng.integers(prompt_tokens[0], prompt_tokens[1] + 1,
                                   size=n)[order],
        decode_tokens=rng.integers(decode_tokens[0], decode_tokens[1] + 1,
                                   size=n)[order])


def make_server_proc(base: str = "arc_opteron_6128",
                     const_scale: float = 0.1) -> ProcessorModel:
    """Server-class processor for serving clusters.

    A `GEAR_TABLES[base]` ladder with the non-CPU nodal constant scaled
    down (default 0.1x): an HPC node's 150 W constant would drown the
    gear-sensitive energy on an idle-heavy serving trace, whereas a
    serving node's idle-to-peak ratio is what DVFS strategies actually
    get to exploit. Derive LITTLE siblings with `scale_processor`.
    """
    return scale_processor(make_processor(base), f"serve_{base}",
                           const_scale=const_scale)


def make_clock_proc(freq_ghz: float = CLOCK_FREQ_GHZ) -> ProcessorModel:
    """Zero-power single-gear processor for the wall-clock rank.

    One gear (no switches possible), zero dynamic capacitance, zero
    leakage, zero constant power: whatever idle gear or plan a strategy
    assigns to the clock rank costs nothing and -- because the serving
    cost model pins `CLOCK` beta at 0.0 -- changes no duration.
    """
    return ProcessorModel(name="wall_clock",
                          gears=(Gear(0, freq_ghz, 0.5),),
                          n_cores=1, eff_cap_nf=0.0, idle_activity=0.0,
                          i_sub_amps=0.0, p_const_watts=0.0,
                          switch_latency_s=1e-9)


def serving_machine(servers: "ProcessorModel | MachineModel",
                    n_servers: int) -> MachineModel:
    """Serving cluster: `n_servers` server ranks plus the clock rank.

    Parameters
    ----------
    servers : ProcessorModel | MachineModel
        The server side -- a bare processor for a homogeneous cluster or
        a `MachineModel` pattern (e.g. `make_big_little`) unrolled over
        the first `n_servers` ranks.
    n_servers : int
        Number of server ranks; rank `n_servers` becomes the zero-power
        clock rank (`make_clock_proc`).

    Returns
    -------
    MachineModel
        Pattern of length `n_servers + 1`, exactly matching the rank
        count of graphs from `build_serving_graph(..., n_servers=...)`.
    """
    m = as_machine(servers)
    procs = tuple(m.rank_procs(n_servers)) + (make_clock_proc(),)
    return MachineModel(name=f"serving_{m.name}", procs=procs)


def serving_cost_model(profile: ServingModelProfile, *,
                       flops_per_cycle: float = 4.0,
                       comm_bandwidth_gbs: float = 5.0,
                       comm_latency_s: float = 5e-6) -> CostModel:
    """Cost model for serving graphs of one model family.

    Parameters
    ----------
    profile : ServingModelProfile
        Supplies the measured prefill and decode betas; `CLOCK` is
        pinned at beta 0.0 so the wave cadence is gear-invariant
        (required by `build_serving_graph`).
    flops_per_cycle, comm_bandwidth_gbs, comm_latency_s : float
        Forwarded to `CostModel`; comm prices the clock-tick fan-out and
        is negligible against realistic wave periods.

    Returns
    -------
    CostModel
        Ready for `build_serving_graph` / `PlanContext`.
    """
    return CostModel(flops_per_cycle=flops_per_cycle,
                     freq_sensitivity={"PREFILL": profile.prefill_beta,
                                       "DECODE": profile.decode_beta,
                                       "CLOCK": 0.0},
                     comm_bandwidth_gbs=comm_bandwidth_gbs,
                     comm_latency_s=comm_latency_s)


@dataclasses.dataclass(frozen=True)
class ServingGraph:
    """A compiled serving trace: the `TaskGraph` plus request bookkeeping.

    `done_tid[r]` is the tid of the `DECODE` task whose completion emits
    request r's final token -- `request_latencies` subtracts arrivals
    from those finish times, for serial `Schedule`s and batched
    `FleetSchedule` lanes alike.
    """

    graph: TaskGraph            # CLOCK/PREFILL/DECODE wave DAG
    trace: ServingTrace         # the compiled trace
    n_servers: int              # server ranks (clock rank is n_servers)
    step_period_s: float        # continuous-batching wave period
    tokens_per_wave: int        # decode tokens per request per wave
    n_waves: int                # emitted waves (admission + drain)
    done_tid: np.ndarray        # per-request completion tid, shape (R,)
    admit_wave: np.ndarray      # per-request admission wave, shape (R,)

    @property
    def horizon_s(self) -> float:
        """Wall-clock span of the wave chain (`n_waves * period`).

        Every schedule's makespan is at least this (the clock chain is
        gear-invariant), so an SLO deadline for `slo_latency_s` is
        naturally expressed as `horizon_s + <per-request headroom>`.
        """
        return self.n_waves * self.step_period_s


def build_serving_graph(trace: ServingTrace, *, n_servers: int,
                        step_period_s: float, cost: CostModel,
                        profile: ServingModelProfile,
                        tokens_per_wave: int = 8,
                        clock_freq_ghz: float = CLOCK_FREQ_GHZ,
                        tile_size: int = 64) -> ServingGraph:
    """Compile a trace into a continuous-batching wave `TaskGraph`.

    Wave w ticks at `w * step_period_s`: a `CLOCK` task on the dedicated
    clock rank (chained to wave w-1, duration exactly one period --
    calibrated through `cost` so `durations_top` reproduces it). Requests
    arrived by the tick are admitted round-robin across server ranks;
    each admission emits a `PREFILL` task, and every server with active
    requests emits one fused `DECODE` task covering up to
    `tokens_per_wave` tokens per active request. Server tasks depend on
    their wave's clock task, so no work starts before its wave tick (plus
    the cross-rank comm delay); an overloaded server simply falls behind
    its ticks through program order, which is exactly how queueing delay
    reaches the p99. Emission is wave-by-wave, clock first, so tids are
    topologically sorted and in per-rank program order -- the layout
    `simulate_fleet` requires.

    Parameters
    ----------
    trace : ServingTrace
        Seeded traffic trace from `make_trace`.
    n_servers : int
        Server ranks; the graph gets `n_servers + 1` ranks (clock last).
        Pair with `serving_machine(..., n_servers)`.
    step_period_s : float
        Continuous-batching wave period in seconds.
    cost : CostModel
        Must pin `CLOCK` at beta 0.0 (`serving_cost_model` does), or no
        strategy could be trusted not to stretch the wall clock.
    profile : ServingModelProfile
        Per-token flop costs for `PREFILL` / `DECODE` tasks.
    tokens_per_wave : int
        Decode tokens generated per request per wave.
    clock_freq_ghz : float
        Frequency the clock rank runs at; must match the
        `make_clock_proc` used in the machine.
    tile_size : int
        `TaskGraph.tile_size` -- only sets the (small) per-edge transfer
        size of the clock fan-out.

    Returns
    -------
    ServingGraph
        The graph plus per-request completion/admission bookkeeping.
    """
    if cost.beta("CLOCK") != 0.0:
        raise ValueError("serving graphs need freq_sensitivity['CLOCK']=0.0 "
                         "(gear-invariant wave cadence); use "
                         "serving_cost_model()")
    if np.any(trace.decode_tokens < 1):
        raise ValueError("every request must decode at least one token")
    period = float(step_period_s)
    clock_rank = n_servers
    # flops such that durations_top gives exactly one period on the clock
    # rank: d = flops / (f * 1e9 * flops_per_cycle * eff)
    clock_rate = (clock_freq_ghz * 1e9 * cost.flops_per_cycle
                  * cost.kind_efficiency.get("CLOCK", 1.0))
    n_req = trace.n_requests
    done_tid = np.full(n_req, -1, dtype=np.int64)
    admit_wave = np.zeros(n_req, dtype=np.int64)
    tasks: list[Task] = []
    active: list[list[list[int]]] = [[] for _ in range(n_servers)]
    idx = admitted = 0
    w = 0
    prev_ctid = -1
    last_arrival = float(trace.arrival_s[-1]) if n_req else 0.0
    max_decode = int(trace.decode_tokens.max()) if n_req else 0
    limit = (math.ceil(last_arrival / period)
             + math.ceil(max_decode / tokens_per_wave) + 2)
    while idx < n_req or any(active):
        w += 1
        if w > limit:                            # pragma: no cover
            raise RuntimeError("serving wave compiler failed to drain")
        tick = w * period
        ctid = len(tasks)
        tasks.append(Task(ctid, "CLOCK", w, 0, 0, clock_rank,
                          period * clock_rate,
                          [prev_ctid] if w > 1 else [], (w, clock_rank)))
        prev_ctid = ctid
        new_by_server: list[list[int]] = [[] for _ in range(n_servers)]
        while idx < n_req and trace.arrival_s[idx] <= tick + 1e-12:
            new_by_server[admitted % n_servers].append(idx)
            admit_wave[idx] = w
            admitted += 1
            idx += 1
        for s in range(n_servers):
            pre_tids = []
            for r in new_by_server[s]:
                ptid = len(tasks)
                tasks.append(Task(
                    ptid, "PREFILL", w, s, r, s,
                    float(trace.prompt_tokens[r])
                    * profile.prefill_flops_per_token,
                    [ctid], (w, s)))
                pre_tids.append(ptid)
                active[s].append([r, int(trace.decode_tokens[r])])
            if not active[s]:
                continue
            tok = sum(min(tokens_per_wave, rem) for _, rem in active[s])
            dtid = len(tasks)
            tasks.append(Task(dtid, "DECODE", w, s, 0, s,
                              float(tok) * profile.decode_flops_per_token,
                              [ctid] + pre_tids, (w, s)))
            still = []
            for rec in active[s]:
                rec[1] -= min(tokens_per_wave, rec[1])
                if rec[1] == 0:
                    done_tid[rec[0]] = dtid
                else:
                    still.append(rec)
            active[s] = still
    graph = TaskGraph(name=f"serving_{trace.shape}",
                      n_tiles=n_servers + 1, tile_size=tile_size,
                      grid=(1, n_servers + 1), tasks=tasks)
    return ServingGraph(graph=graph, trace=trace, n_servers=n_servers,
                        step_period_s=period, tokens_per_wave=tokens_per_wave,
                        n_waves=w, done_tid=done_tid, admit_wave=admit_wave)


def request_latencies(sg: ServingGraph, finish: np.ndarray) -> np.ndarray:
    """Per-request latency (completion minus arrival) from finish times.

    Parameters
    ----------
    sg : ServingGraph
        Compiled trace (supplies `done_tid` and arrivals).
    finish : np.ndarray
        Per-task finish times: a serial `Schedule.finish` of shape
        `(n_tasks,)` or a `FleetSchedule.finish` of shape
        `(B, n_tasks)` -- any leading batch dimensions broadcast.

    Returns
    -------
    np.ndarray
        Latencies in seconds, shape `finish.shape[:-1] + (R,)`.
    """
    finish = np.asarray(finish, dtype=float)
    return finish[..., sg.done_tid] - sg.trace.arrival_s


def p99_latency_s(latencies: np.ndarray, q: float = 99.0) -> np.ndarray:
    """Tail latency percentile along the last (request) axis.

    Parameters
    ----------
    latencies : np.ndarray
        Output of `request_latencies` (any leading batch dims).
    q : float
        Percentile in [0, 100] (default 99).

    Returns
    -------
    np.ndarray
        The q-th percentile per leading index (0.0 for empty traces).
    """
    latencies = np.asarray(latencies, dtype=float)
    if latencies.shape[-1] == 0:
        return np.zeros(latencies.shape[:-1])
    return np.percentile(latencies, q, axis=-1)


def slo_violation_rate(latencies: np.ndarray, slo_s: float) -> np.ndarray:
    """Fraction of requests whose latency exceeds the SLO.

    Parameters
    ----------
    latencies : np.ndarray
        Output of `request_latencies` (any leading batch dims).
    slo_s : float
        Per-request latency SLO in seconds.

    Returns
    -------
    np.ndarray
        Violation fraction in [0, 1] per leading index (0.0 for empty
        traces).
    """
    latencies = np.asarray(latencies, dtype=float)
    if latencies.shape[-1] == 0:
        return np.zeros(latencies.shape[:-1])
    return np.mean(latencies > slo_s, axis=-1)
