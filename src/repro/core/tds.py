"""Task Dependency Set (TDS) analysis: the paper's per-task wait taxonomy.

TX (the paper's Section 3 mechanism) inspects, for every task of the
statically known factorization DAG, its *Task Dependency Set* -- the tasks
whose outputs it consumes -- and classifies the idle period the task induces
on its rank before it can start:

  * panel wait          -- the latest-arriving dependency is a panel-
                           factorization task (POTRF/GETRF/GEQRT/TSQRT):
                           the rank is stalled on the iteration's critical
                           panel, the classic fork-join wait of right-
                           looking factorizations.
  * communication wait  -- the binding dependency's *output* was already
                           computed when the rank went idle; the wait is
                           (mostly) wire time for the cross-rank transfer.
  * load-imbalance wait -- the binding producer was still computing when
                           the rank ran out of work: the block-cyclic
                           layout handed this rank less work this
                           iteration.

Symmetrically, each task's *reclaimable local slack* (the gap between its
finish and the earliest moment anything -- a DAG consumer, the next task in
its rank's program order, or the end of the schedule -- needs it) is
classified by what bounds it, so a strategy can decide per class how
aggressively to stretch:

  * panel slack      -- bounded by a panel consumer: stretching eats
                        directly into the next panel's start, so a plan
                        that distrusts its cost model stays conservative
                        and pre-arms the up-switch instead.
  * comm slack       -- bounded by a cross-rank (non-panel) consumer:
                        safe to fill, the consumer pays the wire delay
                        anyway.
  * imbalance slack  -- bounded only by the rank's own program order or
                        the end of the schedule: the rank simply has a
                        hole; fully reclaimable.

Everything is computed in a handful of vectorized NumPy scatter passes over
`TaskGraph`'s cached edge arrays -- no per-task Python loops -- and exposed
as flat arrays (`wait_class`, `wait_s`, `slack_class`, `slack_s`,
`binding_dep`, `binding_consumer`) that `core/strategies.py` consumes via
`PlanContext.tds`. The classification is deterministic: the binding edge is
the latest-arriving (waits) / tightest (slack) one, ties broken toward the
highest task id, and class precedence is panel > comm/imbalance.

Heterogeneous machines: the analysis consumes a concrete baseline
schedule's start/finish times, and `PlanContext` builds that baseline from
*per-rank* top-gear durations (each task timed at its owner's own f_max via
`CostModel.durations_top` on a `MachineModel`), so waits and slacks induced
by slow ranks are classified exactly as the mixed cluster would realize
them -- a LITTLE rank's long panel task genuinely binds its consumers.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .critical_path import _edge_delays, schedule_slack
from .dag import PANEL_KINDS, TaskGraph

# Wait / slack classes (int8 codes in the result arrays).
WAIT_NONE = 0        # no wait / no slack
WAIT_PANEL = 1       # bound by a panel-factorization task
WAIT_COMM = 2        # bound by cross-rank communication
WAIT_IMBALANCE = 3   # bound by uneven work distribution / schedule end

WAIT_CLASS_NAMES = ("none", "panel", "comm", "imbalance")

# ---------------------------------------------------------------------------
# Task-type gear classes (Costero et al.): the grouping a per-task-type gear
# policy assigns asymmetric tables to. Panel kinds sit on the iteration's
# critical path (keep them on the fast operating points); solve kinds
# (triangular solves / Q applications) feed the trailing update; update
# kinds (GEMM-like) dominate the flops and tolerate the full ladder.
# ---------------------------------------------------------------------------
GEAR_CLASS_PANEL = 0
GEAR_CLASS_SOLVE = 1
GEAR_CLASS_UPDATE = 2

GEAR_CLASS_NAMES = ("panel", "solve", "update")

SOLVE_KINDS = frozenset({"TRSM", "TRSM_ROW", "TRSM_COL", "UNMQR"})


def task_gear_classes(graph: TaskGraph) -> np.ndarray:
    """Per-task gear-class codes (int8): panel / solve / update.

    Panel membership reuses `PANEL_KINDS` (the wait taxonomy's notion of
    'on the critical panel'); solve kinds are the triangular/orthogonal
    applies; everything else (GEMM, SYRK, SSRFB, unknown kinds) is a
    trailing-matrix update.
    """
    codes = np.full(len(graph.tasks), GEAR_CLASS_UPDATE, dtype=np.int8)
    for t in graph.tasks:
        if t.kind in PANEL_KINDS:
            codes[t.tid] = GEAR_CLASS_PANEL
        elif t.kind in SOLVE_KINDS:
            codes[t.tid] = GEAR_CLASS_SOLVE
    return codes


_EPS = 1e-15         # same "is there a wait at all" threshold the engines use


@dataclasses.dataclass
class TdsResult:
    """Per-task TDS arrays over one baseline schedule of a TaskGraph.

    All arrays are indexed by task id. `wait_*` describe the idle gap on the
    task's rank *before* the task starts; `slack_*` describe the reclaimable
    window *after* it finishes.
    """

    graph: TaskGraph
    comm_time: float | np.ndarray
    rank_ready: np.ndarray        # finish of the previous same-rank task (0 for rank heads)
    wait_s: np.ndarray            # start - rank_ready, clipped at 0
    wait_class: np.ndarray        # int8, WAIT_* code of the wait
    binding_dep: np.ndarray       # tid of the latest-arriving dependency (-1: none)
    slack_s: np.ndarray           # reclaimable local slack (schedule_slack)
    slack_class: np.ndarray       # int8, WAIT_* code of the slack bound
    binding_consumer: np.ndarray  # tid bounding the slack (-1: program order / makespan)

    def dependency_set(self, tid: int) -> frozenset[int]:
        """The task's TDS proper: ids of the tasks whose outputs it consumes."""
        return frozenset(self.graph.tasks[tid].deps)

    def dependency_counts(self) -> np.ndarray:
        """Per-task TDS cardinality: how many producers each task consumes."""
        return np.asarray([len(t.deps) for t in self.graph.tasks],
                          dtype=np.int64)

    def _seconds_by_class(self, seconds: np.ndarray,
                          cls: np.ndarray) -> dict[str, float]:
        return {name: float(seconds[cls == code].sum())
                for code, name in enumerate(WAIT_CLASS_NAMES)}

    def wait_seconds_by_class(self) -> dict[str, float]:
        """Total pre-task idle seconds attributed to each wait class."""
        return self._seconds_by_class(self.wait_s, self.wait_class)

    def slack_seconds_by_class(self) -> dict[str, float]:
        """Total reclaimable slack seconds attributed to each class."""
        return self._seconds_by_class(self.slack_s, self.slack_class)


def _is_panel(graph: TaskGraph) -> np.ndarray:
    return np.asarray([t.kind in PANEL_KINDS for t in graph.tasks],
                      dtype=bool)


def analyze_tds(graph: TaskGraph, start: np.ndarray, finish: np.ndarray,
                comm_time: float = 0.0,
                slack: np.ndarray | None = None) -> TdsResult:
    """Classify every task's wait and slack on a concrete schedule.

    Parameters
    ----------
    graph : TaskGraph
        The scheduled task graph.
    start, finish : np.ndarray
        Per-task times of a baseline (usually top-gear) schedule;
        classification semantics assume ranks execute their tasks in
        program order, as both simulator engines do.
    comm_time : float or np.ndarray
        Transfer delay on cross-rank dependency edges: a uniform scalar,
        or an (n_ranks, n_ranks) matrix from a nonuniform `LinkModel`
        (`CostModel.comm_cost`; zero diagonal, local edges free).
    slack : np.ndarray, optional
        Lets a caller that already ran `schedule_slack` on this schedule
        (PlanContext) share it instead of recomputing.

    Returns
    -------
    TdsResult
        Per-task wait/slack seconds, their panel/comm/imbalance classes,
        and the binding dependency/consumer representatives.
    """
    n = len(graph.tasks)
    start = np.asarray(start, dtype=float)
    finish = np.asarray(finish, dtype=float)
    owner = np.asarray([t.owner for t in graph.tasks], dtype=np.int64)
    panel = _is_panel(graph)
    src, dst, cross = graph.dep_edge_arrays()
    delay = _edge_delays(graph, src, dst, cross, comm_time)

    # ---- waits: idle gap before each task ------------------------------
    rank_ready = np.zeros(n)
    prev, nxt = graph.rank_order_pairs()
    if len(prev):
        rank_ready[nxt] = finish[prev]
    wait = np.maximum(start - rank_ready, 0.0)

    # binding dependency: latest arrival; ties toward the highest tid for
    # the representative, but a panel dep among the ties wins the *class*
    binding_dep = np.full(n, -1, dtype=np.int64)
    wait_class = np.zeros(n, dtype=np.int8)
    panel_binds_wait = np.zeros(n, dtype=bool)
    if len(src):
        arrival = finish[src] + delay
        max_arr = np.full(n, -np.inf)
        np.maximum.at(max_arr, dst, arrival)
        at_max = arrival == max_arr[dst]
        np.maximum.at(binding_dep, dst[at_max], src[at_max])
        pm = at_max & panel[src]
        panel_binds_wait[dst[pm]] = True

    waiting = wait > _EPS
    has_dep = binding_dep >= 0
    w = waiting & has_dep
    if w.any():
        b = binding_dep[w]
        # how long the producer kept computing after this rank went idle,
        # vs the wire time of the binding edge
        busy_after_idle = finish[b] - rank_ready[w]
        if np.ndim(comm_time) == 0:
            edge_delay = np.where(owner[b] != owner[w], comm_time, 0.0)
        else:
            edge_delay = np.asarray(comm_time)[owner[b], owner[w]]
        cls = np.where(busy_after_idle > edge_delay,
                       WAIT_IMBALANCE, WAIT_COMM).astype(np.int8)
        cls[panel_binds_wait[w]] = WAIT_PANEL
        wait_class[w] = cls

    # ---- slack: reclaimable window after each task ---------------------
    if slack is None:
        slack = schedule_slack(start, finish, graph, comm_time)
    binding_consumer = np.full(n, -1, dtype=np.int64)
    slack_class = np.zeros(n, dtype=np.int8)
    has_slack = slack > _EPS
    edge_cross = np.zeros(n, dtype=bool)
    edge_panel = np.zeros(n, dtype=bool)
    if len(src):
        # same expression schedule_slack minimizes, so comparisons are exact
        edge_slack = (start[dst] - delay) - finish[src]
        sel = (edge_slack == slack[src]) & has_slack[src]
        np.maximum.at(binding_consumer, src[sel], dst[sel])
        edge_cross[src[sel & cross]] = True
        edge_panel[src[sel & panel[dst]]] = True
    # program order / makespan / same-rank edges (the latter tie with
    # program order) -> the rank simply has a hole: imbalance; among tied
    # binding edges, panel beats comm beats imbalance
    slack_class[has_slack] = WAIT_IMBALANCE
    slack_class[has_slack & edge_cross] = WAIT_COMM
    slack_class[has_slack & edge_panel] = WAIT_PANEL

    return TdsResult(graph=graph, comm_time=comm_time, rank_ready=rank_ready,
                     wait_s=wait, wait_class=wait_class,
                     binding_dep=binding_dep, slack_s=slack,
                     slack_class=slack_class, binding_consumer=binding_consumer)


def analyze_residual_tds(graph: TaskGraph, start: np.ndarray,
                         finish: np.ndarray, comm_time: float = 0.0,
                         pending: np.ndarray | None = None,
                         slack: np.ndarray | None = None) -> TdsResult:
    """TDS analysis restricted to the pending (residual) subgraph.

    The closed-loop re-planning counterpart of `analyze_tds`
    (`core/replan.py`): `start`/`finish` are *hybrid* times -- observed
    realized finishes for already-executed (frozen) tasks, predicted
    top-gear times for pending ones, as produced by
    `critical_path.residual_schedule_times` -- so every pending task's
    wait and slack is re-derived anchored on what actually happened.
    Frozen tasks cannot be re-planned: their entries come back neutral
    (zero seconds, `WAIT_NONE`, binding ids of -1).

    Parameters
    ----------
    graph : TaskGraph
        The full task graph (the residual subgraph is selected by mask).
    start, finish : np.ndarray
        Hybrid per-task times (see `residual_schedule_times`; frozen
        tasks' `start` entries are never read).
    comm_time : float or np.ndarray
        Transfer delay on cross-rank dependency edges (scalar or matrix,
        as for `analyze_tds`).
    pending : np.ndarray, optional
        Boolean mask of not-yet-started tasks (default: all, in which
        case this is exactly `analyze_tds`).
    slack : np.ndarray, optional
        Precomputed `residual_schedule_slack` over the same times.

    Returns
    -------
    TdsResult
        The full-graph result with frozen entries neutralized; pending
        entries are identical to `analyze_tds` on the hybrid schedule.
    """
    res = analyze_tds(graph, start, finish, comm_time, slack=slack)
    if pending is None:
        return res
    done = ~np.asarray(pending, dtype=bool)
    if not done.any():
        return res
    # analyze_tds stores a caller-passed `slack` array into the result
    # without copying; detach before neutralizing so the masking can never
    # write through into the caller's array
    res.slack_s = res.slack_s.copy()
    res.wait_s[done] = 0.0
    res.wait_class[done] = WAIT_NONE
    res.binding_dep[done] = -1
    res.slack_s[done] = 0.0
    res.slack_class[done] = WAIT_NONE
    res.binding_consumer[done] = -1
    return res


def compute_tds(graph: TaskGraph, proc, cost) -> TdsResult:
    """TDS analysis over the zero-overhead top-gear baseline schedule.

    Convenience wrapper for callers without a `PlanContext` (which caches
    the baseline schedule and this analysis; prefer `PlanContext.tds`).
    """
    from .strategies import PlanContext
    ctx = PlanContext(graph, proc, cost)
    return ctx.tds
