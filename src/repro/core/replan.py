"""Closed-loop online re-planning: the `tx_replan` strategy + replay driver.

The paper's TX scheduler (and PR 3's `tx_online` noise study) commits ONCE
to a plan derived from predicted task durations. When those predictions are
noisy, the committed stretches drift away from reality and the drift
*accumulates*: a task overrunning its window in iteration 2 poisons the
slack estimates of everything planned for iterations 3..T. Rizvandi et al.
show optimal gear choice is sensitive to exactly these duration estimates,
and Calore et al. measure the model-vs-hardware drift that makes one-shot
plans stale -- the classic cure is a feedback loop, and this module closes
it:

    wave 0          plan ALL tasks from the noisy estimates (this is
                    exactly the tx_online plan), but COMMIT only the
                    first `replan_every` iterations' gears;
    observe         execute the committed prefix on the true durations
                    (replay driver: one fast-engine simulation per wave)
                    and read the realized finish times -- because d(f) is
                    linear in a task's work, each observed finish reveals
                    the executed task's TRUE top-gear duration, so the
                    planner's estimate for the past snaps to ground truth;
    wave w          re-derive the residual baseline / slack / TDS through
                    `PlanContext.restricted_to(pending, anchor)` -- the
                    executed prefix pinned at the anchor finishes, pending
                    tasks predicted at the (still noisy) estimated
                    durations -- and re-plan every not-yet-started task
                    with the unchanged TX policy (`tx_policy_segments`:
                    per-owner switch-latency floors, full per-rank
                    MachineModel awareness), then commit the next wave;
    repeat          until every iteration's gears are committed.

Receding-horizon control, in scheduling clothes: estimation error can hurt
at most one wave before the planner re-anchors on ground truth.

Two anchoring modes (`StrategyConfig.replan_anchor`):

  * "model" (default) -- the prefix is pinned at the *duration-reconciled*
    top-gear reconstruction: the corrected estimates replayed through the
    same baseline recursion TX plans against. This keeps the residual
    analysis consistent with the TX slack model, and makes rel_err = 0 a
    provable fixed point: every wave re-derives the perfect-knowledge `tx`
    plan bit-for-bit (pinned by tests/test_replan.py).
  * "observed" -- the prefix is pinned at the raw realized finish times,
    so the planner also re-plans around engine effects the slack model
    does not price (visible gear-switch stalls), at the cost of the exact
    rel_err = 0 identity (gears still match; times shift by stall-induced
    anchor drift).

With `replan_every` >= the iteration count the loop degenerates to a
single wave whose plan IS `tx_online`'s, bit for bit (same seeded noise
draw, same policy, same realize-on-true-work rescale).

With `StrategyConfig.replan_migrate` on a heterogeneous machine, each
wave additionally considers re-MAPPING the pending tasks (the
`migration_mappings` heuristic restricted to not-yet-started work):
candidate mappings are re-planned under their new owners, realized on the
true durations, and scored as full composite plans -- committed past plus
candidate future -- in one batched fleet pass against the
`tx_migrate_slowdown_cap` makespan bound. Already-committed tasks never
move. The default (`replan_migrate=False`) path is byte-identical to the
pre-migration driver.

The composite plan is expressed entirely in the `StrategyPlan` vocabulary
both engines already implement -- per-task gear segments, per-rank idle
gears, hidden switches -- so no engine change was needed and the lockstep
obligation (docs/ARCHITECTURE.md: any engine-visible semantic must land in
BOTH `simulate` and `simulate_reference`) is preserved trivially;
registering the strategy auto-enrolls it in
`tests/test_scheduler_differential.py` with exact fast-vs-oracle agreement.

Waves partition the graph by panel iteration (`Task.k`), the natural
re-planning epoch of a right-looking factorization: iteration boundaries
are dependency-closed and per-rank program-order prefixes (validated by
`critical_path.validate_frozen_closure`), so "everything before the wave"
is a well-formed executed past. Graphs whose tasks share one iteration
(e.g. synthetic DAGs) simply get the single-wave = tx_online behavior.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .dag import TaskGraph
from .fleet import simulate_fleet
from .scheduler import StrategyPlan, simulate
from .strategies import (PlanContext, draw_duration_noise,
                         migration_mappings, realize_on_true_work,
                         register_strategy, tx_policy_segments)

REPLAN_ANCHORS = ("model", "observed")


@dataclasses.dataclass
class WaveRecord:
    """Bookkeeping for one re-planning wave of the replay driver."""

    wave: int                     # wave index, 0-based
    iterations: tuple[int, int]   # [first, last] panel iteration committed
    n_committed: int              # tasks whose gears were fixed this wave
    n_observed: int               # tasks already realized when planning
    residual_slack_s: float       # total slack the planner saw for pending
    max_drift_s: float            # max |observed - reconciled model| finish
    #                               over the executed prefix (0.0 on wave 0)
    n_migrated: int = 0           # pending tasks re-mapped this wave
    #                               (only with cfg.replan_migrate on a
    #                               heterogeneous machine)


@dataclasses.dataclass
class ReplanOutcome:
    """Result of the replay/feedback driver: the plan plus its trace."""

    plan: StrategyPlan
    waves: list[WaveRecord]

    @property
    def n_waves(self) -> int:
        """Number of re-planning waves the driver executed."""
        return len(self.waves)


def iteration_waves(graph: TaskGraph, every: int) -> np.ndarray:
    """Per-task wave ids: `every` consecutive panel iterations per wave.

    Parameters
    ----------
    graph : TaskGraph
        The task graph; tasks are grouped by their `Task.k` iteration.
    every : int
        Iterations per wave (>= 1). Iteration values need not be
        contiguous; grouping is by position in the sorted unique values.

    Returns
    -------
    np.ndarray
        int64 wave id per task; wave w is exactly the tasks of the w-th
        group of `every` iterations, so each wave boundary is a
        dependency-closed, per-rank program-order prefix.
    """
    if every < 1:
        raise ValueError(f"replan_every must be >= 1, got {every}")
    iters = np.asarray([t.k for t in graph.tasks], dtype=np.int64)
    if not len(iters):
        return iters
    uniq = np.unique(iters)                     # sorted
    pos = np.searchsorted(uniq, iters)          # iteration -> position
    return pos // every


def replan_tx(ctx: PlanContext, every: int | None = None,
              anchor: str | None = None) -> ReplanOutcome:
    """The closed-loop replay/feedback driver behind `tx_replan`.

    Runs the wave loop described in the module docstring: plan from noisy
    estimates (`draw_duration_noise` -- the identical draw `tx_online`
    uses), commit one wave of gears, simulate the committed prefix on the
    true durations with the fast engine, reconcile the estimates with the
    true work each observed finish reveals, re-derive the residual
    slack/TDS through `PlanContext.restricted_to`, and re-plan the
    remaining subgraph until every task is committed.

    Parameters
    ----------
    ctx : PlanContext
        Ground-truth planning context (its `durations` are the true ones
        the committed plan is realized against). Heterogeneous
        `MachineModel` contexts are fully supported -- the TX policy
        floors and two-gear splits resolve per owning rank throughout.
    every : int, optional
        Iterations per wave; defaults to `ctx.cfg.replan_every`.
    anchor : str, optional
        "model" or "observed" (see module docstring); defaults to
        `ctx.cfg.replan_anchor`.

    Returns
    -------
    ReplanOutcome
        The composite `StrategyPlan` (consumable by both engines
        unchanged) and one `WaveRecord` per wave.
    """
    cfg = ctx.cfg
    if every is None:
        every = cfg.replan_every
    if anchor is None:
        anchor = cfg.replan_anchor
    if anchor not in REPLAN_ANCHORS:
        raise ValueError(f"replan_anchor must be one of {REPLAN_ANCHORS}, "
                         f"got {anchor!r}")
    graph = ctx.graph
    n = ctx.n_tasks
    idle, rank_idle = ctx._idle_gears(-1)
    migrate = bool(cfg.replan_migrate) and not ctx.is_homogeneous
    owner0 = [t.owner for t in graph.tasks]

    def compose(segs: list[list],
                owners: "list[int] | None" = None) -> StrategyPlan:
        return StrategyPlan("tx_replan", segs, idle_gear=idle,
                            per_task_overhead=np.zeros(n),
                            hide_switch_in_wait=True,
                            rank_idle_gears=rank_idle,
                            task_owners=owners)

    wave_id = iteration_waves(graph, every)
    if not n:
        return ReplanOutcome(compose([]), [])

    d_true = ctx.durations
    eps = draw_duration_noise(cfg, n)
    # the planner's current belief: the tx_online draw initially, snapped
    # to ground truth task by task as observed finishes reveal true work
    d_known = d_true * (1.0 + eps)
    iters = np.asarray([t.k for t in graph.tasks], dtype=np.int64)

    # migrating state: the mapping committed so far (frozen tasks never
    # move) and the relative estimate error, zeroed as tasks freeze so
    # re-deriving d_known under a NEW mapping keeps the reconciled past
    eps_cur = eps.copy()
    owners_cur = list(owner0)
    mapped_ctx = ctx

    def owners_arg() -> "list[int] | None":
        return None if owners_cur == owner0 else list(owners_cur)

    n_waves = int(wave_id.max()) + 1
    segments: list[list] = [[] for _ in range(n)]
    frozen = np.zeros(n, dtype=bool)
    observed = np.zeros(n)
    waves: list[WaveRecord] = []
    for w in range(n_waves):
        in_wave = wave_id == w
        pending = ~frozen
        if migrate:
            # durations/estimates referenced to the CURRENT mapping
            d_true = mapped_ctx.durations
            d_known = d_true * (1.0 + eps_cur)
        est = mapped_ctx.with_durations(d_known)
        if not frozen.any():
            # wave 0 has no past to anchor on: the view IS the estimate
            # context, so the first wave's decisions match tx_online's
            view = est
            drift = 0.0
            pin = None
        else:
            model_finish = np.asarray(est.baseline.finish, dtype=float)
            drift = float(np.abs(observed[frozen]
                                 - model_finish[frozen]).max())
            pin = observed if anchor == "observed" else model_finish
            view = est.restricted_to(pending, pin)
        segs_est = tx_policy_segments(view)
        segs_true = realize_on_true_work(segs_est, d_true, d_known)
        n_migrated = 0
        if migrate:
            # feedback channel 2: candidate re-mappings of the pending
            # tasks, scored as full composite plans (committed past +
            # candidate future) in one batched fleet pass on the true
            # machine; keep-current sits in lane 0 and wins ties
            mappings = [m for m in migration_mappings(view, movable=pending)
                        if m != owners_cur]
            if mappings:
                plans = [compose([segments[i] if frozen[i] else segs_true[i]
                                  for i in range(n)], owners=owners_arg())]
                realized = [segs_true]
                for m in mappings:
                    mctx = ctx.with_owners(m)
                    dt = mctx.durations
                    dk = dt * (1.0 + eps_cur)
                    mest = mctx.with_durations(dk)
                    if pin is None:
                        mview = mest
                    else:
                        mpin = observed if anchor == "observed" else \
                            np.asarray(mest.baseline.finish, dtype=float)
                        mview = mest.restricted_to(pending, mpin)
                    st = realize_on_true_work(tx_policy_segments(mview),
                                              dt, dk)
                    realized.append(st)
                    plans.append(compose(
                        [segments[i] if frozen[i] else st[i]
                         for i in range(n)], owners=list(m)))
                fleet = simulate_fleet(graph, ctx.proc, ctx.cost, plans)
                energies, makespans = fleet.total_energy_j(), fleet.makespan
                cap = ctx.makespan_cap(cfg.tx_migrate_slowdown_cap)
                best = 0
                for i in range(1, len(plans)):
                    if makespans[i] <= cap + 1e-12 and \
                            energies[i] < energies[best]:
                        best = i
                if best:
                    m = mappings[best - 1]
                    n_migrated = sum(1 for a, b in zip(m, owners_cur)
                                     if a != b)
                    owners_cur = list(m)
                    mapped_ctx = ctx.with_owners(owners_cur)
                    segs_true = realized[best]
                    d_true = mapped_ctx.durations
        for tid in np.flatnonzero(in_wave):
            segments[tid] = segs_true[tid]
        waves.append(WaveRecord(
            wave=w,
            iterations=(int(iters[in_wave].min()),
                        int(iters[in_wave].max())),
            n_committed=int(in_wave.sum()),
            n_observed=int(frozen.sum()),
            residual_slack_s=float(view.tds.slack_s[pending].sum()),
            max_drift_s=drift,
            n_migrated=n_migrated))
        frozen |= in_wave
        if w + 1 < n_waves:
            # replay: realize the committed prefix on the TRUE durations.
            # Uncommitted tasks run as empty segment lists; a frozen
            # task's timing depends only on its (frozen) dependencies and
            # same-rank predecessors, so their realized times are exactly
            # what the final composite schedule will produce.
            partial = compose([segments[i] if frozen[i] else []
                               for i in range(n)], owners=owners_arg())
            sched = simulate(graph, ctx.proc, ctx.cost, partial)
            observed = np.asarray(sched.finish, dtype=float)
            # feedback channel 1: each observed finish reveals the frozen
            # task's true top-gear duration (d(f) is linear in work, and
            # the executed gears are known), so the belief snaps to truth
            d_known = np.where(frozen, d_true, d_known)
            eps_cur = np.where(frozen, 0.0, eps_cur)
    return ReplanOutcome(compose(segments, owners=owners_arg()), waves)


@register_strategy
class TxReplanStrategy:
    """Closed-loop TX: per-wave re-planning from observed finish times.

    `tx_online` with the loop closed (see the module docstring): the same
    seeded noisy duration estimates (`tx_online_rel_err` /
    `tx_online_seed`), but gears are committed `replan_every` panel
    iterations at a time and the remaining slack/TDS is re-derived from
    the realized schedule before each commit, so estimation error can
    accumulate across at most one wave.
    """

    name = "tx_replan"

    def plan(self, ctx: PlanContext) -> StrategyPlan:
        """Plan via the replay driver; see `replan_tx`."""
        return replan_tx(ctx).plan
