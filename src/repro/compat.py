"""Cross-version jax shims shared by the sharding and linalg layers.

`jax.shard_map` became a top-level API (with a `check_vma` kwarg) after
the 0.4.x series; on 0.4.x it lives at `jax.experimental.shard_map` and
the same knob is spelled `check_rep`. `shard_map` here presents the
modern calling convention on either version. (The pallas analogue lives
in `repro.kernels.compat`.)
"""

from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _LEGACY = False
else:                                       # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _LEGACY = True


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """`jax.shard_map` with the modern kwarg spelling on any jax version."""
    if _LEGACY:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)


__all__ = ["shard_map"]
