"""Single-device blocked factorizations (right-looking, LAPACK-style).

These are the sequential baselines: panel factorization + BLAS-3 trailing
update with a static block loop (jit unrolls it; block count is a config
constant). They double as oracles for the tiled/distributed versions.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import ref


def cholesky_blocked(a, block: int):
    """Lower Cholesky of SPD matrix `a` with block size `block`."""
    n = a.shape[0]
    assert n % block == 0, (n, block)
    nb = n // block
    l = a
    for k in range(nb):
        s = k * block
        e = s + block
        lkk = ref.potrf_ref(l[s:e, s:e])
        l = l.at[s:e, s:e].set(lkk)
        if e < n:
            panel = ref.trsm_ref(lkk, l[e:, s:e])          # X L^T = A
            l = l.at[e:, s:e].set(panel)
            l = l.at[e:, e:].add(-(panel @ panel.T))       # SYRK on trailing
    return jnp.tril(l)


def lu_blocked_nopiv(a, block: int):
    """Packed LU (unit-lower L, upper U) without pivoting.

    Valid for diagonally-dominant / SPD-shifted matrices (the paper's
    energy experiments use well-conditioned synthetic inputs; pivoted panel
    variants live in the tiled layer).
    """
    n = a.shape[0]
    assert n % block == 0
    nb = n // block
    m = a
    for k in range(nb):
        s, e = k * block, (k + 1) * block
        lu_kk = ref.getrf_nopiv_ref(m[s:e, s:e])
        m = m.at[s:e, s:e].set(lu_kk)
        if e < n:
            # U row block: solve unit-lower L_kk X = A
            u_row = ref.trsm_ref(jnp.tril(lu_kk, -1) + jnp.eye(block,
                                                               dtype=a.dtype),
                                 m[s:e, e:], side="left", trans=False,
                                 unit_diag=True)
            m = m.at[s:e, e:].set(u_row)
            # L column block: solve X U_kk = A
            l_col = ref.trsm_upper_right_ref(jnp.triu(lu_kk), m[e:, s:e])
            m = m.at[e:, s:e].set(l_col)
            m = m.at[e:, e:].add(-(l_col @ u_row))         # GEMM update
    return m


def qr_blocked(a, block: int):
    """Blocked Householder QR; returns (Q, R) with Q explicit (tests only)."""
    m_rows, n = a.shape
    assert n % block == 0 and m_rows == n, "square panels for the tiled grid"
    nb = n // block
    r = a
    q = jnp.eye(m_rows, dtype=a.dtype)
    for k in range(nb):
        s, e = k * block, (k + 1) * block
        v, t, rkk = ref.householder_qr_ref(r[s:, s:e])
        r = r.at[s:, s:e].set(0.0).at[s:e, s:e].set(rkk)
        if e < n:
            r = r.at[s:, e:].set(
                ref.apply_block_reflector_ref(v, t, r[s:, e:]))
        # accumulate Q = Q (I - V T V^T)
        q = q.at[:, s:].set(q[:, s:] - (q[:, s:] @ v) @ (t @ v.T))
    return q, jnp.triu(r)
