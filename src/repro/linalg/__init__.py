"""Dense factorization substrate: blocked + tiled + distributed
Cholesky / LU / QR in JAX (the paper's target workloads)."""

from .blocked import cholesky_blocked, lu_blocked_nopiv, qr_blocked
from .tiled import (TiledMatrix, tiled_cholesky, tiled_lu, tiled_qr,
                    tiles_to_dense, dense_to_tiles)

__all__ = [
    "cholesky_blocked", "lu_blocked_nopiv", "qr_blocked",
    "TiledMatrix", "tiled_cholesky", "tiled_lu", "tiled_qr",
    "tiles_to_dense", "dense_to_tiles",
]
