"""Tile-task factorizations that execute core/dag.py graphs 1:1.

A `TiledMatrix` stores the matrix as a [T, T, b, b] array of tiles. The
tiled factorizations run exactly the task kinds the energy DAG schedules
(POTRF/TRSM/SYRK/GEMM etc.), through the kernels.ops dispatch layer (Pallas
on TPU, pure jnp on CPU) -- so the thing the energy scheduler reasons about
is the thing that actually runs.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.kernels import ops, ref


@dataclasses.dataclass
class TiledMatrix:
    tiles: jnp.ndarray          # [T, T, b, b]

    @property
    def n_tiles(self) -> int:
        return self.tiles.shape[0]

    @property
    def tile_size(self) -> int:
        return self.tiles.shape[2]


def dense_to_tiles(a, tile: int) -> TiledMatrix:
    n = a.shape[0]
    assert n % tile == 0
    t = n // tile
    tiles = a.reshape(t, tile, t, tile).transpose(0, 2, 1, 3)
    return TiledMatrix(tiles)


def tiles_to_dense(tm: TiledMatrix):
    t, _, b, _ = tm.tiles.shape
    return tm.tiles.transpose(0, 2, 1, 3).reshape(t * b, t * b)


def tiled_cholesky(tm: TiledMatrix) -> TiledMatrix:
    """Right-looking tiled Cholesky; mirrors build_cholesky_dag task order."""
    t = tm.n_tiles
    tiles = tm.tiles
    for k in range(t):
        lkk = ops.potrf(tiles[k, k])                       # POTRF(k)
        tiles = tiles.at[k, k].set(lkk)
        for i in range(k + 1, t):                          # TRSM(i, k)
            tiles = tiles.at[i, k].set(ops.trsm(lkk, tiles[i, k]))
        for i in range(k + 1, t):
            tiles = tiles.at[i, i].set(                    # SYRK(i, k)
                ops.syrk(tiles[i, k], tiles[i, i]))
            for j in range(k + 1, i):                      # GEMM(i, j, k)
                tiles = tiles.at[i, j].set(
                    ops.gemm(tiles[i, k], tiles[j, k].T,
                             tiles[i, j], alpha=-1.0))
    # zero strict upper tiles, lower-triangularize diagonal tiles
    for i in range(t):
        tiles = tiles.at[i, i].set(jnp.tril(tiles[i, i]))
        for j in range(i + 1, t):
            tiles = tiles.at[i, j].set(jnp.zeros_like(tiles[i, j]))
    return TiledMatrix(tiles)


def tiled_lu(tm: TiledMatrix) -> TiledMatrix:
    """Right-looking tiled LU (no pivoting), packed LU tiles."""
    t = tm.n_tiles
    tiles = tm.tiles
    b = tm.tile_size
    eye = jnp.eye(b, dtype=tiles.dtype)
    for k in range(t):
        lu_kk = ops.getrf(tiles[k, k])                     # GETRF(k)
        tiles = tiles.at[k, k].set(lu_kk)
        l_kk = jnp.tril(lu_kk, -1) + eye
        u_kk = jnp.triu(lu_kk)
        for j in range(k + 1, t):                          # TRSM_ROW(k, j)
            tiles = tiles.at[k, j].set(
                ref.trsm_ref(l_kk, tiles[k, j], side="left", trans=False,
                             unit_diag=True))
        for i in range(k + 1, t):                          # TRSM_COL(i, k)
            tiles = tiles.at[i, k].set(
                ref.trsm_upper_right_ref(u_kk, tiles[i, k]))
        for i in range(k + 1, t):
            for j in range(k + 1, t):                      # GEMM(i, j, k)
                tiles = tiles.at[i, j].set(
                    ops.gemm(tiles[i, k], tiles[k, j],
                             tiles[i, j], alpha=-1.0))
    return TiledMatrix(tiles)


def tiled_qr(tm: TiledMatrix) -> TiledMatrix:
    """Tiled Householder QR with flat reduction tree (returns R tiles).

    GEQRT/UNMQR factor+apply the diagonal tile's reflectors; TSQRT/SSRFB
    couple each sub-diagonal tile with the running R. Only R is kept
    (Q is validated via R^T R == A^T A in tests, the standard identity).
    """
    t = tm.n_tiles
    tiles = tm.tiles
    b = tm.tile_size
    for k in range(t):
        v, tt, rkk = ops.geqrt(tiles[k, k])                # GEQRT(k)
        tiles = tiles.at[k, k].set(rkk)
        for j in range(k + 1, t):                          # UNMQR(k, j)
            tiles = tiles.at[k, j].set(
                ops.apply_reflector(v, tt, tiles[k, j]))
        for i in range(k + 1, t):                          # TSQRT(i, k)
            stacked = jnp.concatenate([tiles[k, k], tiles[i, k]], axis=0)
            v2, t2, r2 = ops.geqrt(stacked)
            tiles = tiles.at[k, k].set(r2)
            tiles = tiles.at[i, k].set(jnp.zeros_like(tiles[i, k]))
            for j in range(k + 1, t):                      # SSRFB(i, j, k)
                c = jnp.concatenate([tiles[k, j], tiles[i, j]], axis=0)
                c = ops.apply_reflector(v2, t2, c)
                tiles = tiles.at[k, j].set(c[:b])
                tiles = tiles.at[i, j].set(c[b:])
    # R: zero everything below the diagonal tiles
    for i in range(t):
        tiles = tiles.at[i, i].set(jnp.triu(tiles[i, i]))
        for j in range(i):
            tiles = tiles.at[i, j].set(jnp.zeros_like(tiles[i, j]))
    return TiledMatrix(tiles)
