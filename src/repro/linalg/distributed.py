"""Distributed 2-D block-cyclic factorizations under shard_map.

The paper's experimental substrate: ScaLAPACK-style Cholesky / LU / QR over
a P x Q process grid (the paper's own runs use 16 x 16 = 256 processes).
Mapping onto TPU-native constructs (DESIGN.md S3.4):

    MPI rank (p, q)          -> mesh device at ("data"=p, "model"=q)
    block-cyclic tile owner  -> tile (i, j) lives on device (i % P, j % Q)
    panel broadcast (row)    -> masked psum over the "model" axis
    panel broadcast (col)    -> all_gather over the "data" axis
    QR tall-panel apply      -> psum of partial V^T C products over "data"
                                (the TSQR-free distributed Householder apply)

Layout.  A global tile array [T, T, b, b] is reordered so that *block*
sharding of the reordered array equals *cyclic* sharding of the original
(i -> (i % P) * (T//P) + i // P); `shard_map` over ("data", "model") then
hands every device its [T/P, T/Q, b, b] cyclic tile set. Inside the kernel,
global indices are recovered from `lax.axis_index`.

Algorithm (per iteration k, fully static Python loop -- the DAG the energy
core schedules is literally this unrolled loop):

  1. row-bcast:  devices in column k % Q contribute their column-k tiles;
     a masked psum over "model" gives every device the panel tiles for its
     own row subset (the MPI row broadcast).
  2. col-bcast:  all_gather over "data" assembles the full panel on every
     device (the MPI column broadcast).
  3. panel math: POTRF/GETRF/GEQRT of the (stacked) panel is computed
     REDUNDANTLY on every device -- the replicated-panel variant: on TPU,
     b^3 of redundant compute is far cheaper than serializing a panel tree
     over ICI (hardware adaptation of the paper's CPU panel, DESIGN.md S3).
  4. trailing update: batched masked GEMM over the local trailing tiles
     (one einsum over [Tp', Tq', b, b] -- MXU-shaped, no per-tile loop).

The trailing slice [li0:, lj0:] is the *static union* over ranks of tiles
with (gi > k, gj > k), so the update einsum shrinks as k advances even
though per-rank indices are dynamic; the residual waste is <= one tile
row/column per rank (see EXPERIMENTS.md S-Perf for the measured effect).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.kernels import ref

# ---------------------------------------------------------------- layout

def cyclic_perm(t: int, p: int) -> jnp.ndarray:
    """Permutation sending global tile index i to its block-sharded slot."""
    i = jnp.arange(t)
    return (i % p) * (t // p) + i // p


def to_block_cyclic(tiles: jax.Array, grid: tuple[int, int]) -> jax.Array:
    """[T, T, b, b] global tiles -> reordered so block sharding == cyclic."""
    t = tiles.shape[0]
    pr, pc = grid
    rp = jnp.argsort(cyclic_perm(t, pr))
    cp = jnp.argsort(cyclic_perm(t, pc))
    return tiles[rp][:, cp]


def from_block_cyclic(tiles: jax.Array, grid: tuple[int, int]) -> jax.Array:
    t = tiles.shape[0]
    pr, pc = grid
    return tiles[cyclic_perm(t, pr)][:, cyclic_perm(t, pc)]


# --------------------------------------------------------- panel assembly

def _gather_panel_col(tiles, k, t, pr, pc):
    """Full factor-column k ([T, b, b], global order) on every device.

    tiles: local [Tp, Tq, b, b]. Two hops: masked psum over "model" (row
    broadcast), all_gather over "data" (column broadcast).
    """
    q = jax.lax.axis_index("model")
    lj = k // pc                                  # local col of global col k
    cand = tiles[:, lj]                           # [Tp, b, b]
    mine = jnp.where(q == (k % pc), cand, jnp.zeros_like(cand))
    rows_mine = jax.lax.psum(mine, "model")       # row bcast: my rows' tiles
    gathered = jax.lax.all_gather(rows_mine, "data")   # [P, Tp, b, b]
    # global row i lives at gathered[i % P, i // P]
    gi = jnp.arange(t)
    return gathered[gi % pr, gi // pr], rows_mine


def _gather_panel_row(tiles, k, t, pr, pc):
    """Full factor-row k ([T, b, b]) on every device (LU's U panel)."""
    p = jax.lax.axis_index("data")
    li = k // pr
    cand = tiles[li]                              # [Tq, b, b]
    mine = jnp.where(p == (k % pr), cand, jnp.zeros_like(cand))
    cols_mine = jax.lax.psum(mine, "data")        # col bcast
    gathered = jax.lax.all_gather(cols_mine, "model")  # [Q, Tq, b, b]
    gj = jnp.arange(t)
    return gathered[gj % pc, gj // pc], cols_mine


def _local_rows(panel_full, pr, axis_name="data"):
    """Select a device's own rows from a [T, ...] global-order panel."""
    p = jax.lax.axis_index(axis_name)
    t = panel_full.shape[0]
    li = jnp.arange(t // pr)
    return jnp.take(panel_full, li * pr + p, axis=0)


def _local_cols(panel_full, pc):
    q = jax.lax.axis_index("model")
    t = panel_full.shape[0]
    lj = jnp.arange(t // pc)
    return jnp.take(panel_full, lj * pc + q, axis=0)


def _trail_start(k: int, p: int) -> int:
    """Smallest local index that can hold a global index > k (static)."""
    return max(0, (k + 2 - p) // p)


# ------------------------------------------------------------- Cholesky

def _cholesky_kernel(tiles, *, t: int, pr: int, pc: int):
    """Local kernel: tiles [Tp, Tq, b, b] (full symmetric matrix in, lower
    factor out -- upper tiles are garbage and zeroed by the wrapper)."""
    p = jax.lax.axis_index("data")
    q = jax.lax.axis_index("model")
    tp, tq = t // pr, t // pc
    gi_l = jnp.arange(tp) * pr + p                # my global rows  [Tp]
    gj_l = jnp.arange(tq) * pc + q                # my global cols  [Tq]

    for k in range(t):
        panel, _ = _gather_panel_col(tiles, k, t, pr, pc)   # [T, b, b]
        # --- redundant panel factorization -------------------------------
        lkk = ref.potrf_ref(panel[k])
        if k + 1 < t:
            lpan = jax.vmap(lambda a: ref.trsm_ref(lkk, a))(panel[k + 1:])
            panel_f = jnp.concatenate([lkk[None], lpan], axis=0)  # rows k..T
        else:
            panel_f = lkk[None]
        # --- write the factored column back into my tiles ----------------
        lj = k // pc
        col_rows = jnp.take(panel_f, jnp.clip(gi_l - k, 0, t - 1 - k), axis=0)
        write = (q == (k % pc)) & (gi_l >= k)
        tiles = tiles.at[:, lj].set(
            jnp.where(write[:, None, None], col_rows, tiles[:, lj]))
        # --- trailing update over the static union slice ------------------
        if k + 1 == t:
            break
        li0, lj0 = _trail_start(k, pr), _trail_start(k, pc)
        lrow = jnp.take(panel_f, jnp.clip(gi_l[li0:] - k, 0, t - 1 - k),
                        axis=0)                    # [Tp', b, b]
        lcol = jnp.take(panel_f, jnp.clip(gj_l[lj0:] - k, 0, t - 1 - k),
                        axis=0)                    # [Tq', b, b]
        upd = jnp.einsum("iab,jcb->ijac", lrow, lcol,
                         preferred_element_type=tiles.dtype)
        mask = (gi_l[li0:, None] > k) & (gj_l[None, lj0:] > k)
        tiles = tiles.at[li0:, lj0:].add(
            jnp.where(mask[..., None, None], -upd, 0.0))
    return tiles


def _lu_kernel(tiles, *, t: int, pr: int, pc: int):
    """Right-looking LU without pivoting (packed L\\U tiles)."""
    p = jax.lax.axis_index("data")
    q = jax.lax.axis_index("model")
    tp, tq = t // pr, t // pc
    gi_l = jnp.arange(tp) * pr + p
    gj_l = jnp.arange(tq) * pc + q
    b = tiles.shape[-1]
    eye = jnp.eye(b, dtype=tiles.dtype)

    for k in range(t):
        colp, _ = _gather_panel_col(tiles, k, t, pr, pc)
        lu_kk = ref.getrf_nopiv_ref(colp[k])
        l_kk = jnp.tril(lu_kk, -1) + eye
        u_kk = jnp.triu(lu_kk)
        if k + 1 < t:
            lpan = jax.vmap(lambda a: ref.trsm_upper_right_ref(u_kk, a))(
                colp[k + 1:])                     # L column below diag
            col_f = jnp.concatenate([lu_kk[None], lpan], axis=0)
        else:
            col_f = lu_kk[None]
        # write the L column (and packed diag) back
        lj = k // pc
        col_rows = jnp.take(col_f, jnp.clip(gi_l - k, 0, t - 1 - k), axis=0)
        write = (q == (k % pc)) & (gi_l >= k)
        tiles = tiles.at[:, lj].set(
            jnp.where(write[:, None, None], col_rows, tiles[:, lj]))
        if k + 1 == t:
            break
        # U row: needs the updated row k (TRSM with L_kk)
        rowp, _ = _gather_panel_row(tiles, k, t, pr, pc)
        urow = jax.vmap(lambda a: ref.trsm_ref(
            l_kk, a, side="left", trans=False, unit_diag=True))(rowp[k + 1:])
        row_f = jnp.concatenate([u_kk[None], urow], axis=0)   # cols k..T
        li = k // pr
        row_cols = jnp.take(row_f, jnp.clip(gj_l - k, 0, t - 1 - k), axis=0)
        writer = (p == (k % pr)) & (gj_l > k)     # diag already written
        tiles = tiles.at[li].set(
            jnp.where(writer[:, None, None], row_cols, tiles[li]))
        # trailing update: A[i, j] -= L[i, k] @ U[k, j]
        li0, lj0 = _trail_start(k, pr), _trail_start(k, pc)
        lrow = jnp.take(col_f, jnp.clip(gi_l[li0:] - k, 0, t - 1 - k), axis=0)
        ucol = jnp.take(row_f, jnp.clip(gj_l[lj0:] - k, 0, t - 1 - k), axis=0)
        upd = jnp.einsum("iab,jbc->ijac", lrow, ucol,
                         preferred_element_type=tiles.dtype)
        mask = (gi_l[li0:, None] > k) & (gj_l[None, lj0:] > k)
        tiles = tiles.at[li0:, lj0:].add(
            jnp.where(mask[..., None, None], -upd, 0.0))
    return tiles


def _qr_kernel(tiles, *, t: int, pr: int, pc: int,
               panel: str = "householder"):
    """QR with a replicated tall panel + distributed compact-WY apply.

    Per iteration: the full panel column (rows k..T-1, one b-wide block) is
    assembled on every device and factorized redundantly (compact WY); the
    trailing update C := (I - V T V^T)^T C runs distributed -- each device
    row holds a slice of V and C, the inner product W = V^T C is a psum
    over "data", and the rank-b correction is applied locally. Returns R in
    the upper triangle (V is consumed; tests validate R^T R == A^T A).

    panel: "householder" (PLASMA-faithful, HBM-bound at big b) or
    "cholqr2" (CholeskyQR2 + Yamamoto WY reconstruction, ~4 panel passes;
    the S-Perf hillclimbed variant). Both produce identical trailing-update
    structure -- only the panel math differs.
    """
    p = jax.lax.axis_index("data")
    q = jax.lax.axis_index("model")
    tp, tq = t // pr, t // pc
    gi_l = jnp.arange(tp) * pr + p
    gj_l = jnp.arange(tq) * pc + q
    b = tiles.shape[-1]
    panel_qr = ref.cholqr2 if panel == "cholqr2" else ref.householder_qr

    for k in range(t):
        panel_col, _ = _gather_panel_col(tiles, k, t, pr, pc)   # [T, b, b]
        m = (t - k) * b
        stacked = panel_col[k:].reshape(m, b)
        v_full, t_mat, r_kk = panel_qr(stacked)
        # write R_kk at the diagonal owner, zero the column below
        lj = k // pc
        new_col = jnp.where((gi_l == k)[:, None, None], r_kk[None],
                            jnp.where((gi_l > k)[:, None, None],
                                      jnp.zeros((), tiles.dtype),
                                      tiles[:, lj]))
        tiles = tiles.at[:, lj].set(
            jnp.where(q == (k % pc), new_col, tiles[:, lj]))
        if k + 1 == t:
            break
        # my V rows: global row gi maps to stacked rows (gi - k) * b ...
        vt = v_full.reshape(t - k, b, b)                     # per-tile V
        v_mine = jnp.take(vt, jnp.clip(gi_l - k, 0, t - 1 - k), axis=0)
        v_mine = jnp.where((gi_l >= k)[:, None, None], v_mine, 0.0)  # [Tp,b,b]
        # distributed apply to trailing local columns
        lj0 = _trail_start(k, pc)
        c = tiles[:, lj0:]                                   # [Tp, Tq', b, b]
        w_part = jnp.einsum("iab,ijac->jbc", v_mine, c)      # [Tq', b, b]
        w = jax.lax.psum(w_part, "data")                     # V^T C
        y = jnp.einsum("ab,jbc->jac", t_mat.T, w)            # T^T W
        corr = jnp.einsum("iab,jbc->ijac", v_mine, y)
        cmask = (gj_l[None, lj0:] > k) & (gi_l[:, None] >= k)
        tiles = tiles.at[:, lj0:].add(
            jnp.where(cmask[..., None, None], -corr, 0.0))
    return tiles


_KERNELS = {
    "cholesky": _cholesky_kernel,
    "lu": _lu_kernel,
    "qr": _qr_kernel,
    "qr-cholqr2": functools.partial(_qr_kernel, panel="cholqr2"),
}


# ------------------------------------------------------------- public API

def distributed_factorize(name: str, tiles_bc: jax.Array, mesh: Mesh):
    """Factorize a block-cyclic-reordered tile array on a ("data","model")
    mesh. tiles_bc: [T, T, b, b] (see to_block_cyclic). Returns the factor
    tiles in the same block-cyclic order."""
    pr, pc = (dict(zip(mesh.axis_names, mesh.devices.shape))["data"],
              dict(zip(mesh.axis_names, mesh.devices.shape))["model"])
    t = tiles_bc.shape[0]
    assert t % pr == 0 and t % pc == 0, (t, pr, pc)
    kern = functools.partial(_KERNELS[name], t=t, pr=pr, pc=pc)
    spec = P("data", "model", None, None)
    fn = shard_map(kern, mesh=mesh, in_specs=(spec,), out_specs=spec)
    return fn(tiles_bc)


def factorize(name: str, a: jax.Array, tile: int, mesh: Mesh) -> jax.Array:
    """End-to-end: dense [N, N] -> factor [N, N] on the mesh.

    cholesky -> lower L; lu -> packed L\\U (no pivoting); qr -> R (upper).
    """
    n = a.shape[0]
    assert n % tile == 0
    t = n // tile
    tiles = a.reshape(t, tile, t, tile).transpose(0, 2, 1, 3)
    grid = (dict(zip(mesh.axis_names, mesh.devices.shape))["data"],
            dict(zip(mesh.axis_names, mesh.devices.shape))["model"])
    bc = to_block_cyclic(tiles, grid)
    bc = jax.device_put(bc, NamedSharding(mesh, P("data", "model")))
    out_bc = distributed_factorize(name, bc, mesh)
    out = from_block_cyclic(out_bc, grid)
    dense = out.transpose(0, 2, 1, 3).reshape(n, n)
    if name == "cholesky":
        return jnp.tril(dense)
    if name.startswith("qr"):
        return jnp.triu(dense)
    return dense


def dryrun_cell(name: str, n: int, tile: int, mesh: Mesh, dtype=jnp.float32):
    """(fn, abstract args, shardings) for lowering on the production mesh."""
    t = n // tile
    kern = functools.partial(
        _KERNELS[name], t=t,
        pr=dict(zip(mesh.axis_names, mesh.devices.shape))["data"],
        pc=dict(zip(mesh.axis_names, mesh.devices.shape))["model"])
    spec = P("data", "model", None, None)
    fn = shard_map(kern, mesh=mesh, in_specs=(spec,), out_specs=spec)
    abstract = jax.ShapeDtypeStruct((t, t, tile, tile), dtype)
    shard = NamedSharding(mesh, spec)
    return fn, (abstract,), (shard,), shard
