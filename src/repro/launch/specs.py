"""Input specs + sharding derivation for every (architecture x shape) cell.

`input_specs(arch, shape)` returns ShapeDtypeStruct stand-ins for every
model input -- weak-type-correct, shardable, no device allocation -- and
`make_cell(...)` assembles the (step_fn, args, in_shardings, out_shardings,
donate) tuple that both the dry-run and the roofline consume.

Workload kinds:
    train    -> train_step(params, opt_state, batch)
    prefill  -> prefill(params, batch, cache)
    decode   -> decode_step(params, token, cache, pos)  with a seq_len cache
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs import ShapeSpec, get_config
from repro.models import ModelApi, get_model
from repro.models.config import ModelConfig
from repro.sharding.rules import Rules, make_rules, spec_for
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import make_train_step, train_state_specs

from .mesh import data_axes, mesh_axis_sizes

_TUPLE = lambda x: isinstance(x, tuple)  # noqa: E731


# ----------------------------------------------------------------- helpers
def adapt_rules_for_batch(rules: Rules, mesh: Mesh, global_batch: int) -> Rules:
    """Shrink the batch mapping to the largest prefix of the data axes that
    divides global_batch (long_500k has batch=1: fully replicated)."""
    sizes = mesh_axis_sizes(mesh)
    axes = rules.get("batch") or ()
    if isinstance(axes, str):
        axes = (axes,)
    kept: list[str] = []
    prod = 1
    for ax in axes:
        if global_batch % (prod * sizes[ax]) == 0:
            kept.append(ax)
            prod *= sizes[ax]
        else:
            break
    out = dict(rules)
    out["batch"] = tuple(kept) if kept else None
    out["moe_groups"] = out["batch"]
    return out


def shardings_of(tree_axes, rules: Rules, mesh: Mesh):
    """Logical-axes pytree -> NamedSharding pytree."""
    return jax.tree.map(
        lambda ax: NamedSharding(mesh, spec_for(ax, rules)),
        tree_axes, is_leaf=_TUPLE)


def _batch_axes(cfg: ModelConfig, kind: str) -> dict:
    """Logical axes of the input batch dict (matches data.py layout)."""
    if kind == "train":
        axes = {"tokens": ("batch", None), "labels": ("batch", None)}
        if cfg.frontend == "audio":
            axes["audio_embeds"] = ("batch", None, None)
        if cfg.frontend == "vision":
            axes["vision_embeds"] = ("batch", None, None)
        return axes
    if kind == "prefill":
        axes = {"tokens": ("batch", None)}
        if cfg.frontend == "audio":
            axes["audio_embeds"] = ("batch", None, None)
        if cfg.frontend == "vision":
            axes["vision_embeds"] = ("batch", None, None)
        return axes
    raise ValueError(kind)


def _batch_abstract(cfg: ModelConfig, shape: ShapeSpec, kind: str) -> dict:
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if kind == "train":
        out = {"tokens": tok, "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    else:
        out = {"tokens": tok}
    if cfg.frontend == "audio":
        out["audio_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_len, cfg.d_model), jnp.float32)
    if cfg.frontend == "vision":
        n_pre = min(cfg.frontend_len or 0, s // 2) or 1
        out["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, n_pre, cfg.d_model), jnp.float32)
    return out


def input_specs(arch: str, shape_name: str,
                shapes: dict[str, ShapeSpec] | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for every input of the cell's step."""
    from repro.configs import SHAPES
    cfg = get_config(arch)
    shape = (shapes or SHAPES)[shape_name]
    api = get_model(cfg)
    if shape.kind == "train":
        return _batch_abstract(cfg, shape, "train")
    if shape.kind == "prefill":
        return _batch_abstract(cfg, shape, "prefill")
    # decode: one new token against a seq_len cache
    return {
        "token": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
        "cache": api.init_cache(shape.global_batch, shape.seq_len, "abstract"),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


# ------------------------------------------------------------------- cells
@dataclasses.dataclass
class Cell:
    """Everything needed to lower one (arch x shape x mesh) combination."""
    arch: str
    shape: ShapeSpec
    cfg: ModelConfig
    fn: Callable
    args: tuple
    in_shardings: Any
    out_shardings: Any
    donate_argnums: tuple[int, ...]
    rules: Rules


def default_microbatches(cfg: ModelConfig, shape: ShapeSpec,
                         mesh: Mesh) -> int:
    """Gradient-accumulation factor so the remat layer-boundary activations
    (layers x per-device-batch x seq x d_model, bf16) stay under ~8 GiB of
    a 16 GiB v5e HBM. Powers of two only; must divide the per-device batch."""
    if shape.kind != "train":
        return 1
    sizes = mesh_axis_sizes(mesh)
    dp = 1
    for ax in data_axes(mesh):
        dp *= sizes[ax]
    per_dev_b = max(shape.global_batch // dp, 1)
    layers = cfg.n_layers + cfg.encoder_layers
    act_gb = layers * per_dev_b * shape.seq_len * cfg.d_model * 2 / 2**30
    n = 1
    while act_gb / n > 8.0 and n < min(16, per_dev_b):
        n *= 2
    return n


def make_cell(arch: str, shape: ShapeSpec, mesh: Mesh, *,
              opt_cfg: AdamWConfig | None = None,
              cfg: ModelConfig | None = None,
              rules: Rules | None = None,
              n_microbatches: int | None = None) -> Cell:
    """Assemble the lowering inputs of one (arch x shape x mesh) cell.

    Builds the step function for the shape's kind (train step with
    gradient accumulation, prefill, or single-token decode), the
    abstract argument tree, and the in/out shardings from the arch's
    sharding rules adapted to the mesh and batch.

    Parameters
    ----------
    arch : str
        Architecture key (a `repro.configs.ARCHS` name).
    shape : repro.configs.ShapeSpec
        Input shape (kind selects the step function).
    mesh : jax.sharding.Mesh
        Target mesh.
    opt_cfg, cfg, rules : optional
        Override the default optimizer config, model config, or
        sharding rules.
    n_microbatches : int, optional
        Gradient-accumulation factor (train only; defaults to
        `default_microbatches`).

    Returns
    -------
    Cell
        Everything `jax.jit(...).lower(...)` needs (fn, args,
        shardings, donations, rules).
    """
    cfg = cfg or get_config(arch)
    api = get_model(cfg)
    if rules is None:
        rules = make_rules(cfg, mesh, workload=shape.kind,
                           seq_len=shape.seq_len)
    rules = adapt_rules_for_batch(rules, mesh, shape.global_batch)

    params_abs = api.param_tree("abstract")
    params_axes = api.param_tree("axes")
    params_shard = shardings_of(params_axes, rules, mesh)
    repl = NamedSharding(mesh, PartitionSpec())

    if shape.kind == "train":
        opt_cfg = opt_cfg or default_opt_for(cfg)
        pspec, opt_spec = train_state_specs(api, opt_cfg, rules)
        opt_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), opt_spec,
                                 is_leaf=lambda x: isinstance(x, PartitionSpec))
        opt_abs = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_abs)
        batch_abs = _batch_abstract(cfg, shape, "train")
        batch_shard = shardings_of(_batch_axes(cfg, "train"), rules, mesh)
        if n_microbatches is None:
            n_microbatches = default_microbatches(cfg, shape, mesh)
        step = make_train_step(api, opt_cfg, n_microbatches=n_microbatches)
        metrics_shard = {"loss": repl, "grad_norm": repl, "lr": repl}
        return Cell(arch, shape, cfg, step,
                    (params_abs, opt_abs, batch_abs),
                    (params_shard, opt_shard, batch_shard),
                    (params_shard, opt_shard, metrics_shard),
                    donate_argnums=(0, 1), rules=rules)

    cache_abs = api.init_cache(shape.global_batch, shape.seq_len, "abstract")
    cache_axes = api.init_cache(shape.global_batch, shape.seq_len, "axes")
    cache_shard = shardings_of(cache_axes, rules, mesh)

    if shape.kind == "prefill":
        batch_abs = _batch_abstract(cfg, shape, "prefill")
        batch_shard = shardings_of(_batch_axes(cfg, "prefill"), rules, mesh)

        def prefill_step(params, batch, cache):
            return api.prefill(params, batch, cache)

        return Cell(arch, shape, cfg, prefill_step,
                    (params_abs, batch_abs, cache_abs),
                    (params_shard, batch_shard, cache_shard),
                    None, donate_argnums=(2,), rules=rules)

    # decode
    token_abs = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    batch_spec = spec_for(("batch", None), rules)
    token_shard = NamedSharding(mesh, batch_spec)
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)

    def decode(params, token, cache, pos):
        return api.decode_step(params, token, cache, pos)

    return Cell(arch, shape, cfg, decode,
                (params_abs, token_abs, cache_abs, pos_abs),
                (params_shard, token_shard, cache_shard, repl),
                None, donate_argnums=(2,), rules=rules)


def default_opt_for(cfg: ModelConfig) -> AdamWConfig:
    """Optimizer-state dtype policy: the two ~300B-class archs need bf16
    moments + no master copy to fit a 256-chip pod (EXPERIMENTS.md S-Dry-run
    memory table); everything else trains with fp32 state."""
    big = cfg.param_count() > 60e9
    if big:
        return AdamWConfig(m_dtype="bfloat16", v_dtype="bfloat16",
                           master_dtype=None)
    return AdamWConfig()
