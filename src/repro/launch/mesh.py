"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state, so smoke tests keep seeing 1 CPU device while the dry-run
(which sets XLA_FLAGS before any jax import) sees its 512 placeholders.

Production topology (TPU v5e target):
    single pod:  (16, 16)    axes ("data", "model")   = 256 chips
    multi-pod:   (2, 16, 16) axes ("pod", "data", "model") = 512 chips

"model" is the tensor/expert-parallel axis (intra-pod ICI rings);
"data" is data/FSDP; "pod" is the cross-pod data-parallel axis (DCN) --
gradients all-reduce over ("pod", "data"), weights FSDP-shard over the same.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_factorization_mesh(p: int = 16, q: int = 16) -> jax.sharding.Mesh:
    """P x Q process grid for the distributed factorizations (the paper's own
    experiment uses 16 x 16 = 256 processes)."""
    return jax.make_mesh((p, q), ("data", "model"))


def mesh_axis_sizes(mesh: jax.sharding.Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def data_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    return tuple(n for n in mesh.axis_names if n != "model")


def n_chips(mesh: jax.sharding.Mesh) -> int:
    return int(mesh.devices.size)
