"""Model-zoo roofline generator: the reproducible pipeline behind the
committed `results/roofline.json` artifact (docs/ROOFLINE.md).

For every config in `repro.configs.ARCHS` x three phases (train /
prefill / decode), this module:

  1. builds the roofline-representative `make_zoo` reduction (real
     widths, one layer-pattern period -- per-layer arithmetic intensity
     matches the production model),
  2. lowers + compiles the cell on a fixed 2x4 ("data", "model") host
     mesh (8 fake CPU devices, `JAX_PLATFORMS=cpu`),
  3. runs the trip-count-aware HLO analyzer (`launch/hlo_analysis`) on
     the compiled module and converts per-device dot flops / HBM bytes /
     collective bytes into the three roofline seconds terms, and
  4. derives the phase's frequency-sensitivity beta
     (`core.roofline_model.beta_from_terms`).

Everything is static compiler analysis -- nothing executes -- so the
output is deterministic for a pinned jax version and runs in a few
minutes on CPU. CI regenerates the artifact on every push and fails on
drift (`--check`); the nightly workflow uploads the fresh output.

Usage:
    python -m repro.launch.zoo --out results/roofline.json
    python -m repro.launch.zoo --check              # drift gate (CI)
    python -m repro.launch.zoo --arch gemma2-2b --out /tmp/one.json
"""

import argparse
import json
import os
import time

import numpy as np

from repro.configs import ShapeSpec, get_config, list_archs, make_zoo
from repro.core.roofline_model import BETA_FLOOR, PHASES, beta_from_terms
from repro.launch import hlo_analysis
# Importing dryrun forces >= 512 fake host devices before jax's first init
# (its module header runs pre-import); the zoo mesh slices the first 8.
from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_FLOPS

SCHEMA = "roofline/v2"
DCN_BW = 25e9                      # cross-pod bytes/s (matches roofline.py)
ZOO_MESH_SHAPE = (2, 2 * 2)        # 2x4 ("data", "model"), 8 devices
ZOO_AXES = ("data", "model")
CHIPS_PER_POD = 256

#: Per-phase input shapes: large enough that per-layer arithmetic
#: intensity is meaningful (1024-token sequences), small enough that
#: every cell compiles in ~a second on CPU.
ZOO_SHAPES: dict[str, ShapeSpec] = {
    "train": ShapeSpec("zoo_train", 1024, 8, "train"),
    "prefill": ShapeSpec("zoo_prefill", 1024, 8, "prefill"),
    "decode": ShapeSpec("zoo_decode", 1024, 8, "decode"),
}


def _sig(x: float, digits: int = 6) -> float:
    """Round to `digits` significant digits (stable JSON output)."""
    if x == 0.0:
        return 0.0
    return float(f"{x:.{digits}g}")


def _zoo_mesh():
    """The fixed 2x4 ("data", "model") mesh on the first 8 host devices."""
    import jax

    n = int(np.prod(ZOO_MESH_SHAPE))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"zoo mesh needs {n} devices, found {len(devs)}; import "
            "repro.launch.zoo before jax's first init (its dryrun import "
            "forces the fake host device count)")
    from jax.sharding import Mesh
    return Mesh(np.asarray(devs[:n]).reshape(ZOO_MESH_SHAPE), ZOO_AXES)


def zoo_row(arch: str, phase: str, mesh=None) -> dict:
    """Compile one (arch, phase) zoo cell and measure its roofline row.

    Lowers + compiles the `make_zoo` reduction of `arch` for the phase's
    `ZOO_SHAPES` input on the 2x4 host mesh, runs the trip-count-aware
    HLO analyzer on the compiled module, converts the per-device counts
    into roofline seconds at the TPU-v5e constants, and derives the
    phase beta. Pure static analysis: nothing executes.

    Parameters
    ----------
    arch : str
        Architecture key (a `repro.configs.ARCHS` name).
    phase : str
        One of `core.roofline_model.PHASES` ("train" / "prefill" /
        "decode").
    mesh : jax.sharding.Mesh, optional
        Compile mesh; defaults to the fixed 2x4 zoo mesh.

    Returns
    -------
    dict
        One `results/roofline.json` row (see docs/ROOFLINE.md for the
        schema): identity, per-device counts, the three `*_s` terms,
        `bottleneck`, `arithmetic_intensity`, `beta`,
        `flops_per_token`, and compile timings.
    """
    import jax

    from repro.launch.specs import make_cell
    from repro.sharding.rules import use_sharding

    mesh = mesh if mesh is not None else _zoo_mesh()
    shape = ZOO_SHAPES[phase]
    cfg = make_zoo(get_config(arch))
    n_devices = mesh.devices.size

    t0 = time.time()
    cell = make_cell(arch, shape, mesh, cfg=cfg)
    with use_sharding(mesh, cell.rules):
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate_argnums)
        compiled = jitted.lower(*cell.args).compile()
    compile_s = time.time() - t0
    cost = hlo_analysis.analyze(compiled.as_text(), n_devices=n_devices,
                                chips_per_pod=CHIPS_PER_POD)

    compute_s = cost.dot_flops / PEAK_FLOPS
    memory_s = cost.hbm_bytes / HBM_BW
    collective_s = cost.ici_bytes / ICI_BW + cost.dcn_bytes / DCN_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bottleneck = max(terms, key=lambda k: terms[k])

    tokens = (shape.global_batch if shape.kind == "decode"
              else shape.global_batch * shape.seq_len)
    n_active = cfg.active_param_count()
    model_flops = (6.0 if shape.kind == "train" else 2.0) * n_active * tokens
    total_dot = cost.dot_flops * n_devices
    return {
        "arch": arch,
        "family": cfg.family,
        "phase": phase,
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "tokens": tokens,
        "dot_flops_per_device": _sig(cost.dot_flops),
        "hbm_bytes_per_device": _sig(cost.hbm_bytes),
        "ici_bytes_per_device": _sig(cost.ici_bytes),
        "dcn_bytes_per_device": _sig(cost.dcn_bytes),
        "compute_s": _sig(compute_s),
        "memory_s": _sig(memory_s),
        "collective_s": _sig(collective_s),
        "step_s_lower_bound": _sig(max(terms.values())),
        "bottleneck": bottleneck,
        "arithmetic_intensity": _sig(cost.dot_flops / cost.hbm_bytes
                                     if cost.hbm_bytes else 0.0),
        "beta": _sig(beta_from_terms(compute_s, memory_s, collective_s)),
        "flops_per_token": _sig(total_dot / tokens if tokens else 0.0),
        "model_flops_global": _sig(model_flops),
        "useful_flop_ratio": _sig(model_flops / total_dot
                                  if total_dot else 0.0),
        "n_while": cost.n_while,
        "compile_s": round(compile_s, 2),
    }


def generate(archs: tuple[str, ...] | None = None,
             phases: tuple[str, ...] = PHASES,
             verbose: bool = True) -> dict:
    """Generate the full roofline document for the model zoo.

    Parameters
    ----------
    archs : tuple[str, ...], optional
        Architectures to measure; defaults to every `ARCHS` entry.
    phases : tuple[str, ...]
        Phases per architecture (default: train / prefill / decode).
    verbose : bool
        Print one progress line per cell.

    Returns
    -------
    dict
        The ``roofline/v2`` document: generator metadata (mesh, device
        count, hardware constants, beta floor) plus one row per
        (arch, phase) under ``"rows"``.
    """
    import jax

    mesh = _zoo_mesh()
    rows = []
    for arch in (archs or tuple(list_archs())):
        for phase in phases:
            row = zoo_row(arch, phase, mesh)
            rows.append(row)
            if verbose:
                print(f"[zoo] {arch:22s} {phase:8s} compile={row['compile_s']:6.1f}s "
                      f"bound={row['bottleneck']:13s} beta={row['beta']:.3f}")
    return {
        "schema": SCHEMA,
        "generator": "python -m repro.launch.zoo --out results/roofline.json",
        "jax_version": jax.__version__,
        "mesh": "x".join(str(s) for s in ZOO_MESH_SHAPE),
        "n_devices": int(np.prod(ZOO_MESH_SHAPE)),
        "chips_per_pod": CHIPS_PER_POD,
        "hardware": {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW,
                     "ici_bw": ICI_BW, "dcn_bw": DCN_BW},
        "beta_floor": BETA_FLOOR,
        "rows": rows,
    }


#: Numeric row fields compared by `check` under --rtol (float drift from
#: compiler-version or host differences); `beta` is compared absolutely
#: and identity/bottleneck fields exactly.
_CHECK_REL_FIELDS = ("dot_flops_per_device", "hbm_bytes_per_device",
                     "ici_bytes_per_device", "compute_s", "memory_s",
                     "collective_s", "step_s_lower_bound",
                     "arithmetic_intensity", "flops_per_token")


def check(path: str, archs: tuple[str, ...] | None = None,
          rtol: float = 0.05, beta_atol: float = 0.05) -> list[str]:
    """Regenerate the zoo rows and diff them against a committed artifact.

    Parameters
    ----------
    path : str
        The committed `results/roofline.json`.
    archs : tuple[str, ...], optional
        Restrict the regeneration (e.g. one arch for a quick gate).
    rtol : float
        Allowed relative drift on the numeric fields
        (`_CHECK_REL_FIELDS`); identity fields and `bottleneck` must
        match exactly, `beta` within `beta_atol`.
    beta_atol : float
        Allowed absolute drift on the derived beta.

    Returns
    -------
    list[str]
        Human-readable drift descriptions; empty when the committed
        artifact is up to date.
    """
    with open(path) as f:
        committed = json.load(f)
    if not isinstance(committed, dict) or "rows" not in committed:
        return [f"{path} is not a {SCHEMA} document"]
    want = {(r["arch"], r["phase"]): r for r in committed["rows"]}
    if archs is None:
        archs = tuple(dict.fromkeys(r["arch"] for r in committed["rows"]))
    fresh = generate(archs=archs)
    drift: list[str] = []
    for row in fresh["rows"]:
        key = (row["arch"], row["phase"])
        old = want.get(key)
        if old is None:
            drift.append(f"{key}: missing from committed artifact")
            continue
        if old["bottleneck"] != row["bottleneck"]:
            drift.append(f"{key}: bottleneck {old['bottleneck']} -> "
                         f"{row['bottleneck']}")
        if abs(old["beta"] - row["beta"]) > beta_atol:
            drift.append(f"{key}: beta {old['beta']} -> {row['beta']}")
        for field in _CHECK_REL_FIELDS:
            o, n = float(old[field]), float(row[field])
            denom = max(abs(o), abs(n), 1e-30)
            if abs(o - n) / denom > rtol:
                drift.append(f"{key}: {field} {o:g} -> {n:g}")
    missing = set(want) - {(r["arch"], r["phase"]) for r in fresh["rows"]}
    if archs is None or set(archs) >= {a for a, _ in want}:
        for key in sorted(missing):
            drift.append(f"{key}: committed but no longer generated")
    return drift


def main() -> None:
    """CLI: generate (`--out`), or gate drift against a committed file
    (`--check`)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append",
                    help="restrict to these archs (repeatable)")
    ap.add_argument("--out", default=None,
                    help="write the roofline/v2 JSON here")
    ap.add_argument("--check", nargs="?", const="results/roofline.json",
                    default=None, metavar="JSON",
                    help="regenerate and fail on drift vs this artifact "
                         "(default results/roofline.json)")
    ap.add_argument("--rtol", type=float, default=0.05,
                    help="--check relative tolerance on numeric fields")
    args = ap.parse_args()

    archs = tuple(args.arch) if args.arch else None
    if args.check is not None:
        drift = check(args.check, archs=archs, rtol=args.rtol)
        if drift:
            print(f"[zoo] {len(drift)} drift(s) vs {args.check}:")
            for line in drift:
                print("  ", line)
            print("[zoo] regenerate with: python -m repro.launch.zoo "
                  f"--out {args.check}")
            raise SystemExit(1)
        print(f"[zoo] {args.check} is up to date")
        return

    doc = generate(archs=archs)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        print(f"[zoo] wrote {len(doc['rows'])} rows -> {args.out}")
    else:
        print(json.dumps(doc, indent=1))


if __name__ == "__main__":
    main()
