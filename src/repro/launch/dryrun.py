"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes, print memory/cost analysis, and persist the
roofline terms.

Usage:
    python -m repro.launch.dryrun --arch mamba2-370m --shape train_4k
    python -m repro.launch.dryrun --all                    # every live cell
    python -m repro.launch.dryrun --all --mesh multi_pod   # 2x16x16
    python -m repro.launch.dryrun --all --out results/dryrun.json

Success here proves the distribution config is coherent: sharding
mismatches, compile-time OOM, or unsupported collectives all surface as
hard failures. The compiled artifact's cost analysis feeds EXPERIMENTS.md
S-Roofline (launch/roofline.py) and the model-zoo roofline generator
(launch/zoo.py, docs/ROOFLINE.md)."""

import os
os.environ["XLA_FLAGS"] = os.environ.get("REPRO_XLA_FLAGS_EXTRA", "") + \
    " --xla_force_host_platform_device_count=512"
# ^ MUST run before any other import (jax locks device count on first init).

import argparse       # noqa: E402
import json           # noqa: E402
import re             # noqa: E402
import time           # noqa: E402
import traceback      # noqa: E402

import jax            # noqa: E402

from repro.configs import SHAPES, all_cells, cell_applicable, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh, n_chips  # noqa: E402
from repro.launch.specs import make_cell                     # noqa: E402
from repro.sharding.rules import use_sharding                # noqa: E402

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT )?(%?[\w.\-]+) = (.+)$")
_OPERAND_REF_RE = re.compile(r"%?([\w.\-]+)")


def _shape_bytes(type_str: str) -> int:
    """'bf16[16,4096,5120]{2,1,0}' -> bytes; sums every shape expression in
    the string (tuples / multiple operands), ignoring non-dtype brackets."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-device bytes moved through each collective op family, summed over
    the module. Operand sizes are parsed from the instruction body (XLA
    prints operand shapes inline); `*-start` variants are counted, their
    `*-done` halves are not (avoids double counting async pairs)."""
    sizes: dict[str, int] = {}
    per_op: dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, body = m.groups()
        sizes[name.lstrip("%")] = _shape_bytes(body.split(" ", 1)[0])
        for op in COLLECTIVE_OPS:
            marker = None
            for cand in (f" {op}(", f" {op}-start("):
                if cand in body:
                    marker = cand
                    break
            if marker is None:
                continue
            operand_str = body.split(marker, 1)[1]
            operand_str = operand_str.split("),", 1)[0]   # strip attributes
            operand_bytes = _shape_bytes(operand_str)
            if operand_bytes == 0:                        # name-only operands
                for ref in _OPERAND_REF_RE.findall(operand_str):
                    operand_bytes += sizes.get(ref, 0)
            per_op[op] += operand_bytes
            break
    return per_op


# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link


def roofline_terms(flops_dev: float, bytes_dev: float,
                   coll_dev: float) -> dict[str, float]:
    """Three-term roofline from *per-device* quantities (the SPMD module is
    the per-device program; multiplying by chips and dividing by chips*peak
    cancels).

    Parameters
    ----------
    flops_dev : float
        Dot flops per device (MXU term).
    bytes_dev : float
        HBM bytes per device.
    coll_dev : float
        ICI collective bytes per device.

    Returns
    -------
    dict[str, float]
        ``compute_s`` / ``memory_s`` / ``collective_s`` at the TPU-v5e
        constants, plus ``bottleneck`` (argmax key) and
        ``step_s_lower_bound`` (the max term).
    """
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll_dev / ICI_BW,
    }
    terms["bottleneck"] = max(terms, key=lambda k: terms[k])
    terms["step_s_lower_bound"] = max(terms["compute_s"], terms["memory_s"],
                                      terms["collective_s"])
    return terms


def run_cell(arch: str, shape_name, mesh, *, verbose: bool = True,
             hlo_out: str | None = None, cfg=None, rules=None,
             opt_cfg=None) -> dict:
    """Lower + compile one (arch x shape x mesh) cell and report its costs.

    The dry-run workhorse: builds the cell (`specs.make_cell`), jits and
    compiles it under the mesh's sharding rules, and collects XLA's raw
    cost/memory analysis, the per-family collective bytes from the HLO
    text, and the model-flops accounting. Nothing executes -- success
    proves the distribution config is coherent at this scale.

    Parameters
    ----------
    arch : str
        Architecture key (a `repro.configs.ARCHS` name).
    shape_name : str or repro.configs.ShapeSpec
        A `repro.configs.SHAPES` key, or a `ShapeSpec` directly (e.g.
        the zoo generator's reduced phase shapes).
    mesh : jax.sharding.Mesh
        Compile mesh (`mesh.make_production_mesh` or any custom mesh).
    verbose : bool
        Print the per-cell summary block.
    hlo_out : str, optional
        Write the compiled module text here (feeds
        `roofline.corrected_terms` / `hlo_analysis.analyze_file`).
    cfg, rules, opt_cfg : optional
        Overrides forwarded to `specs.make_cell` (default: the arch's
        registered config and sharding rules).

    Returns
    -------
    dict
        One dry-run record: identity, lower/compile timings, per-device
        flop/byte/collective counts, `model_flops_global`, and
        `useful_flop_ratio` (also a `results/dryrun.json` row).
    """
    if isinstance(shape_name, str):
        shape = SHAPES[shape_name]
    else:
        shape, shape_name = shape_name, shape_name.name
    cell = make_cell(arch, shape, mesh, cfg=cfg, rules=rules, opt_cfg=opt_cfg)
    t0 = time.time()
    with use_sharding(mesh, cell.rules):
        jitted = jax.jit(cell.fn,
                         in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate_argnums)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # some jax builds wrap in a list
        cost = cost[0] if cost else {}
    try:
        mem = compiled.memory_analysis()
    except Exception:
        mem = None
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    if hlo_out:
        with open(hlo_out, "w") as f:
            f.write(hlo)

    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll_dev = float(sum(coll.values()))
    chips = n_chips(mesh)
    cfg_ = cell.cfg

    # 6*N*D model flops (D = tokens for train incl. backward 3x factor;
    # decode/prefill use forward-only 2*N*D)
    n_active = cfg_.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens
    else:
        tokens = shape.global_batch * 1
        model_flops = 2.0 * n_active * tokens

    out = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": chips,
        "kind": shape.kind,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "collectives": {k: v for k, v in coll.items() if v},
        "model_flops_global": model_flops,
        "useful_flop_ratio": (model_flops / (flops_dev * chips)
                              if flops_dev else 0.0),
        **roofline_terms(flops_dev, bytes_dev, coll_dev),
    }
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                out[f"mem_{attr}"] = int(v)
    if verbose:
        print(f"[dryrun] {arch:22s} {shape_name:12s} mesh={out['mesh']:9s} "
              f"lower={t_lower:6.1f}s compile={t_compile:6.1f}s "
              f"flops/dev={flops_dev:.3e} bytes/dev={bytes_dev:.3e} "
              f"coll/dev={coll_dev:.3e} -> {out['bottleneck']}")
        if mem is not None:
            print(f"         memory_analysis: "
                  f"args={out.get('mem_argument_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"temps={out.get('mem_temp_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"out={out.get('mem_output_size_in_bytes', 0)/2**30:.2f}GiB")
    return out


def _write_out(out_path: str | None, results: list[dict]) -> None:
    """Append results to a JSON file, replacing stale same-cell entries."""
    if not out_path or not results:
        return
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    prior = []
    if os.path.exists(out_path):
        with open(out_path) as f:
            prior = json.load(f)
    seen = {(r["arch"], r["shape"], r["mesh"]) for r in results}
    prior = [r for r in prior
             if (r["arch"], r["shape"], r["mesh"]) not in seen]
    with open(out_path, "w") as f:
        json.dump(prior + results, f, indent=1)
    print(f"[dryrun] wrote {len(results)} results -> {out_path}")


def run_fact_cell(name: str, n: int, tile: int, mesh, *,
                  verbose: bool = True, hlo_out: str | None = None,
                  dtype=None) -> dict:
    """Dry-run one distributed factorization (the paper's own workload) on
    the production mesh: lower + compile the full unrolled shard_map
    factorization, extract roofline terms."""
    import jax.numpy as jnp

    from repro.core.dag import factorization_flops
    from repro.linalg.distributed import dryrun_cell

    dtype = dtype or jnp.float32
    fn, args, in_sh, out_sh = dryrun_cell(name, n, tile, mesh, dtype)
    t0 = time.time()
    lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                      donate_argnums=(0,)).lower(*args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # some jax builds wrap in a list
        cost = cost[0] if cost else {}
    try:
        mem = compiled.memory_analysis()
    except Exception:
        mem = None
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    if hlo_out:
        with open(hlo_out, "w") as f:
            f.write(hlo)
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll_dev = float(sum(coll.values()))
    chips = n_chips(mesh)
    model_flops = factorization_flops(name, n)
    out = {
        "arch": f"fact-{name}", "shape": f"n{n}_b{tile}",
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": chips, "kind": "factorization",
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_device": flops_dev, "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "collectives": {k: v for k, v in coll.items() if v},
        "model_flops_global": model_flops,
        "useful_flop_ratio": (model_flops / (flops_dev * chips)
                              if flops_dev else 0.0),
        **roofline_terms(flops_dev, bytes_dev, coll_dev),
    }
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                out[f"mem_{attr}"] = int(v)
    if verbose:
        print(f"[dryrun] fact-{name:8s} N={n} b={tile} mesh={out['mesh']:9s} "
              f"lower={t_lower:6.1f}s compile={t_compile:6.1f}s "
              f"flops/dev={flops_dev:.3e} bytes/dev={bytes_dev:.3e} "
              f"coll/dev={coll_dev:.3e} -> {out['bottleneck']} "
              f"useful={out['useful_flop_ratio']:.2f}")
    return out


def main() -> None:
    """CLI driver (see module docstring for usage)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--fact",
                    choices=("cholesky", "lu", "qr", "qr-cholqr2"),
                    help="dry-run a distributed factorization instead")
    ap.add_argument("--n", type=int, default=163840,
                    help="--fact matrix dimension (paper: 160000->163840)")
    ap.add_argument("--tile", type=int, default=2560,
                    help="--fact tile size")
    ap.add_argument("--mesh", choices=("single_pod", "multi_pod", "both"),
                    default="single_pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="append results to this JSON")
    ap.add_argument("--hlo-dir", default=None)
    args = ap.parse_args()

    meshes = {"single_pod": False, "multi_pod": True}
    mesh_names = (["single_pod", "multi_pod"] if args.mesh == "both"
                  else [args.mesh])

    if args.fact:
        results, failures = [], []
        for mesh_name in mesh_names:
            mesh = make_production_mesh(multi_pod=meshes[mesh_name])
            # multi-pod: the factorization grid is ("data","model") inside
            # each pod; the pod axis runs independent instances (the paper's
            # workload is a single-grid job -- pod axis stays batch-like)
            if meshes[mesh_name]:
                import jax as _jax
                mesh = _jax.make_mesh((2, 16, 16), ("pod", "data", "model"))
            hlo_out = None
            if args.hlo_dir:
                os.makedirs(args.hlo_dir, exist_ok=True)
                hlo_out = os.path.join(
                    args.hlo_dir,
                    f"fact-{args.fact}_n{args.n}_{mesh_name}.hlo")
            try:
                results.append(run_fact_cell(args.fact, args.n, args.tile,
                                             mesh, hlo_out=hlo_out))
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                failures.append((args.fact, args.n, mesh_name, repr(e)))
        _write_out(args.out, results)
        if failures:
            raise SystemExit(1)
        return

    if args.all:
        cells = all_cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        ok, why = cell_applicable(get_config(args.arch), args.shape)
        if not ok:
            print(f"SKIP {args.arch} x {args.shape}: {why}")
            return
        cells = [(args.arch, args.shape)]

    results, failures = [], []
    for mesh_name in mesh_names:
        mesh = make_production_mesh(multi_pod=meshes[mesh_name])
        for arch, shape in cells:
            hlo_out = None
            if args.hlo_dir:
                os.makedirs(args.hlo_dir, exist_ok=True)
                hlo_out = os.path.join(
                    args.hlo_dir, f"{arch}_{shape}_{mesh_name}.hlo")
            try:
                results.append(run_cell(arch, shape, mesh, hlo_out=hlo_out))
            except Exception as e:  # noqa: BLE001 -- report, then fail at exit
                traceback.print_exc()
                failures.append((arch, shape, mesh_name, repr(e)))
        del mesh

    _write_out(args.out, results)

    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:")
        for f_ in failures:
            print("  ", f_)
        raise SystemExit(1)
    print(f"[dryrun] all {len(results)} cells compiled OK")


if __name__ == "__main__":
    main()
