"""Roofline report: three terms per (arch x shape x mesh) cell.

Reads results/dryrun.json (raw cost_analysis numbers captured at compile
time) and results/hlo/*.hlo (the compiled modules), reruns the trip-count-
aware analyzer, and emits per-cell:

    compute_s     dot_flops / (197 TFLOP/s bf16)        [per chip]
    memory_s      hbm_bytes / (819 GB/s)                [per chip]
    collective_s  ici_bytes / (50 GB/s)  [+ dcn_bytes / (25 GB/s) x-pod]
    bottleneck    argmax of the three
    MODEL_FLOPS   6 N D (train) / 2 N D (inference), N = active params
    useful ratio  MODEL_FLOPS / (dot_flops x chips)
    roofline_frac compute_s / max(all three)  -- how compute-bound the
                  step is; 1.0 = at the compute roofline

Usage:
    python -m repro.launch.roofline --json results/dryrun.json \
        --hlo-dir results/hlo --out results/roofline_cells.json [--markdown]

(The committed model-zoo artifact `results/roofline.json` has its own
``roofline/v2`` schema and generator -- `python -m repro.launch.zoo`; see
docs/ROOFLINE.md. This module is the ad-hoc per-cell report for dry-run
sweeps on the production meshes.)
"""

from __future__ import annotations

import argparse
import json
import os

from .hlo_analysis import analyze_file

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s per link
DCN_BW = 25e9              # cross-pod (not an assignment constant; only
                           # used for collectives whose groups span pods)

MESH_DIR = {"16x16": "single_pod", "2x16x16": "multi_pod"}


def corrected_terms(rec: dict, hlo_dir: str) -> dict | None:
    """Trip-count-corrected roofline terms for one dry-run record.

    Reruns `hlo_analysis.analyze_file` on the cell's persisted HLO (the
    correction XLA's single-visit ``cost_analysis()`` lacks for scanned
    models) and converts the per-device counts into the three roofline
    seconds terms at the TPU-v5e constants.

    Parameters
    ----------
    rec : dict
        One `results/dryrun.json` record (needs ``arch``, ``shape``,
        ``mesh``, ``chips``, optionally ``model_flops_global``).
    hlo_dir : str
        Directory holding ``<arch>_<shape>_<mesh_name>.hlo`` modules.

    Returns
    -------
    dict or None
        Terms + ``bottleneck`` + ``roofline_frac`` (the compute-bound
        fraction that `core.roofline_model.beta_from_terms` floors into
        a beta), or None when the record's mesh is unknown or its HLO
        file is missing.
    """
    mesh_name = MESH_DIR.get(rec["mesh"])
    if mesh_name is None:
        return None
    path = os.path.join(hlo_dir, f"{rec['arch']}_{rec['shape']}_{mesh_name}.hlo")
    if not os.path.exists(path):
        return None
    cost = analyze_file(path, n_devices=rec["chips"],
                        chips_per_pod=256)
    compute_s = cost.dot_flops / PEAK_FLOPS
    memory_s = cost.hbm_bytes / HBM_BW
    collective_s = cost.ici_bytes / ICI_BW + cost.dcn_bytes / DCN_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bottleneck = max(terms, key=lambda k: terms[k])
    bound = max(terms.values())
    chips = rec["chips"]
    model = rec.get("model_flops_global", 0.0)
    return {
        **terms,
        "bottleneck": bottleneck,
        "step_s_lower_bound": bound,
        "dot_flops_per_device": cost.dot_flops,
        "hbm_bytes_per_device": cost.hbm_bytes,
        "ici_bytes_per_device": cost.ici_bytes,
        "dcn_bytes_per_device": cost.dcn_bytes,
        "per_collective": cost.per_collective,
        "useful_flop_ratio": (model / (cost.dot_flops * chips)
                              if cost.dot_flops else 0.0),
        "roofline_frac": compute_s / bound if bound else 0.0,
        "n_while": cost.n_while,
    }


def build(json_path: str, hlo_dir: str) -> list[dict]:
    """Dry-run records with a ``corrected`` terms block attached where the
    cell's HLO module is available."""
    with open(json_path) as f:
        records = json.load(f)
    out = []
    for rec in records:
        corr = corrected_terms(rec, hlo_dir)
        row = dict(rec)
        if corr is not None:
            row["corrected"] = corr
        out.append(row)
    return out


def fmt_s(x: float) -> str:
    """Seconds formatted for the report table (ms below 1 s)."""
    if x >= 1.0:
        return f"{x:7.2f}s"
    return f"{x * 1e3:6.1f}ms"


def markdown_table(rows: list[dict], mesh: str = "16x16") -> str:
    """Markdown roofline table of one mesh's corrected cells."""
    lines = [
        "| arch | shape | compute | memory | collective | bound | bottleneck"
        " | useful | roofline |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != mesh or "corrected" not in r:
            continue
        c = r["corrected"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(c['compute_s'])} "
            f"| {fmt_s(c['memory_s'])} | {fmt_s(c['collective_s'])} "
            f"| {fmt_s(c['step_s_lower_bound'])} "
            f"| {c['bottleneck'].removesuffix('_s')} "
            f"| {c['useful_flop_ratio']:.2f} | {c['roofline_frac']:.2f} |")
    return "\n".join(lines)


def main() -> None:
    """CLI: build the corrected per-cell report (see module docstring)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="results/dryrun.json")
    ap.add_argument("--hlo-dir", default="results/hlo")
    ap.add_argument("--out", default="results/roofline_cells.json")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()

    rows = build(args.json, args.hlo_dir)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"[roofline] wrote {len(rows)} rows -> {args.out}")
    if args.markdown:
        for mesh in ("16x16", "2x16x16"):
            print(f"\n### mesh {mesh}\n")
            print(markdown_table(rows, mesh))


if __name__ == "__main__":
    main()
