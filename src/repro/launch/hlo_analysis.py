"""Trip-count-aware analysis of compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts each computation ONCE -- a
``jax.lax.scan`` over 40 layer groups contributes its body a single time,
so flop/byte totals for scanned models are undercounted by orders of
magnitude (and collective bytes inside scanned bodies likewise). This
module parses ``compiled.as_text()`` into a computation call graph,
infers while-loop trip counts from their condition computations
(jax-lowered loops compare an induction var starting at 0 against a
constant with direction=LT), and propagates execution multipliers:

    ENTRY                      x1
    while body/condition       x trip_count x caller
    fusion / call / to_apply   x caller
    conditional branches       x caller      (upper bound: both branches)

Per-computation costs, then multiplied through the graph:

  * flops       -- dot ops: 2 x |out| x prod(contracting dims); convolution
                   handled approximately; elementwise ignored (documented:
                   matmul-dominated workloads; this matches the MXU term).
  * hbm bytes   -- for every instruction at fusion *boundaries* (fusion
                   internals move through registers/VMEM): |out| + sum
                   |operands|, skipping no-data ops (tuple/gte/parameter/
                   constant/bitcast). A buffer-level HBM traffic model --
                   deliberately different from cost_analysis's
                   "bytes accessed", which double-counts fused operands.
  * collectives -- ring-model bytes per device and per family, with the
                   replica-group size G: all-gather counts (G-1)/G x |out|,
                   all-reduce 2(G-1)/G x |in|, reduce-scatter (G-1)/G x
                   |in|, all-to-all (G-1)/G x |in|, collective-permute
                   |in|. Groups that span more than one pod's chips are
                   split out as DCN traffic (cross-pod links are not ICI).

The result feeds the roofline terms in launch/roofline.py; raw
cost_analysis numbers are kept alongside for comparison.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Iterable

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f16": 2, "bf16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->.*\{")
_INSTR_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s+=\s+(.+)$")
_ATTR_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_ATTR_BODY = re.compile(r"body=%?([\w.\-]+)")
_ATTR_COND = re.compile(r"condition=%?([\w.\-]+)")
_ATTR_APPLY = re.compile(r"to_apply=%?([\w.\-]+)")
_ATTR_BRANCH = re.compile(r"branch_computations=\{([^}]*)\}")
_ATTR_TF = re.compile(r"(?:true|false)_computation=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BDIMS_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
# s32 normally; s64 when the program was built under jax_enable_x64
_CONST_S32_RE = re.compile(r"s(?:32|64)\[\]\s+constant\((\d+)\)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_NO_DATA_OPS = frozenset({
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "add-dependency",
})


def _shape_list(type_str: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_list(type_str):
        total += _DTYPE_BYTES[dt] * math.prod(dims) if dims else _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    out_type: str          # type portion of the body
    body: str              # full body text
    operands: list[str]    # referenced instruction names

    @property
    def out_bytes(self) -> int:
        """Total bytes of the instruction's output shape(s)."""
        return _shape_bytes(self.out_type)


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: list[Instr] = dataclasses.field(default_factory=list)

    def by_name(self) -> dict[str, Instr]:
        """Instruction lookup table keyed by instruction name."""
        return {i.name: i for i in self.instrs}


def parse_module(text: str) -> dict[str, Computation]:
    """Parse ``compiled.as_text()`` into named `Computation` blocks."""
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        if cur is None:
            m = _HEADER_RE.match(line)
            if m:
                cur = Computation(m.group(2), bool(m.group(1)))
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, body = m.groups()
        # body = "<type> <opcode>(<operands>), attrs..."
        # the type may be a tuple: find the opcode as the first word
        # followed by '(' after the leading type expression.
        op_m = re.search(r"\s([a-z][\w\-]*)\(", body)
        if not op_m:
            continue
        opcode = op_m.group(1)
        out_type = body[:op_m.start()].strip()
        paren = body[op_m.end():]
        depth, i = 1, 0
        while i < len(paren) and depth:
            if paren[i] == "(":
                depth += 1
            elif paren[i] == ")":
                depth -= 1
            i += 1
        operand_str = paren[:i - 1]
        operands = _OPERAND_RE.findall(operand_str)
        cur.instrs.append(Instr(name, opcode, out_type, body, operands))
    if cur is not None:
        comps[cur.name] = cur
    return comps


# ------------------------------------------------------------- call graph

def _trip_count(cond: Computation) -> int:
    """Trip count of a jax-lowered while: the s32[] constant its condition
    compares against (induction variables start at 0, direction=LT)."""
    vals = []
    for ins in cond.instrs:
        vals.extend(int(v) for v in _CONST_S32_RE.findall(ins.body))
    if not vals:
        return 1
    return max(vals)        # the loop bound dominates any stray constants


def computation_multipliers(comps: dict[str, Computation]) -> dict[str, float]:
    """Execution count of every computation, propagated from ENTRY through
    while-loop trip counts, fusions/calls, and conditional branches."""
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    mult: dict[str, float] = {name: 0.0 for name in comps}
    if entry is None:
        return mult

    import collections
    pending = collections.deque([(entry, 1.0)])
    while pending:
        name, m = pending.popleft()
        if name not in comps:
            continue
        mult[name] = mult.get(name, 0.0) + m
        for ins in comps[name].instrs:
            if ins.opcode == "while":
                b = _ATTR_BODY.search(ins.body)
                c = _ATTR_COND.search(ins.body)
                trips = _trip_count(comps[c.group(1)]) if c and \
                    c.group(1) in comps else 1
                if b:
                    pending.append((b.group(1), m * trips))
                if c:
                    pending.append((c.group(1), m * (trips + 1)))
            else:
                for pat in (_ATTR_CALLS, _ATTR_APPLY, _ATTR_TF):
                    for g in pat.findall(ins.body):
                        pending.append((g, m))
                br = _ATTR_BRANCH.search(ins.body)
                if br:
                    for g in _OPERAND_RE.findall(br.group(1)):
                        pending.append((g, m))
    return mult


# ----------------------------------------------------------------- costs

def _dot_flops(ins: Instr, table: dict[str, Instr]) -> float:
    shapes = _shape_list(ins.out_type)
    if not shapes:
        return 0.0
    out_elems = math.prod(shapes[0][1]) if shapes[0][1] else 1
    cd = _CDIMS_RE.search(ins.body)
    if not cd or not ins.operands:
        return 2.0 * out_elems            # unknown contraction: assume 1
    lhs = table.get(ins.operands[0])
    if lhs is None:
        return 2.0 * out_elems
    lhs_shapes = _shape_list(lhs.out_type)
    if not lhs_shapes:
        return 2.0 * out_elems
    dims = lhs_shapes[0][1]
    k = 1
    for d in (int(x) for x in cd.group(1).split(",") if x):
        if d < len(dims):
            k *= dims[d]
    return 2.0 * out_elems * k


def _conv_flops(ins: Instr, table: dict[str, Instr]) -> float:
    shapes = _shape_list(ins.out_type)
    if not shapes or len(ins.operands) < 2:
        return 0.0
    out_elems = math.prod(shapes[0][1]) if shapes[0][1] else 1
    ker = table.get(ins.operands[1])
    if ker is None:
        return 2.0 * out_elems
    kshapes = _shape_list(ker.out_type)
    kelems = math.prod(kshapes[0][1]) if kshapes and kshapes[0][1] else 1
    # 2 * |out| * kernel_elems / out_features (approximate)
    out_feat = shapes[0][1][-1] if shapes[0][1] else 1
    return 2.0 * out_elems * max(kelems // max(out_feat, 1), 1)


def _group_size(ins: Instr, default: int) -> int:
    m = _GROUPS_BRACE_RE.search(ins.body)
    if m:
        return len([x for x in m.group(1).split(",") if x])
    m = _GROUPS_IOTA_RE.search(ins.body)
    if m:
        return int(m.group(2))            # [n_groups, group_size]
    return default


def _group_spans_pods(ins: Instr, chips_per_pod: int) -> bool:
    """True if any replica group mixes devices from different pods."""
    m = _GROUPS_BRACE_RE.search(ins.body)
    if m:
        ids = [int(x) for x in m.group(1).split(",") if x]
        return len({i // chips_per_pod for i in ids}) > 1
    m = _GROUPS_IOTA_RE.search(ins.body)
    if m:
        # iota groups [G, S] <= [N]: group g = {g*S .. g*S+S-1} after the
        # permutation; without decoding the permutation, a group larger
        # than a pod must span pods; smaller iota groups are contiguous
        # in the (pod-major) device order produced by make_mesh.
        return int(m.group(2)) > chips_per_pod
    return False


@dataclasses.dataclass
class HloCost:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    ici_bytes: float = 0.0               # ring-model, per device
    dcn_bytes: float = 0.0               # cross-pod portion
    coll_bytes_raw: float = 0.0          # operand bytes (dryrun parity)
    per_collective: dict = dataclasses.field(default_factory=dict)
    n_while: int = 0
    trip_counts: dict = dataclasses.field(default_factory=dict)

    def as_dict(self) -> dict:
        """JSON-serializable view (drops the per-while trip counts)."""
        return {
            "dot_flops": self.dot_flops,
            "hbm_bytes": self.hbm_bytes,
            "ici_bytes": self.ici_bytes,
            "dcn_bytes": self.dcn_bytes,
            "coll_bytes_raw": self.coll_bytes_raw,
            "per_collective": dict(self.per_collective),
            "n_while": self.n_while,
        }


# computations reached through `calls=` (fusions): flops counted, bytes not
def _fusion_callees(comps: dict[str, Computation]) -> set[str]:
    out: set[str] = set()
    for c in comps.values():
        for ins in c.instrs:
            if ins.opcode == "fusion":
                m = _ATTR_CALLS.search(ins.body)
                if m:
                    out.add(m.group(1))
    return out


def analyze(text: str, *, n_devices: int = 1,
            chips_per_pod: int = 256) -> HloCost:
    """Trip-count-aware cost analysis of one compiled HLO module.

    Parses the module text, propagates per-computation execution
    multipliers (`computation_multipliers` -- the correction XLA's own
    ``cost_analysis()`` lacks for scanned models), and accumulates dot
    flops, fusion-boundary HBM bytes, and ring-model collective bytes,
    splitting collective groups that span pods onto the DCN. All counts
    are *per device*: the SPMD module is the per-device program.

    Parameters
    ----------
    text : str
        ``compiled.as_text()`` of an SPMD-partitioned executable.
    n_devices : int
        Devices the module was partitioned over; the default replica
        group size for collectives that do not carry explicit groups.
    chips_per_pod : int
        ICI domain size; replica groups mixing devices from different
        pods are accounted as DCN (`HloCost.dcn_bytes`) instead of ICI.

    Returns
    -------
    HloCost
        Accumulated per-device flop/byte/collective counts plus the
        while-loop census (`n_while`, `trip_counts`).
    """
    comps = parse_module(text)
    mult = computation_multipliers(comps)
    fusion_internal = _fusion_callees(comps)
    cost = HloCost()

    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        table = comp.by_name()
        in_fusion = comp.name in fusion_internal
        for ins in comp.instrs:
            op = ins.opcode
            if op == "while":
                cost.n_while += 1
                c = _ATTR_COND.search(ins.body)
                if c and c.group(1) in comps:
                    cost.trip_counts[ins.name] = _trip_count(comps[c.group(1)])
                continue
            if op == "dot":
                cost.dot_flops += m * _dot_flops(ins, table)
            elif op == "convolution":
                cost.dot_flops += m * _conv_flops(ins, table)
            elif op == "triangular-solve":
                # X [.., n, k] vs triangular [.., n, n]: ~ n^2 k flops
                shapes = _shape_list(ins.out_type)
                if shapes and shapes[0][1]:
                    dims = shapes[0][1]
                    tri = table.get(ins.operands[0]) if ins.operands else None
                    n_tri = (_shape_list(tri.out_type)[0][1][-1]
                             if tri and _shape_list(tri.out_type) else dims[-1])
                    cost.dot_flops += m * math.prod(dims) * n_tri
            elif op == "cholesky":
                shapes = _shape_list(ins.out_type)
                if shapes and shapes[0][1]:
                    dims = shapes[0][1]
                    n_ = dims[-1]
                    batch = math.prod(dims[:-2]) if len(dims) > 2 else 1
                    cost.dot_flops += m * batch * n_ ** 3 / 3.0
            # ---- collectives ------------------------------------------
            base = op.removesuffix("-start")
            if base in COLLECTIVES:
                op_bytes = sum(table[o].out_bytes for o in ins.operands
                               if o in table)
                if op_bytes == 0:      # operands w/o inline defs: use out
                    op_bytes = ins.out_bytes
                out_bytes = ins.out_bytes
                g = _group_size(ins, n_devices)
                frac = (g - 1) / g if g > 1 else 0.0
                if base == "all-gather":
                    moved = frac * out_bytes
                elif base == "all-reduce":
                    moved = 2.0 * frac * op_bytes
                elif base == "reduce-scatter":
                    moved = frac * op_bytes
                elif base == "all-to-all":
                    moved = frac * op_bytes
                else:                                  # collective-permute
                    moved = float(op_bytes)
                cost.coll_bytes_raw += m * op_bytes
                key = base
                cost.per_collective[key] = cost.per_collective.get(key, 0.0) \
                    + m * moved
                if _group_spans_pods(ins, chips_per_pod) and \
                        n_devices > chips_per_pod:
                    cost.dcn_bytes += m * moved
                else:
                    cost.ici_bytes += m * moved
                # collectives also touch HBM
            # ---- HBM traffic at fusion boundaries ----------------------
            if in_fusion or op in _NO_DATA_OPS or op == "while":
                continue
            b = ins.out_bytes
            for o in ins.operands:
                if o in table:
                    b += table[o].out_bytes
            cost.hbm_bytes += m * b
    return cost


def analyze_file(path: str, **kw) -> HloCost:
    """`analyze` on an HLO text file (kwargs forwarded)."""
    with open(path) as f:
        return analyze(f.read(), **kw)
