"""End-to-end training launcher.

    python -m repro.launch.train --arch qwen2.5-3b --smoke \
        --steps 200 --ckpt-dir /tmp/run1 [--resume] [--batch 8 --seq 128]

Production features exercised even in a CPU smoke run:
  * checkpoint/restart: atomic step checkpoints, --resume restarts from the
    latest one (kill -9 mid-run and relaunch: training continues bit-exact
    because the data pipeline is a pure function of step).
  * elastic restore: checkpoints are mesh-agnostic; --resume on a different
    host/device count resshards on load.
  * energy accounting: every N steps the step's phase profile is fed to
    core.energy_aware_step and the per-strategy energy is logged (the
    paper's technique as a first-class runtime feature).
  * straggler mitigation knob: --sim-straggler adds a deterministic delay
    to one host's data fetch; the log shows the step-time impact and the
    energy scheduler treats the induced slack like any other (DESIGN.md S5).

On a real TPU mesh, the same script runs under jax.distributed with the
production mesh from launch/mesh.py and the sharding rules from
repro.sharding (the dry-run proves those compile; this driver proves the
training loop logic end-to-end).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, make_smoke
from repro.core.energy_aware_step import StepProfile, evaluate_step
from repro.models import get_model
from repro.train.checkpoint import latest_step, restore_checkpoint, \
    save_checkpoint
from repro.train.data import SyntheticDataset
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step


def main(argv=None) -> dict:
    """CLI: run the (smoke-scale) training loop; returns final metrics."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--d-model", type=int, default=None,
                    help="override d_model (with --smoke: scale the model up)")
    ap.add_argument("--n-layers", type=int, default=None)
    ap.add_argument("--vocab", type=int, default=None)
    ap.add_argument("--steps", type=int, default=100,
                    help="total schedule length (fixes the LR cosine)")
    ap.add_argument("--stop-at", type=int, default=None,
                    help="stop early at this step (simulated failure); the "
                         "LR schedule still spans --steps")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--energy-every", type=int, default=50)
    ap.add_argument("--sim-straggler", type=float, default=0.0,
                    help="seconds of synthetic per-step delay on host 0")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    import dataclasses

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = make_smoke(cfg)
    overrides = {}
    if args.d_model:
        overrides.update(d_model=args.d_model, head_dim=args.d_model // 8,
                         d_ff=4 * args.d_model if cfg.d_ff else 0)
    if args.n_layers:
        overrides["n_layers"] = args.n_layers
    if args.vocab:
        overrides["vocab_size"] = args.vocab
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    api = get_model(cfg)
    opt_cfg = AdamWConfig(peak_lr=args.lr, warmup_steps=20,
                          total_steps=args.steps)

    data = SyntheticDataset(cfg, batch=args.batch, seq=args.seq,
                            seed=args.seed)
    step_fn = jax.jit(make_train_step(api, opt_cfg,
                                      n_microbatches=args.microbatches),
                      donate_argnums=(0, 1))

    start = 0
    state = init_train_state(api, opt_cfg, jax.random.key(args.seed))
    if args.resume and args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            tmpl = {"params": state.params, "opt": state.opt}
            tree = restore_checkpoint(args.ckpt_dir, last, tmpl)
            state.params, state.opt = tree["params"], tree["opt"]
            start = last
            print(f"[train] resumed from step {last}")

    params, opt = state.params, state.opt
    losses = []
    t_run = time.time()
    stop_at = min(args.stop_at or args.steps, args.steps)
    for step in range(start, stop_at):
        if args.sim_straggler and step % 7 == 3:
            time.sleep(args.sim_straggler)      # one slow host, periodic
        batch = data.batch_at(step)
        t0 = time.time()
        params, opt, metrics = step_fn(params, opt, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        losses.append(loss)
        if step % args.log_every == 0 or step == stop_at - 1:
            print(f"[train] step {step:5d} loss {loss:8.4f} "
                  f"grad_norm {float(metrics['grad_norm']):8.3f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:7.1f} ms")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            path = save_checkpoint(args.ckpt_dir, step + 1,
                                   {"params": params, "opt": opt})
            print(f"[train] checkpoint @ {step + 1} -> {path}")
        if args.energy_every and (step + 1) % args.energy_every == 0:
            # measured step profile: on CPU we only have wall time; lanes
            # split by the arch's dry-run ratio when available, else 60/30/10
            prof = StepProfile(cfg.name, "train", mxu_s=0.6 * dt,
                               hbm_s=dt, ici_s=0.1 * dt)
            res = evaluate_step(prof, "tpu_like")
            print("[energy] " + "  ".join(
                f"{k}={v.energy_j:.1f}J({v.saved_vs_original_pct:+.1f}%)"
                for k, v in res.items()))

    wall = time.time() - t_run
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, stop_at,
                        {"params": params, "opt": opt})
    out = {"final_loss": losses[-1] if losses else float("nan"),
           "first_loss": losses[0] if losses else float("nan"),
           "steps": len(losses), "wall_s": wall}
    print(f"[train] done: loss {out['first_loss']:.3f} -> "
          f"{out['final_loss']:.3f} in {out['steps']} steps, {wall:.1f}s")
    return out


if __name__ == "__main__":
    main()
