"""Fault-tolerant checkpointing: atomic, mesh-shape-agnostic, restartable.

Format: one .npz with path-flattened arrays + a JSON manifest (step, paths,
dtypes). Writes go to a temp file then os.replace (atomic on POSIX), so a
node failure mid-save never corrupts the latest checkpoint. Arrays are
saved fully-replicated (device_get), so a job can restart on a different
mesh shape / pod count and reshard on restore -- the elastic-scaling path.
"""

from __future__ import annotations

import json
import os
import re

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat, _ = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    # bf16 isn't a numpy-native dtype: view as uint16 with a dtype tag
    manifest = {"step": step, "dtypes": {}}
    packed = {}
    for k, a in arrays.items():
        if a.dtype == jnp.bfloat16:
            manifest["dtypes"][k] = "bfloat16"
            packed[k] = a.view(np.uint16)
        else:
            manifest["dtypes"][k] = str(a.dtype)
            packed[k] = a
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **packed)
    os.replace(tmp, path)                      # atomic publish
    mpath = os.path.join(ckpt_dir, f"step_{step:08d}.json")
    with open(mpath + ".tmp", "w") as f:
        json.dump(manifest, f)
    os.replace(mpath + ".tmp", mpath)
    _gc(ckpt_dir, keep)
    return path


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-keep]:
        for ext in (".npz", ".json"):
            p = os.path.join(ckpt_dir, f"step_{s:08d}{ext}")
            if os.path.exists(p):
                os.remove(p)


def latest_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)\.npz", name)
        if m and os.path.exists(os.path.join(
                ckpt_dir, f"step_{int(m.group(1)):08d}.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = latest_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, template):
    """Restore into the structure of `template` (arrays or ShapeDtypeStructs).

    Shape mismatches raise; dtype conversion is applied (e.g. restoring a
    bf16 checkpoint into an f32 smoke model)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    mpath = os.path.join(ckpt_dir, f"step_{step:08d}.json")
    with open(mpath) as f:
        manifest = json.load(f)
    data = np.load(path)
    flat_t, treedef = _flatten(template)
    leaves = []
    for key, tmpl in flat_t.items():
        a = data[key]
        if manifest["dtypes"].get(key) == "bfloat16":
            a = a.view(jnp.bfloat16)
        if tuple(a.shape) != tuple(tmpl.shape):
            raise ValueError(f"{key}: checkpoint shape {a.shape} != "
                             f"template {tmpl.shape}")
        leaves.append(jnp.asarray(a, dtype=tmpl.dtype))
    keys_order = list(flat_t.keys())
    rebuilt = dict(zip(keys_order, leaves))
    # unflatten in the template's leaf order
    flat_list = [rebuilt[k] for k in keys_order]
    return jax.tree_util.tree_unflatten(treedef, flat_list)
