"""Train step assembly: loss + grad + AdamW, with gradient accumulation
(microbatching) and sharding-spec derivation for the full train state.

`make_train_step` returns a pure function suitable for jax.jit with
explicit in/out shardings (the dry-run path) or plain CPU execution (smoke
tests / the quickstart example).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import ModelApi
from repro.sharding.rules import Rules, spec_for

from .optimizer import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any

    def tree(self):
        return {"params": self.params, "opt": self.opt}


def init_train_state(api: ModelApi, opt_cfg: AdamWConfig, key) -> TrainState:
    params = api.param_tree("init", key)
    return TrainState(params=params, opt=adamw_init(params, opt_cfg))


def make_train_step(api: ModelApi, opt_cfg: AdamWConfig,
                    n_microbatches: int = 1) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def grads_of(params, batch):
        return jax.value_and_grad(api.loss_fn)(params, batch)

    def train_step(params, opt_state, batch):
        if n_microbatches == 1:
            loss, grads = grads_of(params, batch)
        else:
            def split(x):
                return x.reshape((n_microbatches,
                                  x.shape[0] // n_microbatches) + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_fn(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = grads_of(params, mb)
                return (loss_acc + loss,
                        jax.tree.map(jnp.add, grad_acc, grads)), None

            zero = (jnp.zeros(()),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
            (loss_sum, grad_sum), _ = jax.lax.scan(acc_fn, zero, micro)
            loss = loss_sum / n_microbatches
            grads = jax.tree.map(lambda g: g / n_microbatches, grad_sum)
        params, opt_state, metrics = adamw_update(params, grads, opt_state,
                                                  opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


# ------------------------------------------------------- sharding specs
def train_state_specs(api: ModelApi, opt_cfg: AdamWConfig, rules: Rules):
    """PartitionSpecs for (params, opt_state): ZeRO -- optimizer moments and
    master copies shard exactly like the FSDP weights."""
    axes = api.param_tree("axes")
    is_tuple = lambda x: isinstance(x, tuple)  # noqa: E731
    pspec = jax.tree.map(lambda ax: spec_for(ax, rules), axes,
                         is_leaf=is_tuple)
    opt_spec = {"step": spec_for((), rules), "m": pspec, "v": pspec}
    if opt_cfg.master_dtype is not None:
        opt_spec["master"] = pspec
    return pspec, opt_spec
