"""Deterministic synthetic data pipeline.

Stateless-resumable by construction: batch contents are a pure function of
(seed, step), so a restarted job regenerates exactly the stream it would
have seen -- the checkpoint only needs the step counter (fault tolerance /
elastic restart come for free). Host-sharded: each data-parallel host can
ask for its slice by (host_id, n_hosts) without coordination.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass
class SyntheticDataset:
    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1

    def __post_init__(self):
        assert self.batch % self.n_hosts == 0

    @property
    def host_batch(self) -> int:
        return self.batch // self.n_hosts

    def batch_at(self, step: int) -> dict:
        """Markov-ish synthetic tokens with learnable structure (so a smoke
        train run can actually reduce loss)."""
        key = jax.random.fold_in(jax.random.key(self.seed), step)
        key = jax.random.fold_in(key, self.host_id)
        k1, k2, k3 = jax.random.split(key, 3)
        b, s, v = self.host_batch, self.seq, self.cfg.vocab_size
        # structured stream: token_{t+1} = token_t + delta (mod small range)
        start = jax.random.randint(k1, (b, 1), 0, v)
        delta = jax.random.randint(k2, (b, 1), 1, 7)
        ramp = start + delta * jnp.arange(s + 1)[None, :]
        toks = jnp.mod(ramp, jnp.minimum(v, 997)).astype(jnp.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.frontend == "audio":
            batch["audio_embeds"] = 0.1 * jax.random.normal(
                k3, (b, self.cfg.frontend_len, self.cfg.d_model), jnp.float32)
        if self.cfg.frontend == "vision":
            n_pre = min(self.cfg.frontend_len or 0, s // 2) or 1
            batch["vision_embeds"] = 0.1 * jax.random.normal(
                k3, (b, n_pre, self.cfg.d_model), jnp.float32)
        return batch
