"""Hand-rolled AdamW with dtype-configurable state (no optax dependency).

Distributed-memory knobs (used by the big-arch dry-runs; see EXPERIMENTS.md
S-Dry-run): `m_dtype`/`v_dtype` drop the moment buffers to bf16 and
`master_dtype=None` trains pure-bf16 -- for nemotron-4-340b that is the
difference between fitting one pod and not. The optimizer state is a plain
pytree mirroring params, so ZeRO-style sharding falls out of the same FSDP
partition specs as the weights.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    m_dtype: str = "float32"
    v_dtype: str = "float32"
    master_dtype: str | None = "float32"   # None => update params in-place

    def schedule(self, step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(self.warmup_steps, 1), 1.0)
        prog = jnp.clip((step - self.warmup_steps)
                        / jnp.maximum(self.total_steps - self.warmup_steps, 1),
                        0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        frac = self.min_lr_frac + (1.0 - self.min_lr_frac) * cos
        return self.peak_lr * warm * frac


def adamw_init(params, cfg: AdamWConfig):
    # NOTE: moments/master are materialized as *distinct* buffers (p * 0 and
    # an explicit copy) -- jnp.zeros constants get deduplicated by the
    # runtime and p.astype(p.dtype) aliases p, either of which makes a
    # donated (params, opt_state) pair share buffers and breaks donation.
    def zeros_like_distinct(p, dtype):
        return (p * 0).astype(jnp.dtype(dtype))

    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: zeros_like_distinct(p, cfg.m_dtype),
                          params),
        "v": jax.tree.map(lambda p: zeros_like_distinct(p, cfg.v_dtype),
                          params),
    }
    if cfg.master_dtype is not None:
        state["master"] = jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.dtype(cfg.master_dtype),
                                copy=True), params)
    return state


def global_norm(tree):
    return jnp.sqrt(sum((g.astype(jnp.float32) ** 2).sum()
                        for g in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = cfg.schedule(step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    ref = state.get("master", params)

    def upd(p_ref, g, m, v):
        gf = g.astype(jnp.float32) * scale
        mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        mhat = mf / b1c
        vhat = vf / b2c
        pf = p_ref.astype(jnp.float32)
        step_vec = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * pf
        pf = pf - lr * step_vec
        return pf, mf.astype(m.dtype), vf.astype(v.dtype)

    flat_ref, treedef = jax.tree.flatten(ref)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    outs = [upd(*args) for args in zip(flat_ref, flat_g, flat_m, flat_v)]
    new_ref = treedef.unflatten([o[0] for o in outs])
    new_m = treedef.unflatten([o[1] for o in outs])
    new_v = treedef.unflatten([o[2] for o in outs])

    if cfg.master_dtype is not None:
        new_state = {"step": step, "m": new_m, "v": new_v,
                     "master": jax.tree.map(
                         lambda p: p.astype(jnp.dtype(cfg.master_dtype)),
                         new_ref)}
        new_params = jax.tree.map(
            lambda pf, p: pf.astype(p.dtype), new_ref, params)
    else:
        new_state = {"step": step, "m": new_m, "v": new_v}
        new_params = jax.tree.map(
            lambda pf, p: pf.astype(p.dtype), new_ref, params)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
