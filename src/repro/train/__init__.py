from .checkpoint import latest_step, restore_checkpoint, save_checkpoint
from .data import SyntheticDataset
from .optimizer import AdamWConfig, adamw_init, adamw_update
from .train_step import (TrainState, init_train_state, make_train_step,
                         train_state_specs)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "SyntheticDataset",
           "TrainState", "init_train_state", "make_train_step",
           "train_state_specs", "save_checkpoint", "restore_checkpoint",
           "latest_step"]
