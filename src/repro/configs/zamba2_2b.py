"""zamba2-2b [hybrid]: 54L d_model=2048 attention-sparse, vocab=32000;
Mamba2 (SSD) backbone with one shared global-attention layer per 6-layer
block, GQA kv=4.  [arXiv:2411.15242; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2b", family="hybrid",
    n_layers=54, d_model=2048, n_heads=16, n_kv_heads=4,
    d_ff=8192, vocab_size=32000,
    layer_pattern=("ssd", "ssd", "ssd", "ssd", "ssd", "global"),
    ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    tie_embeddings=True,
)
