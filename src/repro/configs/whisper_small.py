"""whisper-small [audio]: 12L enc + 12L dec, d_model=768 12H d_ff=3072
vocab=51865; enc-dec, conv frontend is a STUB (input_specs provides the
post-conv frame embeddings, len 1500).  [arXiv:2212.04356; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=51865,
    activation="gelu", norm="layernorm", mlp_bias=True, qkv_bias=True,
    encoder_layers=12, frontend="audio", frontend_len=1500,
)
