"""Architecture registry: the 11 model-zoo configs + the paper's own
factorization workload configs, plus reduced smoke/zoo variants and the
(arch x input-shape) cell table used by the dry-run."""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCHS: dict[str, str] = {
    "stablelm-12b": "stablelm_12b",
    "nemotron-4-340b": "nemotron_4_340b",
    "qwen2.5-3b": "qwen2_5_3b",
    "gemma2-2b": "gemma2_2b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "mixtral-8x7b": "mixtral_8x7b",
    "whisper-small": "whisper_small",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "mamba2-370m": "mamba2_370m",
    "internvl2-76b": "internvl2_76b",
    "zamba2-2b": "zamba2_2b",
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.CONFIG


def list_archs() -> list[str]:
    return list(ARCHS)


# ------------------------------------------------------------- input shapes
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def _subquadratic(cfg: ModelConfig) -> bool:
    """True iff every layer's cost/state is bounded independent of context
    length (ssd / recurrent / local-window only)."""
    if cfg.is_encdec:
        return False
    kinds = set(cfg.layer_pattern)
    if "global" in kinds:
        return False
    if "local" in kinds and cfg.window is None:
        return False
    return True


def cell_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and not _subquadratic(cfg):
        return False, "full attention: 500k decode skipped (see DESIGN.md)"
    return True, ""


def all_cells() -> list[tuple[str, str]]:
    """Every live (arch, shape) dry-run cell."""
    cells = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, _ = cell_applicable(cfg, shape)
            if ok:
                cells.append((arch, shape))
    return cells


# --------------------------------------------------------------- zoo cfgs
def make_zoo(cfg: ModelConfig) -> ModelConfig:
    """Roofline-representative reduced config: real widths, reduced depth.

    Keeps `d_model`, `d_ff`, head/expert/state dimensions (and therefore
    per-layer arithmetic intensity) at production values, but cuts depth
    to one layer-pattern period and shrinks the vocabulary and
    encoder/frontend stubs so the cell lowers + compiles in ~a second on
    CPU. Because the layer pattern repeats, per-layer roofline terms --
    and the compute/memory/collective *ratios* that derive the per-kind
    frequency-sensitivity betas (docs/ROOFLINE.md) -- are representative
    of the full-depth model, unlike `make_smoke` whose tiny widths make
    every phase look memory-bound.

    Parameters
    ----------
    cfg : ModelConfig
        A production config from `ARCHS`.

    Returns
    -------
    ModelConfig
        The reduced same-family config (name suffixed ``-zoo``).
    """
    period = cfg.pattern_period
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-zoo",
        n_layers=period + (1 if cfg.n_tail_layers else 0),
        vocab_size=min(cfg.vocab_size, 4096),
        window=min(cfg.window, 512) if cfg.window else None,
        encoder_layers=min(cfg.encoder_layers, 1) if cfg.encoder_layers
        else 0,
        frontend_len=min(cfg.frontend_len, 256) if cfg.frontend_len else 0,
    )


# --------------------------------------------------------------- smoke cfgs
def make_smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config: tiny widths, few layers/experts, runnable
    in seconds on CPU. Pattern period and every structural feature are kept."""
    period = cfg.pattern_period
    small_layers = period * 2 + (1 if cfg.n_tail_layers else 0)
    heads = min(cfg.n_heads, 4) if cfg.n_heads else 0
    kv = min(cfg.n_kv_heads, heads) if cfg.n_kv_heads else 0
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=small_layers,
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16 if cfg.n_heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        window=min(cfg.window, 32) if cfg.window else None,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        moe_group_size=64,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        lru_width=64 if cfg.lru_width else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        frontend_len=16 if cfg.frontend_len else 0,
        dtype="float32",
    )
