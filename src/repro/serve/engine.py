"""Batched serving loop: prefill once, decode autoregressively with the
model-family-appropriate cache (linear KV / ring KV / recurrent states)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import ModelApi


def greedy_sample(logits, key):
    del key
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(logits, key, temperature: float = 0.8):
    return jax.random.categorical(
        key, logits.astype(jnp.float32) / temperature, axis=-1
    ).astype(jnp.int32)


@dataclasses.dataclass
class GenerationResult:
    tokens: jax.Array            # [B, n_new]
    prefill_logits: jax.Array    # [B, V]


def generate(api: ModelApi, params, batch: dict, n_new: int,
             sampler=greedy_sample, seed: int = 0,
             max_len: int | None = None) -> GenerationResult:
    """batch: {"tokens": [B, S], (+ audio/vision embeds)}."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    if max_len is None:
        max_len = s + n_new
    elif s + n_new > max_len:
        # an undersized cache would silently wrap/overwrite positions
        # >= max_len (ring KV) or drop them (linear KV) mid-generation
        raise ValueError(
            f"prompt ({s}) + n_new ({n_new}) tokens exceed max_len="
            f"{max_len}; pass max_len >= {s + n_new} or omit it")
    cache = api.init_cache(b, max_len, "init")
    logits, cache = api.prefill(params, batch, cache)
    key = jax.random.key(seed)

    # simple python loop (n_new is small in tests/examples); each step jits
    out_tokens = []
    key, sub = jax.random.split(key)
    tok = sampler(logits, sub)[:, None]
    out_tokens.append(tok)
    pos = s
    for i in range(n_new - 1):
        logits_i, cache = api.decode_step(params, tok, cache,
                                          jnp.asarray(pos + i, jnp.int32))
        key, sub = jax.random.split(key)
        tok = sampler(logits_i, sub)[:, None]
        out_tokens.append(tok)
    return GenerationResult(tokens=jnp.concatenate(out_tokens, axis=1),
                            prefill_logits=logits)
