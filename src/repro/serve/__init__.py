from .engine import GenerationResult, generate, greedy_sample, temperature_sample

__all__ = ["GenerationResult", "generate", "greedy_sample",
           "temperature_sample"]
