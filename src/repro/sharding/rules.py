"""Adaptive logical-axis -> mesh-axis sharding rules (MaxText/t5x style).

Logical names are split between activations (batch, seq, embed, heads,
kv_heads, act_ff, act_vocab, act_experts, kv_seq, state) and weights
(wembed, wff, wheads, wkv, whead_dim, wvocab, wexperts, wstate, layers) so
FSDP can shard weight dims over the data axis without touching activations.

`make_rules` adapts to each architecture: a logical axis maps to the
"model" (tensor-parallel) mesh axis only when its size divides by the TP
degree -- e.g. gemma2-2b's 8 query heads on a 16-wide TP axis fall back to
replicated heads while its d_ff=9216 still tensor-shards. KV caches whose
head count cannot shard get their *sequence* dim sharded instead during
decode (flash-decoding style; GSPMD inserts the partial-softmax collectives).
"""

from __future__ import annotations

import dataclasses
from contextlib import contextmanager

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.compat import shard_map

Rules = dict[str, tuple[str, ...] | str | None]


@dataclasses.dataclass
class ShardingCtx:
    mesh: Mesh
    rules: Rules


_CTX: ShardingCtx | None = None


def set_ctx(ctx: ShardingCtx | None) -> None:
    global _CTX
    _CTX = ctx


def get_ctx() -> ShardingCtx | None:
    return _CTX


@contextmanager
def use_sharding(mesh: Mesh, rules: Rules):
    prev = _CTX
    set_ctx(ShardingCtx(mesh, rules))
    try:
        yield
    finally:
        set_ctx(prev)


def spec_for(axes: tuple[str | None, ...], rules: Rules) -> PartitionSpec:
    parts = []
    used: set[str] = set()
    for ax in axes:
        m = rules.get(ax) if ax is not None else None
        # one mesh axis may appear at most once in a spec
        if m is None:
            parts.append(None)
        elif isinstance(m, str):
            parts.append(m if m not in used else None)
            used.add(m)
        else:
            free = tuple(x for x in m if x not in used)
            parts.append(free if free else None)
            used.update(free)
    return PartitionSpec(*parts)


def constraint(x: jax.Array, axes: tuple[str | None, ...]) -> jax.Array:
    """Sharding constraint by logical axes; identity when no ctx is set
    (CPU smoke tests) so model code stays mesh-agnostic."""
    ctx = _CTX
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec_for(axes, ctx.rules)))


def row_parallel_rs(h: jax.Array, w: jax.Array, subscripts: str,
                    contract_axis: str, *, seq_dim: int = 1) -> jax.Array:
    """Row-parallel matmul with an explicit reduce-scatter epilogue.

    einsum(subscripts, h, w) where the contracted dim is sharded over the
    "model" mesh axis (TP). Under sequence parallelism the per-rank partial
    sums are reduce-scattered (bf16) directly onto the sequence-sharded
    residual stream -- (G-1)/G bytes moved instead of the 2(G-1)/G of the
    all-reduce the partitioner would otherwise emit, and no full-size f32
    buffer materializes. Falls back to einsum + constraint when SP is off,
    when there is no sharding ctx (CPU smoke tests), or when the contracted
    dim does not shard (e.g. gemma2's 8 heads on a 16-wide TP axis).

    h: [b, s, ...contract], w: [...contract, d] per `subscripts`.
    The shard_map is partial-manual (axis_names={"model"}): batch/FSDP
    sharding over the remaining mesh axes stays under GSPMD control.
    """
    ctx = _CTX
    sp = (ctx is not None and ctx.rules.get("res_seq") == "model"
          and ctx.rules.get(contract_axis) == "model")
    if sp:
        sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
        dp_axes = tuple(n for n in ctx.mesh.axis_names if n != "model")
        dp = 1
        for ax in dp_axes:
            dp *= sizes[ax]
        sp = (h.shape[seq_dim] % sizes.get("model", 1) == 0
              and h.shape[0] % dp == 0)
    if not sp:
        y = jnp.einsum(subscripts, h, w)
        return constraint(y, ("batch", "res_seq", "embed"))

    # fully-manual shard_map: batch over the data axes, contract dim over
    # "model"; w arrives TP-sharded on its leading dim but FSDP-gathered
    # (the in_spec leaves its trailing dims unsharded, so GSPMD performs
    # the per-layer FSDP all-gather outside, exactly as in the baseline).
    h_spec = [None] * h.ndim
    h_spec[0] = dp_axes
    h_spec[-1 if h.ndim == 3 else 2] = "model"     # bsf / bshe: shard f / h
    w_spec = ["model"] + [None] * (w.ndim - 1)
    out_spec = [dp_axes, "model", None]            # [b, s/G, d]

    # TPU: reduce-scatter the bf16 partials (half the f32 bytes). The CPU
    # backend used for dry-runs crashes promoting a bf16 reduce-scatter
    # (XLA AllReducePromotion bug), so scatter f32 there -- still (G-1)/G
    # bytes vs the all-reduce's 2(G-1)/G; EXPERIMENTS.md S-Perf accounts
    # the extra TPU-side 2x analytically.
    scatter_dtype = h.dtype if jax.default_backend() == "tpu" \
        else jnp.float32

    def body(hl, wl):
        y = jnp.einsum(subscripts, hl, wl,
                       preferred_element_type=jnp.float32)
        y = y.astype(scatter_dtype)
        y = jax.lax.psum_scatter(y, "model", scatter_dimension=seq_dim,
                                 tiled=True)
        return y.astype(hl.dtype)

    fn = shard_map(body, mesh=ctx.mesh,
                       in_specs=(PartitionSpec(*h_spec),
                                 PartitionSpec(*w_spec)),
                       out_specs=PartitionSpec(*out_spec))
    return constraint(fn(h, w), ("batch", "res_seq", "embed"))


def sp_active(x, seq_dim: int = 1) -> bool:
    """True iff sequence parallelism applies to this activation here."""
    ctx = _CTX
    if ctx is None or ctx.rules.get("res_seq") != "model":
        return False
    sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
    dp = 1
    for ax in ctx.mesh.axis_names:
        if ax != "model":
            dp *= sizes[ax]
    return (x.ndim >= 2 and x.shape[seq_dim] % sizes.get("model", 1) == 0
            and x.shape[0] % dp == 0)


def sp_gather_seq(x: jax.Array, seq_dim: int = 1) -> jax.Array:
    """All-gather the sequence-sharded residual stream over the TP axis.

    The Megatron-SP column-parallel entry: forward is an all-gather along
    seq; its TRANSPOSE is a psum_scatter, so the backward dgrad partial
    sums are reduce-scattered back onto the sequence shards automatically.
    No-op when SP is off. Comms run in bf16 on TPU; f32 on the CPU dry-run
    backend (bf16 reduce-scatter crashes XLA CPU's AllReducePromotion).
    """
    if not sp_active(x, seq_dim):
        return constraint(x, ("batch", "seq", "embed"))
    ctx = _CTX
    dp_axes = tuple(n for n in ctx.mesh.axis_names if n != "model")
    comm_dtype = x.dtype if jax.default_backend() == "tpu" else jnp.float32

    spec_in = [None] * x.ndim
    spec_in[0] = dp_axes
    spec_in[seq_dim] = "model"
    spec_out = [None] * x.ndim
    spec_out[0] = dp_axes

    def body(xl):
        y = jax.lax.all_gather(xl.astype(comm_dtype), "model",
                               axis=seq_dim, tiled=True)
        return y.astype(xl.dtype)

    # check_vma=False: the tiled all_gather's output IS replicated over
    # "model" but the varying-axes checker cannot infer that statically.
    fn = shard_map(body, mesh=ctx.mesh,
                       in_specs=(PartitionSpec(*spec_in),),
                       out_specs=PartitionSpec(*spec_out),
                       check_vma=False)
    return fn(constraint(x, ("batch", "res_seq", "embed")))


def rule_is_model(axis_name: str) -> bool:
    """True iff the current rules map this logical axis to the TP axis."""
    return _CTX is not None and _CTX.rules.get(axis_name) == "model"


def column_parallel_ag(x: jax.Array, ws: list[jax.Array],
                       subscripts: list[str], contract_axis: str,
                       seq_dim: int = 1) -> list[jax.Array]:
    """Column-parallel matmuls fused with the SP sequence all-gather.

    One shard_map: all-gather the sequence-sharded x over "model", apply
    each einsum against its TP-sharded weight (outputs sharded on the
    heads/ff dim). Because the matmuls live INSIDE the shard_map, the
    backward dgrad partial sums flow directly into the all-gather's
    transpose (psum_scatter) -- no full-size all-reduce materializes, the
    Megatron-SP backward. Falls back to plain einsums when SP is off.

    ws[i] must have its dim 1 sharded over "model" (wheads / wff layout).
    """
    if not sp_active(x, seq_dim) or not rule_is_model(contract_axis):
        x = constraint(x, ("batch", "seq", "embed"))
        return [jnp.einsum(s, x, w) for s, w in zip(subscripts, ws)]
    ctx = _CTX
    dp_axes = tuple(n for n in ctx.mesh.axis_names if n != "model")
    comm_dtype = x.dtype if jax.default_backend() == "tpu" else jnp.float32

    x_spec = [None] * x.ndim
    x_spec[0] = dp_axes
    x_spec[seq_dim] = "model"
    w_specs = []
    out_specs = []
    for w in ws:
        wsp = [None] * w.ndim
        wsp[1] = "model"
        w_specs.append(PartitionSpec(*wsp))
        osp = [None] * (w.ndim + 1)   # bsd,d<shard>... -> bs<shard>...
        osp[0] = dp_axes
        osp[2] = "model"
        out_specs.append(PartitionSpec(*osp))

    def body(xl, *wls):
        xf = jax.lax.all_gather(xl.astype(comm_dtype), "model",
                                axis=seq_dim, tiled=True).astype(xl.dtype)
        return tuple(jnp.einsum(s, xf, wl)
                     for s, wl in zip(subscripts, wls))

    fn = shard_map(body, mesh=ctx.mesh,
                       in_specs=(PartitionSpec(*x_spec), *w_specs),
                       out_specs=tuple(out_specs), check_vma=False)
    return list(fn(constraint(x, ("batch", "res_seq", "embed")), *ws))


def make_rules(cfg, mesh: Mesh, *, workload: str = "train",
               fsdp: bool = True, seq_len: int | None = None,
               seq_parallel: bool = True) -> Rules:
    """Build the logical->mesh mapping for one (architecture, mesh, workload).

    workload: "train" | "prefill" | "decode".
    seq_parallel: shard the residual stream's sequence dim over the TP axis
    (Megatron-SP): converts the per-layer TP all-reduces into
    reduce-scatter + all-gather pairs (half the bytes) and shards the
    remat-saved layer-boundary activations TP-ways. train/prefill only.
    """
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = axis_sizes.get("model", 1)
    dp_axes = tuple(n for n in mesh.axis_names if n != "model")

    def fits(n: int) -> bool:
        return n > 0 and n % tp == 0

    heads_ok = fits(cfg.n_heads)
    kv_ok = fits(cfg.n_kv_heads)
    ff_ok = fits(cfg.d_ff)
    vocab_ok = fits(cfg.vocab_size)
    experts_ok = fits(cfg.n_experts)
    inner = cfg.ssm_expand * cfg.d_model
    ssm_ok = cfg.ssm_state > 0 and fits(inner // max(cfg.ssm_head_dim, 1))
    lru_ok = cfg.lru_width > 0 and fits(cfg.lru_width)

    # SP measurably hurts the attention-free SSD chunk pipeline (mamba2
    # train_4k memory term 13.2s -> 46.7s: the chunked scan's reshapes
    # fight the seq sharding) -- keep it off for pure-SSM archs.
    ssm_only = set(getattr(cfg, "layer_pattern", ())) == {"ssd"}
    sp_ok = (seq_parallel and workload in ("train", "prefill")
             and not ssm_only
             and seq_len is not None and tp > 1 and seq_len % tp == 0)

    rules: Rules = {
        # activations
        "batch": dp_axes,
        "seq": None,
        # residual-stream sequence dim (layer boundaries): Megatron-SP
        "res_seq": "model" if sp_ok else None,
        "embed": None,
        "heads": "model" if heads_ok else None,
        "kv_heads": "model" if kv_ok else None,
        "head_dim": None,
        "act_ff": "model" if ff_ok else None,
        "act_vocab": "model" if vocab_ok else None,
        "act_experts": "model" if experts_ok else None,
        "act_state": None,
        "act_lru": "model" if lru_ok else None,
        "ssm_heads": "model" if ssm_ok else None,
        "kv_seq": None,
        # weights
        "layers": None,
        "wembed": dp_axes if fsdp else None,
        "wff": "model" if ff_ok else None,
        "wheads": "model" if heads_ok else None,
        "wkv": "model" if kv_ok else None,
        "whead_dim": None,
        "wvocab": "model" if vocab_ok else None,
        "wexperts": "model" if experts_ok else None,
        "wexpert_ff": None if experts_ok else ("model" if ff_ok else None),
        "wstate": None,
        "wlru": "model" if lru_ok else None,
        "wssm_heads": "model" if ssm_ok else None,
    }

    if workload in ("decode", "prefill") and not kv_ok and seq_len \
            and fits(seq_len):
        # flash-decoding style: shard the KV cache along sequence instead.
        # prefill writes the cache seq-sharded (slice of the replicated
        # k/v), decode reads it with the partial-softmax merge -- either
        # way the resident cache drops TP-ways (stablelm/internvl2
        # prefill_32k: 12.7 -> 1.6 GiB args; S-Dry-run memory table).
        rules["kv_seq"] = "model"

    if workload == "decode":
        # decode batches are small; keep batch on data axes only (already)
        pass

    # MoE dispatch groups ride the batch axes
    rules["moe_groups"] = dp_axes
    return rules
