from .rules import (ShardingCtx, constraint, get_ctx, make_rules, set_ctx,
                    spec_for, use_sharding)

__all__ = ["ShardingCtx", "constraint", "get_ctx", "make_rules", "set_ctx",
           "spec_for", "use_sharding"]
