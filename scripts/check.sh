#!/usr/bin/env bash
# One-stop verification entry point for PRs.
#
#   scripts/check.sh          tier-1 suite + simulator differential suite
#                             + full benchmark run compared against the
#                             committed BENCH_pr<N>.json trajectory
#   scripts/check.sh --fast   skip tests marked `slow` (multi-device
#                             subprocess runs take minutes) and the
#                             benchmark-trajectory comparison
#
# Tier-1 (ROADMAP.md): PYTHONPATH=src python -m pytest -x -q
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

FAST=0
MARK=()
if [[ "${1:-}" == "--fast" ]]; then
    FAST=1
    MARK=(-m "not slow")
fi

# fail fast on collection errors before anything expensive runs (listing
# suppressed on success, shown with the error on failure)
echo "== collection preflight =="
python -m pytest --co -q >/tmp/collect.log 2>&1 \
    || { cat /tmp/collect.log; exit 1; }

# differential suite runs as its own step below; keep tier-1 disjoint
echo "== tier-1 test suite =="
python -m pytest -x -q --ignore=tests/test_scheduler_differential.py \
    ${MARK[@]+"${MARK[@]}"}

echo "== scheduler differential suite (simulate / reference / fleet) =="
python -m pytest -x -q tests/test_scheduler_differential.py

# benchmark trajectory: when a committed BENCH_pr<N>.json exists (and not
# --fast), run the FULL suite once -- it includes sim_speed, so the
# standalone speedup step would be a duplicate -- and gate >20% regressions
# against the newest trajectory file. Otherwise just run sim_speed.
prev=""
if [[ "$FAST" -eq 0 ]]; then
    prev=$(ls BENCH_pr*.json 2>/dev/null | sort -V | tail -1 || true)
fi
if [[ -n "$prev" ]]; then
    echo "== full benchmark suite + trajectory vs $prev =="
    python -m benchmarks.run --json /tmp/bench_head.json
    python scripts/bench_compare.py "$prev" /tmp/bench_head.json
else
    echo "== simulator speedup benchmark (target >= 5x) =="
    python -m benchmarks.run --only sim_speed
fi
