#!/usr/bin/env bash
# One-stop verification entry point for PRs.
#
#   scripts/check.sh          tier-1 suite + simulator differential suite
#   scripts/check.sh --fast   skip tests marked `slow` (multi-device
#                             subprocess runs take minutes)
#
# Tier-1 (ROADMAP.md): PYTHONPATH=src python -m pytest -x -q
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MARK=()
if [[ "${1:-}" == "--fast" ]]; then
    MARK=(-m "not slow")
fi

# differential suite runs as its own step below; keep tier-1 disjoint
echo "== tier-1 test suite =="
python -m pytest -x -q --ignore=tests/test_scheduler_differential.py \
    ${MARK[@]+"${MARK[@]}"}

echo "== scheduler differential suite =="
python -m pytest -x -q tests/test_scheduler_differential.py

echo "== simulator speedup benchmark (target >= 5x) =="
python -m benchmarks.run --only sim_speed
