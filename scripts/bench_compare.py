#!/usr/bin/env python3
"""Diff two `benchmarks.run --json` files and gate perf regressions.

    python scripts/bench_compare.py OLD.json NEW.json [--threshold 0.2]

Compares every metric the two files share and exits nonzero when a gated
metric regressed by more than --threshold (default 20%, relative to the
old value):

  * simulator speed  -- `sim_speed` keys ending in `.speedup` plus
                        `worst_speedup` (higher is better). Speedups are
                        wall-clock-derived and noisy across machines and
                        loaded CI runners, so the gate for them is the
                        repo's hard acceptance target (--speedup-floor,
                        default 5.0, the >=5x sim_speed target), applied
                        unconditionally: 9x -> 6x on a busy runner is
                        noise (reported as drift), anything under 5x
                        fails -- however small the relative drop, so the
                        per-PR baseline refresh cannot ratchet below it.
                        `sim_speed.fleet_speedup` (the batched engine vs
                        per-lane oracle runs) carries its own hard floor
                        (--fleet-floor, default 50.0, the >=50x ISSUE 6
                        target) under the same rule, and so does
                        `sim_speed.search_throughput_ratio` (the batched
                        plan-candidate evaluator vs the naive
                        per-candidate loop: --search-floor, default 30.0,
                        the >=30x ISSUE 7 target).
  * serving energy   -- `serving` keys ending in `.j_per_token` (energy
                        per generated token; LOWER is better, fully
                        deterministic). A relative RISE above
                        --serving-floor (default 0.20) fails; smaller
                        moves are reported as drift. Additionally, any
                        `serving` key ending in `.slo_ok` that flips
                        True -> False fails the gate: a strategy whose
                        p99 latency newly violates the SLO is a serving
                        regression even if it saves energy.
  * energy savings   -- any section metric whose key contains `saved`
                        (strategy energy-savings percentages; higher is
                        better, fully deterministic). Near-zero baselines
                        are exempted by an absolute floor (--abs-floor,
                        default 0.25 points) so noise around 0% cannot
                        flap CI. Keys containing `migrate` (the
                        heterogeneous section's tx_migrate savings and
                        migration-sweep cells) are trajectory-only:
                        reported as drift, never gated, since the
                        migration win depends on the machine ratio and
                        link speed under study.

Also fails if `sim_speed.all_agree`, `sim_speed.fleet_agree`, or
`sim_speed.search_agree` flipped from true to false (engines disagreeing
is a correctness red flag, not a perf regression).

Non-gated metrics (timings, wait fractions, gflops) are reported as
informational drift only. Metrics present in only one file NEVER fail the
gate: sections grow across PRs by design, so a metric that exists only in
NEW.json is reported as an addition (it starts gating once a trajectory
file containing it is committed), and one that exists only in OLD.json is
reported as dropped. Malformed sections (non-dict payloads) are skipped
rather than crashing the gate. Behavior pinned by
tests/test_bench_compare.py.
"""

from __future__ import annotations

import argparse
import json
import sys


def _flat_metrics(report: dict) -> dict[str, float]:
    """{'section.key': value} for every numeric, non-timing metric."""
    out: dict[str, float] = {}
    for section, metrics in report.get("sections", {}).items():
        if not isinstance(metrics, dict):
            continue            # malformed/foreign section: skip, don't crash
        for key, val in metrics.items():
            if key == "seconds":
                continue
            if isinstance(val, bool) or not isinstance(val, (int, float)):
                continue
            out[f"{section}.{key}"] = float(val)
    return out


def _is_speedup(name: str) -> bool:
    section, _, key = name.partition(".")
    return section == "sim_speed" and (key.endswith(".speedup")
                                       or key == "worst_speedup")


def _is_fleet_speedup(name: str) -> bool:
    return name == "sim_speed.fleet_speedup"


def _is_search_ratio(name: str) -> bool:
    return name == "sim_speed.search_throughput_ratio"


def _is_serving_j_per_token(name: str) -> bool:
    section, _, key = name.partition(".")
    return section == "serving" and key.endswith(".j_per_token")


def _gated(name: str) -> bool:
    key = name.partition(".")[2]
    if "migrate" in key:
        # migration metrics (tx_migrate savings, sweep cells) are
        # trajectory-only: the win depends on the big:LITTLE ratio and
        # link speed, so they are recorded and reported as drift, never
        # gated (pinned by tests/test_bench_compare.py)
        return False
    return (_is_speedup(name) or _is_fleet_speedup(name)
            or _is_search_ratio(name) or _is_serving_j_per_token(name)
            or "saved" in key)


def main() -> int:
    ap = argparse.ArgumentParser(
        description="gate >threshold regressions between two BENCH_*.json")
    ap.add_argument("old", help="previous trajectory file (BENCH_pr<N>.json)")
    ap.add_argument("new", help="fresh benchmarks.run --json output")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="max allowed relative drop on gated metrics")
    ap.add_argument("--abs-floor", type=float, default=0.25,
                    help="ignore drops smaller than this many absolute "
                         "points (de-noises near-zero savings)")
    ap.add_argument("--speedup-floor", type=float, default=5.0,
                    help="sim_speed speedup drops only fail when the new "
                         "value is also below this hard target (timing "
                         "noise across machines is otherwise expected)")
    ap.add_argument("--fleet-floor", type=float, default=50.0,
                    help="hard floor for sim_speed.fleet_speedup (the "
                         "batched-engine aggregate target), same rule as "
                         "--speedup-floor")
    ap.add_argument("--search-floor", type=float, default=30.0,
                    help="hard floor for "
                         "sim_speed.search_throughput_ratio (the batched "
                         "candidate-evaluator target), same rule as "
                         "--speedup-floor")
    ap.add_argument("--serving-floor", type=float, default=0.20,
                    help="max allowed relative RISE on serving "
                         "*.j_per_token metrics (lower is better; "
                         "deterministic, so no absolute floor applies)")
    args = ap.parse_args()

    with open(args.old) as f:
        old = json.load(f)
    with open(args.new) as f:
        new = json.load(f)
    old_m, new_m = _flat_metrics(old), _flat_metrics(new)

    regressions: list[str] = []
    drifts: list[str] = []
    for name in sorted(old_m.keys() & new_m.keys()):
        o, n = old_m[name], new_m[name]
        drop = o - n
        rel = drop / abs(o) if o else 0.0
        line = f"{name}: {o:g} -> {n:g}"
        if _is_fleet_speedup(name):
            if n < args.fleet_floor:
                regressions.append(
                    f"{line}  (below the {args.fleet_floor:g}x target)")
            elif drop > args.abs_floor and rel > args.threshold:
                drifts.append(f"{line}  (timing noise, still >= "
                              f"{args.fleet_floor:g}x)")
            continue
        if _is_search_ratio(name):
            if n < args.search_floor:
                regressions.append(
                    f"{line}  (below the {args.search_floor:g}x target)")
            elif drop > args.abs_floor and rel > args.threshold:
                drifts.append(f"{line}  (timing noise, still >= "
                              f"{args.search_floor:g}x)")
            continue
        if _is_serving_j_per_token(name):
            # lower is better: gate the relative RISE
            rise = n - o
            rel_rise = rise / abs(o) if o else 0.0
            if rel_rise > args.serving_floor:
                regressions.append(f"{line}  (+{100 * rel_rise:.1f}% "
                                   "J/token)")
            elif abs(rel) > args.threshold:
                drifts.append(line)
            continue
        if _is_speedup(name):
            # hard floor, independent of the relative drop: a refreshed
            # baseline must not let the target erode PR by PR
            if n < args.speedup_floor:
                regressions.append(
                    f"{line}  (below the {args.speedup_floor:g}x target)")
            elif drop > args.abs_floor and rel > args.threshold:
                drifts.append(f"{line}  (timing noise, still >= "
                              f"{args.speedup_floor:g}x)")
            continue
        if _gated(name):
            if drop > args.abs_floor and rel > args.threshold:
                regressions.append(f"{line}  (-{100 * rel:.1f}%)")
            continue
        if o and abs(rel) > args.threshold:
            drifts.append(line)

    for flag in ("all_agree", "fleet_agree", "search_agree"):
        agree_old = old.get("sections", {}).get("sim_speed", {}).get(flag)
        agree_new = new.get("sections", {}).get("sim_speed", {}).get(flag)
        if agree_old is True and agree_new is False:
            regressions.append(f"sim_speed.{flag}: True -> False "
                               "(engine disagreement)")

    # serving SLO flips: a strategy whose p99 newly violates the SLO
    # (slo_ok True -> False vs the committed trajectory) is a regression;
    # metrics present in only one file stay non-gating as usual.
    old_srv = old.get("sections", {}).get("serving", {})
    new_srv = new.get("sections", {}).get("serving", {})
    if isinstance(old_srv, dict) and isinstance(new_srv, dict):
        for key in sorted(old_srv.keys() & new_srv.keys()):
            if (key.endswith(".slo_ok") and old_srv[key] is True
                    and new_srv[key] is False):
                regressions.append(f"serving.{key}: True -> False "
                                   "(p99 newly violates the SLO)")

    only_old = sorted(old_m.keys() - new_m.keys())
    only_new = sorted(new_m.keys() - old_m.keys())
    print(f"compared {len(old_m.keys() & new_m.keys())} shared metrics "
          f"({args.old} vs {args.new})")
    if only_old:
        print(f"  dropped metrics ({len(only_old)}, not gated): "
              + ", ".join(only_old[:8]) + ("..." if len(only_old) > 8 else ""))
    if only_new:
        print(f"  additions ({len(only_new)}, gate from next trajectory): "
              + ", ".join(only_new[:8]) + ("..." if len(only_new) > 8 else ""))
    for line in drifts:
        print(f"  drift (informational): {line}")
    if regressions:
        print(f"\nREGRESSIONS (> {100 * args.threshold:.0f}% drop):")
        for line in regressions:
            print(f"  {line}")
        return 1
    print("no gated regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
