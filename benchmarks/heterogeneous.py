"""Asymmetric-cluster section: the paper's strategies on big.LITTLE-style
machines across big:LITTLE ratios (Costero et al.'s framing), plus the
mixed-accelerator pod.

For each machine configuration the full strategy registry runs through
`evaluate_strategies` (savings are vs that machine's own `original`), and
the machine's baseline energy/makespan are additionally compared against
the all-big homogeneous cluster -- the cost of the LITTLE ranks themselves.
Everything is simulator-deterministic, so the `*.saved_pct` metrics join
the bench-trajectory gate (scripts/bench_compare.py) like the homogeneous
sections' do; first recorded in BENCH_pr4.json.
"""

from __future__ import annotations

from repro.core.dag import build_dag
from repro.core.energy_model import (MachineModel, make_big_little,
                                     make_processor, make_tpu_mixed,
                                     scale_processor)
from repro.core.scheduler import CostModel, simulate
from repro.core.strategies import (PlanContext, evaluate_strategies,
                                   get_strategy, registered_strategies)

FACT = "cholesky"
N_TILES = 16
TILE = 512
GRID = (4, 4)              # 16 ranks; ratios below partition them
# migration sweep: inter-rank bandwidths (GB/s) from a congested fabric to
# a fat one; the 5.0 middle point is the CostModel default
LINK_SPEEDS = (1.25, 5.0, 20.0)


def machines() -> dict[str, MachineModel]:
    """Homogeneous reference + big:LITTLE ratios + the accelerator pod."""
    big = make_processor("arc_opteron_6128")
    little = scale_processor(big, big.name + "_little", freq_scale=0.6,
                             volt_scale=0.85, cap_scale=0.45, leak_scale=0.6)
    out = {"homog_big": MachineModel.homogeneous(big)}
    for n_big, n_little in ((3, 1), (1, 1), (1, 3)):
        out[f"bl_{n_big}_{n_little}"] = make_big_little(
            big, little, n_big=n_big, n_little=n_little)
    out["tpu_mixed"] = make_tpu_mixed()
    return out


def run(n_tiles: int = N_TILES, tile: int = TILE, grid=GRID):
    cost = CostModel()
    graph = build_dag(FACT, n_tiles, tile, grid)
    names = registered_strategies()
    rows = []
    homog_base = None
    for cfg, machine in machines().items():
        res = evaluate_strategies(graph, machine, cost, names=names)
        base = res["original"]
        if homog_base is None:
            homog_base = base            # machines() lists homog_big first
        for name in names:
            r = res[name]
            rows.append({
                "machine": cfg, "strategy": name,
                "makespan_s": r.makespan_s, "energy_j": r.energy_j,
                "slowdown_pct": r.slowdown_pct,
                "energy_saved_pct": r.energy_saved_pct,
                "gear_switches": r.switch_count,
                # this machine's baseline vs the all-big cluster's
                "base_energy_ratio": base.energy_j / homog_base.energy_j,
                "base_makespan_ratio": base.makespan_s
                / homog_base.makespan_s,
            })
    return rows


def migration_sweep(n_tiles: int = 8, tile: int = 256, grid=(2, 2)):
    """tx_migrate vs the frozen-mapping tx across big:LITTLE ratios and
    link speeds: how much energy moving slack-heavy update tasks off the
    LITTLE ranks recovers, and at what simulated slowdown.

    The DAG is smaller than the main section's (each cell re-plans and
    fleet-scores migration candidates); savings are vs `tx` on the SAME
    machine and link, so the number isolates the mapping change itself.
    """
    graph = build_dag(FACT, n_tiles, tile, grid)
    rows = []
    for ratio in ("bl_3_1", "bl_1_1", "bl_1_3"):
        machine = machines()[ratio]
        for bw in LINK_SPEEDS:
            cost = CostModel(comm_bandwidth_gbs=bw)
            ctx = PlanContext(graph, machine, cost)
            plan_tx = get_strategy("tx").plan(ctx)
            plan_mig = get_strategy("tx_migrate").plan(ctx)
            s_tx = simulate(graph, machine, cost, plan_tx)
            s_mig = simulate(graph, machine, cost, plan_mig)
            moved = 0 if plan_mig.task_owners is None else sum(
                1 for t, o in zip(graph.tasks, plan_mig.task_owners)
                if t.owner != o)
            rows.append({
                "machine": ratio, "bandwidth_gbs": bw, "n_moved": moved,
                "saved_vs_tx_pct": 100.0 * (1.0 - s_mig.total_energy_j()
                                            / s_tx.total_energy_j()),
                "slowdown_vs_tx_pct": 100.0 * (s_mig.makespan
                                               / s_tx.makespan - 1.0),
            })
    return rows


def bench() -> tuple[list[str], dict]:
    rows = run()
    out = ["machine,strategy,makespan_s,energy_j,slowdown_pct,"
           "energy_saved_pct,gear_switches"]
    for r in rows:
        out.append(f"{r['machine']},{r['strategy']},{r['makespan_s']:.4f},"
                   f"{r['energy_j']:.1f},{r['slowdown_pct']:.2f},"
                   f"{r['energy_saved_pct']:.2f},{r['gear_switches']}")
    metrics: dict[str, float] = {}
    seen_cfg = set()
    for r in rows:
        if r["strategy"] != "original":
            metrics[f"{r['machine']}.{r['strategy']}.saved_pct"] = \
                round(r["energy_saved_pct"], 3)
        if r["machine"] not in seen_cfg:
            seen_cfg.add(r["machine"])
            out.append(f"# {r['machine']}: baseline energy "
                       f"{100.0 * r['base_energy_ratio']:.1f}% / makespan "
                       f"{100.0 * r['base_makespan_ratio']:.1f}% of homog_big")
            metrics[f"{r['machine']}.base_energy_vs_homog"] = \
                round(r["base_energy_ratio"], 4)
            metrics[f"{r['machine']}.base_makespan_vs_homog"] = \
                round(r["base_makespan_ratio"], 4)
    # migration sweep: trajectory-only metrics ("migrate" in the key keeps
    # them out of the bench_compare gate -- the win depends on ratio and
    # link speed, so it is recorded, not gated)
    out.append("")
    out.append("machine,bandwidth_gbs,n_moved,migrate_saved_vs_tx_pct,"
               "migrate_slowdown_vs_tx_pct")
    for r in migration_sweep():
        out.append(f"{r['machine']},{r['bandwidth_gbs']:g},{r['n_moved']},"
                   f"{r['saved_vs_tx_pct']:.2f},"
                   f"{r['slowdown_vs_tx_pct']:.2f}")
        cell = f"{r['machine']}.bw{r['bandwidth_gbs']:g}"
        metrics[f"{cell}.migrate_saved_vs_tx_pct"] = \
            round(r["saved_vs_tx_pct"], 3)
        metrics[f"{cell}.migrate_n_moved"] = r["n_moved"]
    return out, metrics


def main() -> list[str]:
    return bench()[0]


if __name__ == "__main__":
    print("\n".join(main()))
