"""Serving-energy section: J/token + p99 latency under diurnal traffic.

Every traffic cell replays one deterministic seeded trace
(`core.serving.make_trace`) through the continuous-batching wave compiler
and scores the FULL strategy registry as lanes of one `simulate_fleet`
pass (`cores_per_node=1`: each server rank is its own node, the
zero-power clock rank rides free). Cells:

  * three traffic shapes (diurnal / bursty / flat, mean-normalized to the
    same offered load) x {homogeneous, big.LITTLE} server clusters on the
    dense profile,
  * the MoE + SSM model families on the diurnal/homogeneous cell
    (`core.serving.MODEL_PROFILES`: roofline-derived flop ratios + phase
    betas, anchored per family -- see docs/ROOFLINE.md), and
  * one `zoo_<arch>` cell per committed roofline architecture
    (diurnal/homogeneous, `core.serving.profile_for_arch`): every model
    in `results/roofline.json` becomes a CI-exercised serving scenario
    with its own measured prefill/decode betas.

Metrics per cell x strategy: `<cell>.<strategy>.j_per_token` (energy per
generated token -- LOWER is better; gated by
`scripts/bench_compare.py --serving-floor`, >20% rises fail),
`.p99_latency_ms` (drift-only), `.slo_viol_pct`, and the boolean
`.slo_ok` (p99 <= the SLO; a True -> False flip against the committed
trajectory fails the gate). The per-request SLO also enters planning as
`StrategyConfig.slo_latency_s` (trace horizon + SLO) through
`PlanContext.makespan_cap` -- note the structural finding this section
surfaces: makespan-capped planners (`single_freq_opt`, `plan_search`)
stay inside the cap yet can still wreck p99, because mid-trace queueing
drains before the horizon ends and never shows up in the makespan.
Slack-aware strategies (`tx`, `algorithmic`) save energy with the p99
untouched.
"""

from __future__ import annotations

import numpy as np

from repro.core import (MODEL_PROFILES, MachineModel, PlanContext,
                        StrategyConfig, build_serving_graph, get_strategy,
                        load_roofline, make_server_proc, make_trace,
                        p99_latency_s, profile_for_arch,
                        registered_strategies, request_latencies,
                        scale_processor, serving_cost_model, serving_machine,
                        simulate_fleet, slo_violation_rate)

N_SERVERS = 4
STEP_PERIOD_S = 0.25
RATE_RPS = 10.0
DURATION_S = 24.0
SEED = 0
SLO_LATENCY_S = 2.5       # per-request latency SLO (p99 target)
SHAPES = ("diurnal", "bursty", "flat")
EXTRA_FAMILIES = ("moe", "ssm")     # dense is the default family


def machines() -> dict[str, MachineModel]:
    """Homogeneous and 3:1 big.LITTLE server clusters (serving-class)."""
    big = make_server_proc()
    little = scale_processor(big, big.name + "_little", freq_scale=0.6,
                             volt_scale=0.85, cap_scale=0.45, leak_scale=0.6)
    return {"homog": MachineModel.homogeneous(big),
            "bl": MachineModel("serve_bl", (big, big, big, little))}


def _cell(shape: str, family: str, machine: MachineModel,
          names: tuple[str, ...]) -> list[dict]:
    """Score every registered strategy on one traffic cell.

    `family` is either a `MODEL_PROFILES` key or a `repro.configs` arch
    name (zoo cells), resolved through `profile_for_arch`.
    """
    if family in MODEL_PROFILES:
        profile = MODEL_PROFILES[family]
    else:
        profile = profile_for_arch(family)
    cost = serving_cost_model(profile)
    trace = make_trace(shape, rate_rps=RATE_RPS, duration_s=DURATION_S,
                       seed=SEED)
    sg = build_serving_graph(trace, n_servers=N_SERVERS,
                             step_period_s=STEP_PERIOD_S, cost=cost,
                             profile=profile)
    cluster = serving_machine(machine, N_SERVERS)
    cfg = StrategyConfig(plan_search_rounds=2, plan_search_lanes=64,
                         replan_every=8,
                         slo_latency_s=sg.horizon_s + SLO_LATENCY_S)
    ctx = PlanContext(sg.graph, cluster, cost, cfg)
    plans = [get_strategy(n).plan(ctx) for n in names]
    fleet = simulate_fleet(sg.graph, cluster, cost, plans, cores_per_node=1)
    energy = fleet.total_energy_j()
    lat = request_latencies(sg, fleet.finish)
    p99 = p99_latency_s(lat)
    viol = slo_violation_rate(lat, SLO_LATENCY_S)
    base = energy[names.index("original")]
    rows = []
    for i, name in enumerate(names):
        rows.append({
            "strategy": name,
            "requests": trace.n_requests,
            "j_per_token": energy[i] / trace.total_decode_tokens,
            "p99_latency_ms": float(p99[i]) * 1e3,
            "slo_viol_pct": float(viol[i]) * 100.0,
            "slo_ok": bool(p99[i] <= SLO_LATENCY_S),
            "saved_vs_original_pct": 100.0 * (1.0 - energy[i] / base),
            "makespan_s": float(fleet.makespan[i]),
        })
    return rows


def run() -> dict[str, list[dict]]:
    """All traffic cells: {cell label: per-strategy rows}."""
    names = registered_strategies()
    clusters = machines()
    cells: dict[str, list[dict]] = {}
    for shape in SHAPES:
        cells[shape] = _cell(shape, "dense", clusters["homog"], names)
        cells[f"bl_{shape}"] = _cell(shape, "dense", clusters["bl"], names)
    for family in EXTRA_FAMILIES:
        cells[family] = _cell("diurnal", family, clusters["homog"], names)
    for arch in zoo_archs():
        cells[f"zoo_{arch}"] = _cell("diurnal", arch, clusters["homog"],
                                     names)
    return cells


def zoo_archs() -> tuple[str, ...]:
    """Architectures in the committed roofline artifact (empty if absent)."""
    try:
        return load_roofline().archs()
    except (OSError, ValueError):
        return ()


def bench() -> tuple[list[str], dict]:
    """CSV lines + flat metrics for benchmarks.run / bench_compare."""
    cells = run()
    out = ["cell,strategy,j_per_token,p99_ms,slo_viol_pct,slo_ok,"
           "saved_pct,makespan_s"]
    metrics: dict[str, float | bool | int] = {}
    total_requests = 0
    for cell, rows in cells.items():
        total_requests += rows[0]["requests"] * len(rows)
        for r in rows:
            out.append(f"{cell},{r['strategy']},{r['j_per_token']:.4f},"
                       f"{r['p99_latency_ms']:.1f},{r['slo_viol_pct']:.2f},"
                       f"{int(r['slo_ok'])},{r['saved_vs_original_pct']:.2f},"
                       f"{r['makespan_s']:.3f}")
            key = f"{cell}.{r['strategy']}"
            metrics[f"{key}.j_per_token"] = round(r["j_per_token"], 4)
            metrics[f"{key}.p99_latency_ms"] = round(r["p99_latency_ms"], 1)
            metrics[f"{key}.slo_viol_pct"] = round(r["slo_viol_pct"], 2)
            metrics[f"{key}.slo_ok"] = r["slo_ok"]
    metrics["simulated_requests"] = int(total_requests)
    return out, metrics


def main() -> list[str]:
    """Print the section table (python -m benchmarks.serving_energy)."""
    return bench()[0]


if __name__ == "__main__":
    print("\n".join(main()))
