"""Paper's main table: energy saved + time overhead per factorization x
strategy on the 16 x 16 process grid (256 ranks), ARC-cluster power model.

Reproduces the paper's headline numbers:
  * CP-aware slack reclamation and race-to-halt both save substantial
    energy at < ~4% slowdown (paper: 3.5% / 3.9% average overhead).
  * The *algorithmic* schedule (the paper's contribution) matches or beats
    CP-aware savings with ~zero added overhead, because the plan is
    precomputed from the task DAG.

Rows cover every strategy in the registry (the paper's four plus `tx`, the
explicit TDS-driven plan); all strategies of one factorization share a
single PlanContext through `evaluate_strategies`.
"""

from __future__ import annotations

from repro.core.dag import build_dag
from repro.core.energy_model import make_processor
from repro.core.scheduler import CostModel
from repro.core.strategies import evaluate_strategies, registered_strategies

GRID = (16, 16)
N_TILES = 20               # 20 x 20 tiles of 640 -> 12800 matrix per run
TILE = 640


def run(n_tiles: int = N_TILES, tile: int = TILE, grid=GRID,
        proc_name: str = "arc_opteron_6128"):
    proc = make_processor(proc_name)
    cost = CostModel()
    names = registered_strategies()
    rows = []
    for fact in ("cholesky", "lu", "qr"):
        graph = build_dag(fact, n_tiles, tile, grid)
        res = evaluate_strategies(graph, proc, cost, names=names)
        for name in names:
            r = res[name]
            rows.append({
                "factorization": fact, "strategy": name,
                "makespan_s": r.makespan_s, "energy_j": r.energy_j,
                "avg_power_w": r.avg_power_w,
                "slowdown_pct": r.slowdown_pct,
                "energy_saved_pct": r.energy_saved_pct,
                "gear_switches": r.switch_count,
            })
    return rows


def bench() -> tuple[list[str], dict]:
    rows = run()
    out = ["factorization,strategy,makespan_s,energy_j,avg_power_w,"
           "slowdown_pct,energy_saved_pct,gear_switches"]
    for r in rows:
        out.append(f"{r['factorization']},{r['strategy']},"
                   f"{r['makespan_s']:.4f},{r['energy_j']:.1f},"
                   f"{r['avg_power_w']:.1f},{r['slowdown_pct']:.2f},"
                   f"{r['energy_saved_pct']:.2f},{r['gear_switches']}")
    metrics = {
        f"{r['factorization']}.{r['strategy']}.saved_pct":
            round(r["energy_saved_pct"], 3)
        for r in rows if r["strategy"] != "original"
    }
    metrics.update({
        f"{r['factorization']}.{r['strategy']}.slowdown_pct":
            round(r["slowdown_pct"], 3)
        for r in rows if r["strategy"] != "original"
    })
    return out, metrics


def main() -> list[str]:
    return bench()[0]


if __name__ == "__main__":
    print("\n".join(main()))
