"""The paper's technique applied to every LM dry-run cell: per-arch energy
of the four strategies on the compiled step's lane profile (roofline terms),
on a TPU-like device and on a hypothetical DVFS-laddered accelerator.

This is the hardware-adaptation experiment of DESIGN.md S3.2: it shows the
energy-saving *gap* between race-to-halt and (CP-aware/algorithmic) slack
reclamation collapsing on voltage-flat silicon -- the paper's conclusion,
measured on modern workloads.

When no `results/roofline.json` has been generated (the dry-run + roofline
pipeline needs real compile artifacts), the section falls back to the
checked-in synthetic fixture `benchmarks/data/roofline_fixture.json` --
seven hand-built (arch x shape) lane profiles spanning compute-, memory-,
and collective-bound steps -- so the section always exercises in CI
instead of silently no-opping.
"""

from __future__ import annotations

import json
import os

from repro.core.energy_aware_step import (StepProfile, evaluate_step,
                                          profile_from_dryrun,
                                          strategy_gap_pct)

ROOFLINE_JSON = os.path.join(os.path.dirname(__file__), "..",
                             "results", "roofline.json")
FIXTURE_JSON = os.path.join(os.path.dirname(__file__), "data",
                            "roofline_fixture.json")
DEVICES = ("tpu_like", "amd_opteron_2218", "intel_core_i7_2760qm")


def _resolve_path(path: str | None) -> str:
    """Real roofline results when present, else the synthetic fixture."""
    if path is not None:
        return path
    return ROOFLINE_JSON if os.path.exists(ROOFLINE_JSON) else FIXTURE_JSON


def _profiles(path: str | None = None, mesh: str = "16x16"):
    path = _resolve_path(path)
    if not os.path.exists(path):
        return []
    with open(path) as f:
        rows = json.load(f)
    profs = []
    for r in rows:
        if r["mesh"] != mesh:
            continue
        src = r.get("corrected", r)
        profs.append(StepProfile(r["arch"], r["shape"],
                                 mxu_s=src["compute_s"],
                                 hbm_s=src["memory_s"],
                                 ici_s=src["collective_s"]))
    return profs


def run(path: str | None = None):
    rows = []
    for p in _profiles(path):
        for dev in DEVICES:
            res = evaluate_step(p, dev)   # every registered lane strategy
            rows.append({
                "arch": p.arch, "shape": p.shape, "device": dev,
                "step_s": p.step_s, "critical_lane": p.critical_lane,
                **{f"saved_{k}_pct": v.saved_vs_original_pct
                   for k, v in res.items() if k != "original"},
                "gap_race_vs_algo_pct": strategy_gap_pct(p, dev),
            })
    return rows


def bench() -> tuple[list[str], dict]:
    rows = run()
    if not rows:
        return (["# no roofline.json yet -- run the dry-run + roofline "
                 "first"], {"profiles": 0})
    synthetic = _resolve_path(None) == FIXTURE_JSON
    out = []
    if synthetic:
        out.append("# synthetic fixture (benchmarks/data/"
                   "roofline_fixture.json); run the dry-run + roofline "
                   "pipeline for measured numbers")
    out += ["arch,shape,device,step_s,critical_lane,saved_race_to_halt_pct,"
           "saved_cp_aware_pct,saved_algorithmic_pct,saved_tx_pct,"
           "gap_race_vs_algo_pct"]
    for r in rows:
        out.append(
            f"{r['arch']},{r['shape']},{r['device']},{r['step_s']:.4f},"
            f"{r['critical_lane']},{r['saved_race_to_halt_pct']:.2f},"
            f"{r['saved_cp_aware_pct']:.2f},"
            f"{r['saved_algorithmic_pct']:.2f},"
            f"{r['saved_tx_pct']:.2f},"
            f"{r['gap_race_vs_algo_pct']:.3f}")
    metrics = {"profiles": len(rows) // max(len(DEVICES), 1),
               "synthetic_fixture": synthetic}
    # aggregate: mean gap per device -- the paper's conclusion in one line
    for dev in DEVICES:
        gaps = [r["gap_race_vs_algo_pct"] for r in rows if r["device"] == dev]
        if gaps:
            out.append(f"# mean gap on {dev}: "
                       f"{sum(gaps) / len(gaps):.3f}% of original energy")
            metrics[f"{dev}.mean_gap_pct"] = round(sum(gaps) / len(gaps), 3)
    return out, metrics


def main() -> list[str]:
    return bench()[0]


if __name__ == "__main__":
    print("\n".join(main()))
