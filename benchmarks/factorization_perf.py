"""GFLOP/s of the tiled factorizations vs matrix size (real compute) plus
the distributed kernel's per-iteration phase structure.

On CPU this measures the jnp reference path of the same tile kernels the
Pallas backend accelerates on TPU; the table's purpose is (a) scaling shape
vs the analytic flop model and (b) CI-checkable correctness under timing.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dag import factorization_flops
from repro.linalg.tiled import (dense_to_tiles, tiled_cholesky, tiled_lu,
                                tiled_qr)

SIZES = (256, 512, 1024)
TILE = 128


def _time(fn, *args, reps: int = 3):
    fn(*args)                              # compile/warm
    best = np.inf
    for _ in range(reps):
        t0 = time.time()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.time() - t0)
    return best


def run(sizes=SIZES, tile=TILE):
    rng = np.random.default_rng(0)
    rows = []
    for n in sizes:
        a = rng.standard_normal((n, n)).astype(np.float32)
        spd = jnp.asarray(a @ a.T + n * np.eye(n, dtype=np.float32))
        gen = jnp.asarray(a + np.diag(np.full(n, 2.0 * n, np.float32)))

        for name, fn, mat in (
                ("cholesky", lambda m: tiled_cholesky(dense_to_tiles(m, tile)),
                 spd),
                ("lu", lambda m: tiled_lu(dense_to_tiles(m, tile)), gen),
                ("qr", lambda m: tiled_qr(dense_to_tiles(m, tile)), gen)):
            jitted = jax.jit(lambda m, f=fn: f(m).tiles)
            dt = _time(jitted, mat)
            fl = factorization_flops(name, n)
            rows.append({"factorization": name, "n": n, "tile": tile,
                         "seconds": dt, "gflops": fl / dt / 1e9})
    return rows


def main() -> list[str]:
    rows = run()
    out = ["factorization,n,tile,seconds,gflops"]
    for r in rows:
        out.append(f"{r['factorization']},{r['n']},{r['tile']},"
                   f"{r['seconds']:.4f},{r['gflops']:.2f}")
    return out


if __name__ == "__main__":
    print("\n".join(main()))
