"""GFLOP/s of the tiled factorizations vs matrix size (real compute) plus
the distributed kernel's per-iteration phase structure.

On CPU this measures the jnp reference path of the same tile kernels the
Pallas backend accelerates on TPU; the table's purpose is (a) scaling shape
vs the analytic flop model and (b) CI-checkable correctness under timing.

A second table gives each factorization's TDS wait mix (panel / comm /
imbalance idle fractions on the matching task DAG): the wait taxonomy that
explains *why* the scaling curves flatten -- panel waits serialize, and the
trailing-matrix imbalance grows with the tile count.

A third table gives the per-kind gear-policy view (Costero-style): each
factorization's task mix by gear class (panel / solve / update, with the
gears its class table allows) and the realized savings of the
`task_type_gears` asymmetric-table plan next to the unrestricted
`algorithmic` plan and the `single_freq_opt` uniform-frequency bound.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dag import build_dag, factorization_flops
from repro.core.energy_model import make_processor
from repro.core.scheduler import CostModel
from repro.core.strategies import StrategyConfig, evaluate_strategies
from repro.core.tds import GEAR_CLASS_NAMES, compute_tds, task_gear_classes
from repro.linalg.tiled import (dense_to_tiles, tiled_cholesky, tiled_lu,
                                tiled_qr)

SIZES = (256, 512, 1024)
TILE = 128
TDS_GRID = (2, 2)          # DAG layout used for the wait-mix table


def _time(fn, *args, reps: int = 3):
    fn(*args)                              # compile/warm
    best = np.inf
    for _ in range(reps):
        t0 = time.time()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.time() - t0)
    return best


def run(sizes=SIZES, tile=TILE):
    rng = np.random.default_rng(0)
    rows = []
    for n in sizes:
        a = rng.standard_normal((n, n)).astype(np.float32)
        spd = jnp.asarray(a @ a.T + n * np.eye(n, dtype=np.float32))
        gen = jnp.asarray(a + np.diag(np.full(n, 2.0 * n, np.float32)))

        for name, fn, mat in (
                ("cholesky", lambda m: tiled_cholesky(dense_to_tiles(m, tile)),
                 spd),
                ("lu", lambda m: tiled_lu(dense_to_tiles(m, tile)), gen),
                ("qr", lambda m: tiled_qr(dense_to_tiles(m, tile)), gen)):
            jitted = jax.jit(lambda m, f=fn: f(m).tiles)
            dt = _time(jitted, mat)
            fl = factorization_flops(name, n)
            rows.append({"factorization": name, "n": n, "tile": tile,
                         "seconds": dt, "gflops": fl / dt / 1e9})
    return rows


def run_tds_mix(n: int = SIZES[-1], tile: int = TILE, grid=TDS_GRID,
                proc_name: str = "arc_opteron_6128"):
    """Per-factorization TDS wait-class breakdown on the matching DAG."""
    proc = make_processor(proc_name)
    cost = CostModel()
    rows = []
    for name in ("cholesky", "lu", "qr"):
        graph = build_dag(name, n // tile, tile, grid)
        tds = compute_tds(graph, proc, cost)
        waits = tds.wait_seconds_by_class()
        total = sum(waits.values()) or 1.0
        rows.append({"factorization": name,
                     **{f"{k}_frac": v / total for k, v in waits.items()
                        if k != "none"},
                     "total_wait_s": sum(waits.values())})
    return rows


def run_kind_gears(n: int = SIZES[-1], tile: int = TILE, grid=TDS_GRID,
                   proc_name: str = "arc_opteron_6128"):
    """Per-kind gear rows: class task mix + asymmetric-table savings."""
    proc = make_processor(proc_name)
    cost = CostModel()
    cfg = StrategyConfig()
    depth = cfg.kind_gear_depth
    rows = []
    for name in ("cholesky", "lu", "qr"):
        graph = build_dag(name, n // tile, tile, grid)
        classes = task_gear_classes(graph)
        res = evaluate_strategies(
            graph, proc, cost, cfg=cfg,
            names=("original", "algorithmic", "task_type_gears",
                   "single_freq_opt"))
        row = {"factorization": name}
        for code, cls in enumerate(GEAR_CLASS_NAMES):
            row[f"{cls}_tasks"] = int((classes == code).sum())
            row[f"{cls}_gears"] = len(proc.gear_prefix(depth[cls]))
        for s in ("algorithmic", "task_type_gears", "single_freq_opt"):
            row[f"saved_{s}_pct"] = res[s].energy_saved_pct
        rows.append(row)
    return rows


def bench() -> tuple[list[str], dict]:
    rows = run()
    out = ["factorization,n,tile,seconds,gflops"]
    metrics = {}
    for r in rows:
        out.append(f"{r['factorization']},{r['n']},{r['tile']},"
                   f"{r['seconds']:.4f},{r['gflops']:.2f}")
        metrics[f"{r['factorization']}.n{r['n']}.gflops"] = \
            round(r["gflops"], 2)
    tds_rows = run_tds_mix()
    out.append("factorization,panel_wait_frac,comm_wait_frac,"
               "imbalance_wait_frac,total_wait_s")
    for r in tds_rows:
        out.append(f"{r['factorization']},{r['panel_frac']:.3f},"
                   f"{r['comm_frac']:.3f},{r['imbalance_frac']:.3f},"
                   f"{r['total_wait_s']:.4f}")
        metrics[f"{r['factorization']}.panel_wait_frac"] = \
            round(r["panel_frac"], 3)
    kind_rows = run_kind_gears()
    out.append("factorization,panel_tasks/gears,solve_tasks/gears,"
               "update_tasks/gears,saved_algorithmic_pct,"
               "saved_task_type_gears_pct,saved_single_freq_opt_pct")
    for r in kind_rows:
        out.append(
            f"{r['factorization']},"
            f"{r['panel_tasks']}/{r['panel_gears']},"
            f"{r['solve_tasks']}/{r['solve_gears']},"
            f"{r['update_tasks']}/{r['update_gears']},"
            f"{r['saved_algorithmic_pct']:.2f},"
            f"{r['saved_task_type_gears_pct']:.2f},"
            f"{r['saved_single_freq_opt_pct']:.2f}")
        metrics[f"{r['factorization']}.task_type_gears.saved_pct"] = \
            round(r["saved_task_type_gears_pct"], 3)
        metrics[f"{r['factorization']}.single_freq_opt.saved_pct"] = \
            round(r["saved_single_freq_opt_pct"], 3)
    return out, metrics


def main() -> list[str]:
    return bench()[0]


if __name__ == "__main__":
    print("\n".join(main()))
