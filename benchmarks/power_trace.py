"""Fig-2 reproduction: power consumption traces of three nodes running
distributed Cholesky under each strategy (ARC power model).

The paper's figure shows, over the *first few iterations* of a 160000^2
Cholesky on 16 nodes (three of them metered): ~950 W compute peaks, ~700 W
lows during communication slack for both energy strategies, and mid-power
segments where CP-aware reclamation stretches off-CP computation; peak
durations shrink iteration by iteration as the trailing matrix shrinks.

Here the task DAG is the first K iterations of a 48-tile Cholesky (the DAG
builder emits tasks in iteration order, so the prefix is itself a valid
closed subgraph), simulated on the 16 x 16 rank grid with the ARC
Opteron-6128 gear table; power is integrated over ranks 0..47 = the three
metered nodes.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.critical_path import validate_frozen_closure
from repro.core.dag import TaskGraph, build_dag
from repro.core.energy_model import (LinkModel, ProcessorModel,
                                     comm_low_power_w, make_processor)
from repro.core.scheduler import CostModel, simulate
from repro.core.strategies import PlanContext, get_strategy

GRID = (16, 16)            # 256 ranks = 16 nodes x 16 cores
NODES = (0, 1, 2)          # the paper meters three nodes on one power meter
TRACED = ("original", "cp_aware", "race_to_halt", "tx")
# ARC interconnect: one 40 Gb/s port per node; ~2 nJ end-to-end per byte
# moved, i.e. 10 W of wire power per saturated link at the 5 GB/s default.
# No bandwidth/latency override, so timing stays bit-identical to the
# uniform scalar path; only the wire-energy/power annotation is affected.
LINK = LinkModel(name="arc_ib", energy_per_byte_j=2e-9)


def comm_low_level_w(proc: ProcessorModel, cost: CostModel,
                     n_nodes: int = len(NODES)) -> float:
    """Model-derived 'comm-low' annotation level (W) for the metered
    nodes: every core parked at the halt gear while each node keeps one
    transfer in flight.  Derived from `comm_low_power_w` plus
    `LinkModel.transfer_power_w` -- this replaces the hardcoded ~700 W
    calibration constant the figure's annotation used to carry."""
    wire = cost.link.transfer_power_w(0, 1, cost.comm_bandwidth_gbs)
    return comm_low_power_w(proc, n_nodes=n_nodes,
                            link_power_w=n_nodes * wire)


def truncated_dag(name: str, n_tiles: int, tile: int, grid,
                  first_k: int) -> TaskGraph:
    """The first `first_k` iterations of a factorization DAG as a valid
    closed subgraph, validated (not `assert`ed -- asserts vanish under
    `python -O`) via the replan layer's frozen-closure checker."""
    g = build_dag(name, n_tiles, tile, grid)
    keep = np.asarray([t.k < first_k for t in g.tasks], dtype=bool)
    n_keep = int(keep.sum())
    if keep[:n_keep].sum() != n_keep:
        raise ValueError(
            f"iteration prefix k<{first_k} is not a task-id prefix; "
            "the DAG builder must emit tasks in iteration-major order")
    # dep-closure + per-rank prefix: exactly the executed-prefix closure
    # properties the re-planner validates, reused verbatim
    validate_frozen_closure(g, keep)
    return dataclasses.replace(g, tasks=g.tasks[:n_keep])


def run(n_tiles: int = 48, tile: int = 2560, first_k: int = 5,
        n_samples: int = 600):
    proc = make_processor("arc_opteron_6128")
    cost = CostModel(link=LINK)
    graph = truncated_dag("cholesky", n_tiles, tile, GRID, first_k)
    ctx = PlanContext(graph, proc, cost)    # baseline/slack/TDS shared
    traces = {}
    t_max = 0.0
    for name in TRACED:
        sched = simulate(graph, proc, cost, get_strategy(name).plan(ctx))
        t_max = max(t_max, sched.makespan)
        traces[name] = sched
    times = np.linspace(0.0, t_max, n_samples)
    return times, {name: s.power_trace(times, NODES)
                   for name, s in traces.items()}


def bench() -> tuple[list[str], dict]:
    times, traces = run()
    names = list(traces)
    out = ["time_s," + ",".join(f"{n}_w" for n in names)]
    for i, t in enumerate(times):
        out.append(f"{t:.4f}," + ",".join(f"{traces[n][i]:.1f}"
                                          for n in names))
    metrics = {}
    # summary: the three power levels of the figure
    for n in names:
        w = traces[n]
        out.append(f"# {n}: peak={w.max():.0f}W p75={np.percentile(w, 75):.0f}W "
                   f"median={np.median(w):.0f}W min={w.min():.0f}W")
        metrics[f"{n}.peak_w"] = round(float(w.max()), 1)
        metrics[f"{n}.median_w"] = round(float(np.median(w)), 1)
        metrics[f"{n}.min_w"] = round(float(w.min()), 1)
    level = comm_low_level_w(make_processor("arc_opteron_6128"),
                             CostModel(link=LINK))
    out.append(f"# comm_low: {level:.0f}W (derived: {len(NODES)} nodes at "
               "the halt gear + in-flight wire power; was a hardcoded "
               "~700W calibration comment)")
    metrics["comm_low_w"] = round(level, 1)
    return out, metrics


def main() -> list[str]:
    return bench()[0]


if __name__ == "__main__":
    print("\n".join(main()))
