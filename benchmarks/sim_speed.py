"""Schedule-simulator speed: event-driven engine vs the pick-loop oracle.

Every benchmark section re-runs the simulator per strategy per
factorization, so its speed bounds how large a sweep (grid size, tile
count, LM-DAG scenarios) the repo can afford. This section times
`simulate` (ready-heap + dependency counters) against
`simulate_reference` (the original O(tasks x ranks x deps) pick-loop)
on the paper's Cholesky DAG at T=32 tiles on a (4, 4) grid, for every
registered strategy (all plans built from one shared PlanContext), and
checks they agree while they're at it.

Acceptance target (ISSUE 1): >= 5x per strategy on this configuration.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.dag import build_dag
from repro.core.energy_model import make_processor
from repro.core.scheduler import CostModel, simulate, simulate_reference
from repro.core.strategies import (PlanContext, get_strategy,
                                   registered_strategies)

FACT = "cholesky"
N_TILES = 32
TILE = 256
GRID = (4, 4)


def _best_of(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(n_tiles: int = N_TILES, tile: int = TILE, grid=GRID,
        proc_name: str = "arc_opteron_6128", fast_repeats: int = 7,
        ref_repeats: int = 3):
    graph = build_dag(FACT, n_tiles, tile, grid)
    proc = make_processor(proc_name)
    cost = CostModel()
    ctx = PlanContext(graph, proc, cost)    # baseline/slack/TDS shared
    rows = []
    for name in registered_strategies():
        plan = get_strategy(name).plan(ctx)
        fast = simulate(graph, proc, cost, plan)     # warm graph caches
        ref = simulate_reference(graph, proc, cost, plan)
        agree = (np.array_equal(fast.start, ref.start)
                 and np.array_equal(fast.finish, ref.finish)
                 and fast.switch_count == ref.switch_count
                 and abs(fast.total_energy_j() - ref.total_energy_j())
                 <= 1e-9 * max(1.0, ref.total_energy_j()))
        t_fast = _best_of(lambda: simulate(graph, proc, cost, plan),
                          fast_repeats)
        t_ref = _best_of(lambda: simulate_reference(graph, proc, cost, plan),
                         ref_repeats)
        rows.append({
            "strategy": name, "n_tasks": len(graph.tasks),
            "fast_ms": t_fast * 1e3, "reference_ms": t_ref * 1e3,
            "speedup": t_ref / t_fast, "agree": agree,
        })
    return rows


def bench() -> tuple[list[str], dict]:
    rows = run()
    out = [f"# {FACT} T={N_TILES} tile={TILE} grid={GRID}: "
           f"{rows[0]['n_tasks']} tasks",
           "strategy,fast_ms,reference_ms,speedup,agree"]
    metrics = {}
    for r in rows:
        out.append(f"{r['strategy']},{r['fast_ms']:.2f},"
                   f"{r['reference_ms']:.2f},{r['speedup']:.1f},"
                   f"{r['agree']}")
        metrics[f"{r['strategy']}.speedup"] = round(r["speedup"], 1)
        metrics[f"{r['strategy']}.fast_ms"] = round(r["fast_ms"], 2)
    worst = min(r["speedup"] for r in rows)
    agree = all(r["agree"] for r in rows)
    out.append(f"# worst-case speedup {worst:.1f}x "
               f"(target >= 5x), all agree: {agree}")
    metrics["worst_speedup"] = round(worst, 1)
    metrics["all_agree"] = agree
    return out, metrics


def main() -> list[str]:
    return bench()[0]


if __name__ == "__main__":
    print("\n".join(main()))
