"""Schedule-simulator speed: event-driven engine vs the pick-loop oracle,
plus the batched fleet engine vs running the oracle lane by lane.

Every benchmark section re-runs the simulator per strategy per
factorization, so its speed bounds how large a sweep (grid size, tile
count, LM-DAG scenarios) the repo can afford. The first section times
`simulate` (ready-heap + dependency counters) against
`simulate_reference` (the original O(tasks x ranks x deps) pick-loop)
on the paper's Cholesky DAG at T=32 tiles on a (4, 4) grid, for every
registered strategy (all plans built from one shared PlanContext), and
checks they agree while they're at it.

The second section times `simulate_fleet` on a 64-lane tx_online noise
sweep (the `strategy_gap` Monte-Carlo shape: one distinct noise seed per
lane) against simulating each lane with `simulate_reference`, and checks
every lane against the oracle -- bit-identical timelines and switch
counts, 1e-9 energy -- per the three-engine differential contract.

The third section is the plan-optimizer throughput gate (ISSUE 7): a
1024-candidate batch of extra-time vectors -- on the big.LITTLE cell of
the `strategy_gap` oracle-gap study, the shape `plan_search` actually
runs there -- is scored by `optimize.CandidateEvaluator` in one
structure-of-arrays pass and timed against the naive per-candidate loop
(`PlanContext.reclaimed_segments` -> `StrategyPlan` -> fast `simulate`,
once per candidate -- exactly what a search without the batched
evaluator would run). The naive pass doubles as the agreement check:
bit-identical makespans, 1e-9 energies.

Acceptance targets: >= 5x per strategy (ISSUE 1), >= 50x aggregate on
the 64-lane fleet sweep (ISSUE 6), and >= 30x candidate throughput for
the search batch (ISSUE 7); all gated as hard floors by
`scripts/bench_compare.py`.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.dag import build_dag
from repro.core.energy_model import make_big_little, make_processor
from repro.core.fleet import simulate_fleet
from repro.core.optimize import CandidateEvaluator
from repro.core.scheduler import (CostModel, StrategyPlan, simulate,
                                  simulate_reference)
from repro.core.strategies import (PlanContext, StrategyConfig, get_strategy,
                                   registered_strategies)

FACT = "cholesky"
N_TILES = 32
TILE = 256
GRID = (4, 4)

# fleet sweep: B distinct tx_online lanes on a rank-heavy grid (the oracle
# scans every rank per pick, the fleet pass is rank-count-insensitive)
FLEET_LANES = 64
FLEET_N_TILES = 24
FLEET_GRID = (8, 8)
FLEET_REL_ERR = 0.15

# search-throughput gate: one CandidateEvaluator batch (the plan_search
# inner loop) vs the naive per-candidate fast-engine loop, on the
# oracle-gap study's big.LITTLE Cholesky cell (strategy_gap.run_oracle_gap)
SEARCH_LANES = 1024
SEARCH_N_TILES = 8
SEARCH_TILE = 512
SEARCH_GRID = (2, 2)


def _best_of(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(n_tiles: int = N_TILES, tile: int = TILE, grid=GRID,
        proc_name: str = "arc_opteron_6128", fast_repeats: int = 7,
        ref_repeats: int = 3):
    graph = build_dag(FACT, n_tiles, tile, grid)
    proc = make_processor(proc_name)
    cost = CostModel()
    ctx = PlanContext(graph, proc, cost)    # baseline/slack/TDS shared
    rows = []
    for name in registered_strategies():
        plan = get_strategy(name).plan(ctx)
        fast = simulate(graph, proc, cost, plan)     # warm graph caches
        ref = simulate_reference(graph, proc, cost, plan)
        agree = (np.array_equal(fast.start, ref.start)
                 and np.array_equal(fast.finish, ref.finish)
                 and fast.switch_count == ref.switch_count
                 and abs(fast.total_energy_j() - ref.total_energy_j())
                 <= 1e-9 * max(1.0, ref.total_energy_j()))
        t_fast = _best_of(lambda: simulate(graph, proc, cost, plan),
                          fast_repeats)
        t_ref = _best_of(lambda: simulate_reference(graph, proc, cost, plan),
                         ref_repeats)
        rows.append({
            "strategy": name, "n_tasks": len(graph.tasks),
            "fast_ms": t_fast * 1e3, "reference_ms": t_ref * 1e3,
            "speedup": t_ref / t_fast, "agree": agree,
        })
    return rows


def run_fleet(n_lanes: int = FLEET_LANES, n_tiles: int = FLEET_N_TILES,
              tile: int = TILE, grid=FLEET_GRID,
              proc_name: str = "arc_opteron_6128", fleet_repeats: int = 3):
    """Time one `simulate_fleet` pass over `n_lanes` tx_online plans vs
    running `simulate_reference` once per lane, verifying every lane
    against the oracle along the way (the timed oracle pass doubles as
    the agreement check)."""
    graph = build_dag(FACT, n_tiles, tile, grid)
    proc = make_processor(proc_name)
    cost = CostModel()
    plans = []
    for seed in range(n_lanes):
        cfg = StrategyConfig(tx_online_rel_err=FLEET_REL_ERR,
                             tx_online_seed=seed)
        plans.append(get_strategy("tx_online").plan(
            PlanContext(graph, proc, cost, cfg)))
    fleet = simulate_fleet(graph, proc, cost, plans)     # warm graph caches
    t_fleet = _best_of(lambda: simulate_fleet(graph, proc, cost, plans),
                       fleet_repeats)
    energies = fleet.total_energy_j()
    agree = True
    t0 = time.perf_counter()
    for i, plan in enumerate(plans):
        ref = simulate_reference(graph, proc, cost, plan)
        agree = agree and bool(
            np.array_equal(fleet.start[i], ref.start)
            and np.array_equal(fleet.finish[i], ref.finish)
            and int(fleet.switch_count[i]) == ref.switch_count
            and abs(energies[i] - ref.total_energy_j())
            <= 1e-9 * max(1.0, ref.total_energy_j()))
    t_ref = time.perf_counter() - t0
    return {
        "n_lanes": n_lanes, "n_tasks": len(graph.tasks),
        "n_ranks": graph.n_ranks, "fleet_ms": t_fleet * 1e3,
        "reference_ms": t_ref * 1e3, "speedup": t_ref / t_fleet,
        "agree": agree,
    }


def run_search(n_cands: int = SEARCH_LANES, n_tiles: int = SEARCH_N_TILES,
               tile: int = SEARCH_TILE, grid=SEARCH_GRID,
               proc_name: str = "arc_opteron_6128",
               batch_repeats: int = 3):
    """Candidate throughput of the batched plan evaluator vs a naive loop.

    Scores `n_cands` extra-time vectors (scaled realized slack x seeded
    jitter -- the shape of one `search_plan` round) with one
    `CandidateEvaluator.evaluate` call, then re-scores each candidate the
    way a search WITHOUT the evaluator would: render the plan through
    `PlanContext.reclaimed_segments`, run the fast `simulate` engine, and
    read the (energy, makespan) objective -- once per candidate. The
    workload is the oracle-gap study's big.LITTLE Cholesky cell (same
    tiles/grid/machine as `strategy_gap.run_oracle_gap`). The naive pass
    is timed once; its recorded objectives then double as the exactness
    check (bit-identical makespans, 1e-9-relative energies).
    """
    graph = build_dag(FACT, n_tiles, tile, grid)
    proc = make_big_little(proc_name)
    cost = CostModel()
    ctx = PlanContext(graph, proc, cost)
    n = ctx.n_tasks
    slack = np.maximum(ctx.slack, 0.0)
    d = ctx.durations
    rng = np.random.default_rng(0)
    E = (slack[None, :] * rng.uniform(0.0, 1.4, (n_cands, n))
         + rng.uniform(0.0, 0.15, (n_cands, n)) * d[None, :])
    ev = CandidateEvaluator(ctx, n_cands)        # one chunk, as in a search
    energy, make = ev.evaluate(E)                # warm the buffers
    t_batch = _best_of(lambda: ev.evaluate(E), batch_repeats)
    idle, rank_idle = ctx._idle_gears(-1)
    zeros = np.zeros(n)

    def naive(e):
        plan = StrategyPlan("cand", ctx.reclaimed_segments(e, 0.0),
                            idle_gear=idle, per_task_overhead=zeros,
                            hide_switch_in_wait=True,
                            rank_idle_gears=rank_idle)
        s = simulate(graph, proc, cost, plan)
        return s.total_energy_j(), s.makespan

    naive(E[0])                                  # warm graph caches
    got = []
    t0 = time.perf_counter()
    for i in range(n_cands):
        got.append(naive(E[i]))
    t_naive = time.perf_counter() - t0
    agree = all(
        mk == make[i] and abs(ej - energy[i]) <= 1e-9 * max(1.0, ej)
        for i, (ej, mk) in enumerate(got))
    return {
        "n_cands": n_cands, "n_tasks": n,
        "batch_ms": t_batch * 1e3, "naive_ms": t_naive * 1e3,
        "throughput_ratio": t_naive / t_batch, "agree": agree,
    }


def bench() -> tuple[list[str], dict]:
    rows = run()
    out = [f"# {FACT} T={N_TILES} tile={TILE} grid={GRID}: "
           f"{rows[0]['n_tasks']} tasks",
           "strategy,fast_ms,reference_ms,speedup,agree"]
    metrics = {}
    for r in rows:
        out.append(f"{r['strategy']},{r['fast_ms']:.2f},"
                   f"{r['reference_ms']:.2f},{r['speedup']:.1f},"
                   f"{r['agree']}")
        metrics[f"{r['strategy']}.speedup"] = round(r["speedup"], 1)
        metrics[f"{r['strategy']}.fast_ms"] = round(r["fast_ms"], 2)
    worst = min(r["speedup"] for r in rows)
    agree = all(r["agree"] for r in rows)
    out.append(f"# worst-case speedup {worst:.1f}x "
               f"(target >= 5x), all agree: {agree}")
    metrics["worst_speedup"] = round(worst, 1)
    metrics["all_agree"] = agree
    f = run_fleet()
    out.append(f"# fleet: {f['n_lanes']} tx_online lanes, {FACT} "
               f"T={FLEET_N_TILES} grid={FLEET_GRID}: {f['n_tasks']} tasks "
               f"x {f['n_ranks']} ranks")
    out.append(f"# fleet {f['fleet_ms']:.1f}ms vs oracle "
               f"{f['reference_ms']:.0f}ms = {f['speedup']:.1f}x "
               f"(target >= 50x), lanes agree: {f['agree']}")
    metrics["fleet_speedup"] = round(f["speedup"], 1)
    metrics["fleet_ms"] = round(f["fleet_ms"], 1)
    metrics["fleet_lanes"] = f["n_lanes"]
    metrics["fleet_agree"] = f["agree"]
    s = run_search()
    out.append(f"# search: {s['n_cands']} candidate plans, {FACT} "
               f"T={SEARCH_N_TILES} grid={SEARCH_GRID} big.LITTLE: "
               f"{s['n_tasks']} tasks")
    out.append(f"# batched {s['batch_ms']:.1f}ms vs naive loop "
               f"{s['naive_ms']:.0f}ms = {s['throughput_ratio']:.1f}x "
               f"(target >= 30x), candidates agree: {s['agree']}")
    metrics["search_throughput_ratio"] = round(s["throughput_ratio"], 1)
    metrics["search_ms"] = round(s["batch_ms"], 1)
    metrics["search_lanes"] = s["n_cands"]
    metrics["search_agree"] = s["agree"]
    return out, metrics


def main() -> list[str]:
    return bench()[0]


if __name__ == "__main__":
    print("\n".join(main()))
