"""Companion analysis (Eqns 7-9): Delta E_d and Delta E_l between CP-aware
slack reclamation (S2) and race-to-halt (S1) as the slack ratio n sweeps
over [1, f_h/f_l], for every published gear table.

Validates the worked example (AMD Opteron 2218, n = 1.25:
dEd = -0.8785 ACT, dEl = -0.0875 I_sub T) and quantifies the paper's core
observation -- the flatter V(f) is (modern CMOS), the smaller the energy
advantage of slack reclamation over race-to-halt.

A second sweep measures the same gap *simulated* rather than analytic: per
gear table, a small Cholesky DAG is planned by the registry strategies
(race_to_halt / algorithmic / tx) and the realized savings differences are
reported -- the full-simulator counterpart of the closed-form terms.

A third sweep is the cost-model noise study: `tx_online` plans from
duration estimates perturbed by a relative error eps ~ U[-err, +err]
(knobs: `StrategyConfig.tx_online_rel_err` sets the error magnitude,
`StrategyConfig.tx_online_seed` the noise draw; this module sweeps
`NOISE_LEVELS` x `NOISE_SEEDS` and reports the mean). The headline number
per error level is *retention*: the fraction of perfect-knowledge TX
savings the online planner still realizes once its mispredicted stretches
are charged against the true task durations.

A fourth sweep closes the loop (ISSUE 5): `tx_replan` starts from the
IDENTICAL noise draw but re-derives the residual slack/TDS from observed
finish times every `replan_every` iterations (`core/replan.py`). The sweep
crosses the same noise levels/seeds with `REPLAN_CADENCES` and reports
per-cell retention next to the one-shot `tx_online` row -- the closed loop
must retain at least as much at every error level (equal at rel_err = 0;
pinned by tests/test_replan.py).

A fifth sweep is the oracle-gap study (ISSUE 7): per factorization
(cholesky / lu / qr) x machine (homogeneous + big.LITTLE), `plan_search`
(`core/optimize.py`) establishes a searched upper bound on savings at the
configured slowdown cap, and every registered heuristic's savings are
reported as a *fraction* of that bound -- `oracle_gap.<fact>.<machine>.*`
answers "how much does each heuristic leave on the table" per cell."""

from __future__ import annotations

import numpy as np

from repro.core.dag import build_dag
from repro.core.energy_model import (GEAR_TABLES, make_big_little,
                                     make_processor, max_slack_ratio,
                                     strategy_gap_terms,
                                     verify_worked_example)
from repro.core.fleet import simulate_fleet
from repro.core.scheduler import CostModel
from repro.core.strategies import (PlanContext, StrategyConfig,
                                   evaluate_strategies, get_strategy,
                                   registered_strategies)

SIM_STRATEGIES = ("race_to_halt", "algorithmic", "tx")

# tx_online noise study: relative cost-model error levels and the seeds
# averaged per level (see module docstring).
NOISE_LEVELS = (0.0, 0.05, 0.10, 0.20, 0.40)
NOISE_SEEDS = (0, 1, 2)

# tx_replan cadence study: iterations per re-planning wave (1 = replan
# every panel iteration; large values converge to one-shot tx_online).
REPLAN_CADENCES = (1, 2, 4)


def run():
    ex = verify_worked_example()          # asserts the worked numbers
    rows = []
    for name in GEAR_TABLES:
        proc = make_processor(name)
        n_max = max_slack_ratio(proc)
        for n in np.linspace(1.0, n_max, 9):
            d_ed, d_el = strategy_gap_terms(proc, float(n))
            rows.append({"processor": name, "n": float(n),
                         "dEd_per_ACT": d_ed, "dEl_per_IsubT": d_el})
    return ex, rows


def run_simulated(fact: str = "cholesky", n_tiles: int = 8, tile: int = 512,
                  grid=(2, 2)):
    """Realized savings gap per gear table on a small simulated DAG."""
    cost = CostModel()
    graph = build_dag(fact, n_tiles, tile, grid)
    rows = []
    for name in GEAR_TABLES:
        proc = make_processor(name)
        res = evaluate_strategies(graph, proc, cost,
                                  names=("original",) + SIM_STRATEGIES)
        saved = {s: res[s].energy_saved_pct for s in SIM_STRATEGIES}
        rows.append({"processor": name, **saved,
                     "gap_algo_vs_race": saved["algorithmic"]
                     - saved["race_to_halt"],
                     "gap_tx_vs_race": saved["tx"] - saved["race_to_halt"]})
    return rows


def _fleet_saved_slow(graph, proc, cost, plans, ref_energy, ref_time):
    """Batched per-lane (saved_pct, slowdown_pct) vs the `original` baseline.

    One `simulate_fleet` pass replaces a per-cell serial `simulate` loop;
    the fleet engine is timeline-exact and energy-exact to 1e-9 vs the
    serial engines, so the reported percentages are unchanged within the
    benchmark's 3-decimal rounding.
    """
    fleet = simulate_fleet(graph, proc, cost, plans)
    energy = fleet.total_energy_j()
    span = fleet.makespan
    zeros = np.zeros(len(plans))
    saved = 100.0 * (1.0 - energy / ref_energy) if ref_energy else zeros
    slow = 100.0 * (span / ref_time - 1.0) if ref_time else zeros
    return saved, slow


def run_noise_sweep(fact: str = "cholesky", n_tiles: int = 8, tile: int = 512,
                    grid=(2, 2), proc_name: str = "arc_opteron_6128",
                    levels=NOISE_LEVELS, seeds=NOISE_SEEDS):
    """Savings of tx_online vs perfect-knowledge tx per noise level.

    Every (level, seed) cell replans with its own StrategyConfig (the
    perturbed-duration baseline/slack/TDS is rebuilt from scratch);
    planning stays per-cell, but all resulting plans -- plus the
    perfect-knowledge tx reference -- are charged against the true task
    durations in ONE `simulate_fleet` pass. Rows are per-level means.
    """
    graph = build_dag(fact, n_tiles, tile, grid)
    proc = make_processor(proc_name)
    cost = CostModel()
    ctx = PlanContext(graph, proc, cost)
    ref = ctx.baseline
    ref_energy, ref_time = ref.total_energy_j(), ref.makespan
    cells = [(err, seed) for err in levels for seed in seeds]
    plans = [get_strategy("tx").plan(ctx)]
    for err, seed in cells:
        cfg = StrategyConfig(tx_online_rel_err=err, tx_online_seed=seed)
        plans.append(get_strategy("tx_online").plan(
            PlanContext(graph, proc, cost, cfg)))
    saved, slow = _fleet_saved_slow(graph, proc, cost, plans,
                                    ref_energy, ref_time)
    tx_saved = float(saved[0])
    rows = []
    for i, err in enumerate(levels):
        lanes = slice(1 + i * len(seeds), 1 + (i + 1) * len(seeds))
        mean_saved = float(np.mean(saved[lanes]))
        rows.append({"rel_err": err, "saved_pct": mean_saved,
                     "slowdown_pct": float(np.mean(slow[lanes])),
                     "tx_saved_pct": tx_saved,
                     "retention": mean_saved / tx_saved if tx_saved else 0.0})
    return rows


def run_replan_sweep(fact: str = "cholesky", n_tiles: int = 8,
                     tile: int = 512, grid=(2, 2),
                     proc_name: str = "arc_opteron_6128",
                     levels=NOISE_LEVELS, seeds=NOISE_SEEDS,
                     cadences=REPLAN_CADENCES, noise_rows=None):
    """Closed-loop retention: tx_replan vs tx_online per (rel_err, cadence).

    Same graph/processor/noise grid as `run_noise_sweep`; every cell plans
    `tx_replan` with its own StrategyConfig (identical noise draw to the
    tx_online cell with the same seed) and simulates against the true
    durations. Rows are per-(level, cadence) seed means, each carrying the
    matching tx_online mean for the side-by-side retention comparison.
    `noise_rows` lets `bench()` pass `run_noise_sweep`'s output so the
    tx_online/tx reference cells are not recomputed; levels missing from
    it (or all levels, when None) are evaluated here.
    """
    graph = build_dag(fact, n_tiles, tile, grid)
    proc = make_processor(proc_name)
    cost = CostModel()
    ctx = PlanContext(graph, proc, cost)
    ref = ctx.baseline
    ref_energy, ref_time = ref.total_energy_j(), ref.makespan
    online_by_err = {r["rel_err"]: (r["saved_pct"], r["tx_saved_pct"])
                     for r in (noise_rows or [])}
    # planning stays per-cell (each cell re-derives estimates / replans
    # waves from its own cfg); every final plan is then charged against
    # the true durations in one batched fleet pass
    plans, keys = [], []
    if not online_by_err:
        plans.append(get_strategy("tx").plan(ctx))
        keys.append("tx")
    for err in levels:
        if err not in online_by_err:
            for seed in seeds:
                cfg = StrategyConfig(tx_online_rel_err=err,
                                     tx_online_seed=seed)
                plans.append(get_strategy("tx_online").plan(
                    PlanContext(graph, proc, cost, cfg)))
                keys.append(("online", err))
        for every in cadences:
            for seed in seeds:
                cfg = StrategyConfig(tx_online_rel_err=err,
                                     tx_online_seed=seed,
                                     replan_every=every)
                plans.append(get_strategy("tx_replan").plan(
                    PlanContext(graph, proc, cost, cfg)))
                keys.append(("replan", err, every))
    saved, slow = _fleet_saved_slow(graph, proc, cost, plans,
                                    ref_energy, ref_time)
    by_key: dict = {}
    for k, sv, sl in zip(keys, saved, slow):
        by_key.setdefault(k, ([], []))
        by_key[k][0].append(float(sv))
        by_key[k][1].append(float(sl))
    tx_saved = next(iter(online_by_err.values()))[1] if online_by_err else \
        by_key["tx"][0][0]
    rows = []
    for err in levels:
        online_mean = online_by_err[err][0] if err in online_by_err else \
            float(np.mean(by_key[("online", err)][0]))
        for every in cadences:
            cell_saved, cell_slow = by_key[("replan", err, every)]
            mean_saved = float(np.mean(cell_saved))
            rows.append({
                "rel_err": err, "replan_every": every,
                "saved_pct": mean_saved,
                "slowdown_pct": float(np.mean(cell_slow)),
                "online_saved_pct": online_mean,
                "tx_saved_pct": tx_saved,
                "retention": mean_saved / tx_saved if tx_saved else 0.0,
                "gain_vs_online_pts": mean_saved - online_mean,
            })
    return rows


ORACLE_FACTS = ("cholesky", "lu", "qr")


def run_oracle_gap(n_tiles: int = 8, tile: int = 512, grid=(2, 2),
                   proc_name: str = "arc_opteron_6128",
                   facts=ORACLE_FACTS):
    """Searched savings bound + per-heuristic retention per (fact, machine).

    For each factorization DAG and each machine (homogeneous `proc_name`
    and the canned big.LITTLE), every registered strategy -- including
    `plan_search` -- is planned once and all plans are charged in a single
    `simulate_fleet` pass (via `evaluate_strategies`). `plan_search` is
    seeded with every heuristic's plan, so its savings are a per-cell
    upper bound over the registry; each heuristic's row reports the
    fraction of that bound it realizes.
    """
    cost = CostModel()
    machines = (("homog", make_processor(proc_name)),
                ("big_little", make_big_little(proc_name)))
    names = tuple(registered_strategies())
    heuristics = tuple(n for n in names
                       if n not in ("original", "plan_search"))
    rows = []
    for fact in facts:
        graph = build_dag(fact, n_tiles, tile, grid)
        for mname, machine in machines:
            res = evaluate_strategies(graph, machine, cost, names=names)
            bound = res["plan_search"].energy_saved_pct
            rows.append({
                "fact": fact, "machine": mname,
                "search_saved_pct": bound,
                "search_slowdown_pct": res["plan_search"].slowdown_pct,
                "retention": {h: (res[h].energy_saved_pct / bound
                                  if bound else 0.0)
                              for h in heuristics},
            })
    return rows


def bench() -> tuple[list[str], dict]:
    ex, rows = run()
    out = [f"# worked example ok: dEd={ex['dEd']:.4f} dEl={ex['dEl']:.4f}",
           "processor,n,dEd_per_ACT,dEl_per_IsubT"]
    for r in rows:
        out.append(f"{r['processor']},{r['n']:.3f},"
                   f"{r['dEd_per_ACT']:.4f},{r['dEl_per_IsubT']:.4f}")
    metrics = {"worked_example.dEd": round(ex["dEd"], 4),
               "worked_example.dEl": round(ex["dEl"], 4)}
    # voltage-flatness metric vs gap at n = 1.5 (clamped into range)
    out.append("processor,v_ratio,gap_at_n1_5")
    for name in GEAR_TABLES:
        proc = make_processor(name)
        v = proc.gears[-1].voltage / proc.gears[0].voltage
        n = min(1.5, max_slack_ratio(proc))
        d_ed, _ = strategy_gap_terms(proc, n)
        out.append(f"{name},{v:.3f},{d_ed:.4f}")
        metrics[f"{name}.dEd_at_n1_5"] = round(d_ed, 4)
    # simulated counterpart: registry strategies on a small Cholesky
    sim = run_simulated()
    out.append("processor,saved_race_pct,saved_algo_pct,saved_tx_pct,"
               "gap_algo_vs_race,gap_tx_vs_race")
    for r in sim:
        out.append(f"{r['processor']},{r['race_to_halt']:.2f},"
                   f"{r['algorithmic']:.2f},{r['tx']:.2f},"
                   f"{r['gap_algo_vs_race']:.3f},{r['gap_tx_vs_race']:.3f}")
        metrics[f"{r['processor']}.sim_gap_tx_vs_race"] = \
            round(r["gap_tx_vs_race"], 3)
    # cost-model noise study: how much of TX survives online estimation
    noise = run_noise_sweep()
    out.append("tx_online_rel_err,saved_pct,slowdown_pct,tx_saved_pct,"
               "retention")
    for r in noise:
        out.append(f"{r['rel_err']:.2f},{r['saved_pct']:.3f},"
                   f"{r['slowdown_pct']:.3f},{r['tx_saved_pct']:.3f},"
                   f"{r['retention']:.3f}")
        metrics[f"tx_online.err{r['rel_err']:.2f}.saved_pct"] = \
            round(r["saved_pct"], 3)
        metrics[f"tx_online.err{r['rel_err']:.2f}.retention"] = \
            round(r["retention"], 3)
    # closed-loop study: tx_replan retention per (noise level, cadence);
    # the tx_online/tx reference cells are reused from the sweep above
    replan = run_replan_sweep(noise_rows=noise)
    out.append("tx_replan_rel_err,replan_every,saved_pct,slowdown_pct,"
               "online_saved_pct,tx_saved_pct,retention,gain_vs_online_pts")
    for r in replan:
        out.append(f"{r['rel_err']:.2f},{r['replan_every']},"
                   f"{r['saved_pct']:.3f},{r['slowdown_pct']:.3f},"
                   f"{r['online_saved_pct']:.3f},{r['tx_saved_pct']:.3f},"
                   f"{r['retention']:.3f},{r['gain_vs_online_pts']:.3f}")
        key = f"tx_replan.err{r['rel_err']:.2f}.every{r['replan_every']}"
        metrics[f"{key}.saved_pct"] = round(r["saved_pct"], 3)
        metrics[f"{key}.retention"] = round(r["retention"], 3)
    # oracle-gap study: searched savings bound per (fact, machine) and the
    # fraction of it each registered heuristic realizes
    oracle = run_oracle_gap()
    out.append("oracle_fact,machine,search_saved_pct,search_slowdown_pct,"
               "strategy,retention")
    for r in oracle:
        cell = f"oracle_gap.{r['fact']}.{r['machine']}"
        metrics[f"{cell}.search_saved_pct"] = round(r["search_saved_pct"], 3)
        for strat, frac in sorted(r["retention"].items()):
            out.append(f"{r['fact']},{r['machine']},"
                       f"{r['search_saved_pct']:.3f},"
                       f"{r['search_slowdown_pct']:.3f},"
                       f"{strat},{frac:.3f}")
            metrics[f"{cell}.{strat}"] = round(frac, 3)
    return out, metrics


def main() -> list[str]:
    return bench()[0]


if __name__ == "__main__":
    print("\n".join(main()))
