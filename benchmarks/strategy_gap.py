"""Companion analysis (Eqns 7-9): Delta E_d and Delta E_l between CP-aware
slack reclamation (S2) and race-to-halt (S1) as the slack ratio n sweeps
over [1, f_h/f_l], for every published gear table.

Validates the worked example (AMD Opteron 2218, n = 1.25:
dEd = -0.8785 ACT, dEl = -0.0875 I_sub T) and quantifies the paper's core
observation -- the flatter V(f) is (modern CMOS), the smaller the energy
advantage of slack reclamation over race-to-halt."""

from __future__ import annotations

import numpy as np

from repro.core.energy_model import (GEAR_TABLES, make_processor,
                                     max_slack_ratio, strategy_gap_terms,
                                     verify_worked_example)


def run():
    ex = verify_worked_example()          # asserts the worked numbers
    rows = []
    for name in GEAR_TABLES:
        proc = make_processor(name)
        n_max = max_slack_ratio(proc)
        for n in np.linspace(1.0, n_max, 9):
            d_ed, d_el = strategy_gap_terms(proc, float(n))
            rows.append({"processor": name, "n": float(n),
                         "dEd_per_ACT": d_ed, "dEl_per_IsubT": d_el})
    return ex, rows


def main() -> list[str]:
    ex, rows = run()
    out = [f"# worked example ok: dEd={ex['dEd']:.4f} dEl={ex['dEl']:.4f}",
           "processor,n,dEd_per_ACT,dEl_per_IsubT"]
    for r in rows:
        out.append(f"{r['processor']},{r['n']:.3f},"
                   f"{r['dEd_per_ACT']:.4f},{r['dEl_per_IsubT']:.4f}")
    # voltage-flatness metric vs gap at n = 1.5 (clamped into range)
    out.append("processor,v_ratio,gap_at_n1_5")
    for name in GEAR_TABLES:
        proc = make_processor(name)
        v = proc.gears[-1].voltage / proc.gears[0].voltage
        n = min(1.5, max_slack_ratio(proc))
        d_ed, _ = strategy_gap_terms(proc, n)
        out.append(f"{name},{v:.3f},{d_ed:.4f}")
    return out


if __name__ == "__main__":
    print("\n".join(main()))
