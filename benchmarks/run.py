"""Benchmark driver: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--json PATH]

Sections:
    strategy_gap       Eqns 7-9 sweep + simulated registry gap    (Table 2)
    energy_savings     strategies x factorizations, 16x16 grid   (main table)
    power_trace        3-node power traces, Cholesky             (Figure 2)
    factorization_perf tiled factorization GFLOP/s + TDS mix     (perf table)
    heterogeneous      strategies on big.LITTLE machines          (Costero)
    lm_energy          technique on LM step DAGs (all archs)     (adaptation)
    serving            J/token + p99 under diurnal traffic        (serving)
    sim_speed          event-driven simulator vs pick-loop oracle (infra)

Each section module exposes `bench() -> (lines, metrics)`: the printable
table plus a flat dict of key numbers. `--json PATH` collects per-section
wall time and those metrics into one machine-readable results file
(`BENCH_*.json` style) so successive PRs can track the perf trajectory.
"""

from __future__ import annotations

import argparse
import json
import platform
import time

from . import (energy_savings, factorization_perf, heterogeneous, lm_energy,
               power_trace, serving_energy, sim_speed, strategy_gap)

SECTIONS = {
    "strategy_gap": strategy_gap.bench,
    "energy_savings": energy_savings.bench,
    "power_trace": power_trace.bench,
    "factorization_perf": factorization_perf.bench,
    "heterogeneous": heterogeneous.bench,
    "lm_energy": lm_energy.bench,
    "serving": serving_energy.bench,
    "sim_speed": sim_speed.bench,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(SECTIONS), default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write per-section timings + key metrics as JSON")
    args = ap.parse_args()
    names = [args.only] if args.only else list(SECTIONS)
    report: dict[str, dict] = {}
    for name in names:
        t0 = time.time()
        print(f"\n===== {name} " + "=" * (60 - len(name)))
        lines, metrics = SECTIONS[name]()
        for line in lines:
            print(line)
        dt = time.time() - t0
        print(f"# [{name}] {dt:.1f}s")
        report[name] = {"seconds": round(dt, 3), **metrics}
    if args.json:
        payload = {
            "suite": "benchmarks.run",
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "python": platform.python_version(),
            "machine": platform.machine(),
            "sections": report,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"\n# wrote {args.json}")


if __name__ == "__main__":
    main()
