"""Benchmark driver: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Sections:
    strategy_gap       Eqns 7-9 sweep + worked-example check     (Table 2)
    energy_savings     strategies x factorizations, 16x16 grid   (main table)
    power_trace        3-node power traces, Cholesky             (Figure 2)
    factorization_perf tiled factorization GFLOP/s               (perf table)
    lm_energy          technique on LM step DAGs (all archs)     (adaptation)
    sim_speed          event-driven simulator vs pick-loop oracle (infra)
"""

from __future__ import annotations

import argparse
import time

from . import (energy_savings, factorization_perf, lm_energy, power_trace,
               sim_speed, strategy_gap)

SECTIONS = {
    "strategy_gap": strategy_gap.main,
    "energy_savings": energy_savings.main,
    "power_trace": power_trace.main,
    "factorization_perf": factorization_perf.main,
    "lm_energy": lm_energy.main,
    "sim_speed": sim_speed.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(SECTIONS), default=None)
    args = ap.parse_args()
    names = [args.only] if args.only else list(SECTIONS)
    for name in names:
        t0 = time.time()
        print(f"\n===== {name} " + "=" * (60 - len(name)))
        for line in SECTIONS[name]():
            print(line)
        print(f"# [{name}] {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
